#!/usr/bin/env python
"""Docs smoke checker: the commands quoted in README.md and docs/*.md
must actually run, so the docs cannot rot silently (ISSUE 2, docs CI).

For every fenced ```bash block the checker validates each command line:

  * ``python <script.py>``        -> the script exists and byte-compiles
  * ``python -m pytest ...``      -> ``pytest --version`` succeeds (the
                                     suite itself is CI's tier-1 job)
  * ``python -m <module> ...``    -> ``python -m <module> --help`` runs
                                     under the documented PYTHONPATH
  * anything else                 -> flagged as unknown (fail): keep the
                                     docs to commands this tool can vouch
                                     for, or teach it the new shape

Relative markdown links are also resolved, so a doc cannot point at a
file that was moved or deleted.  Two structural checks (ISSUE 9) keep
the doc graph itself healthy:

  * **orphans** — every ``docs/**/*.md`` must be reachable from
    README.md by following relative markdown links; an unreferenced
    doc is invisible to readers and rots fastest
  * **source paths** — bare repo paths mentioned in prose (``src/...``,
    ``tools/...``, ``benchmarks/...``, ``tests/...``) must exist, so a
    doc cannot keep describing a module that was deleted or moved

Runs fully offline in a few seconds:

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import py_compile
import re
import shlex
import subprocess
import sys
from typing import List, Tuple

ROOT = pathlib.Path(__file__).resolve().parents[1]
# ``**`` so docs added in subdirectories (docs/ops/x.md, ...) are
# scanned too instead of silently skipped
DOC_GLOBS = ["README.md", "docs/**/*.md"]
FENCE = re.compile(r"^```(\w*)\s*$")
LINK = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
# bare repo paths in prose/backticks: src/repro/core/fetch.py, tools/...
SRC_PATH = re.compile(
    r"\b((?:src|tools|benchmarks|tests)/[\w/.-]+\.(?:py|md|json))\b")


def doc_files() -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for g in DOC_GLOBS:
        out.extend(sorted(ROOT.glob(g)))
    return out


def extract_commands(path: pathlib.Path) -> List[Tuple[int, str]]:
    """(line_no, command) for each command line in bash-tagged fences."""
    cmds: List[Tuple[int, str]] = []
    lang = None
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = FENCE.match(line)
        if m:
            lang = None if lang is not None else m.group(1).lower()
            continue
        if lang in ("bash", "sh", "shell", "console"):
            cmd = line.strip().lstrip("$ ").strip()
            if cmd and not cmd.startswith("#"):
                cmds.append((i, cmd))
    return cmds


def _run(argv: List[str], env_extra: dict) -> Tuple[bool, str]:
    import os
    env = dict(os.environ)
    for k, v in env_extra.items():
        env[k] = f"{v}:{env[k]}" if k == "PYTHONPATH" and k in env else v
    try:
        p = subprocess.run(argv, cwd=ROOT, env=env, timeout=120,
                           capture_output=True, text=True)
    except Exception as e:  # noqa: BLE001
        return False, repr(e)
    return p.returncode == 0, (p.stderr or p.stdout)[-400:]


def check_command(cmd: str) -> Tuple[bool, str]:
    toks = shlex.split(cmd)
    env_extra = {}
    while toks and "=" in toks[0] and not toks[0].startswith("-"):
        k, v = toks.pop(0).split("=", 1)
        env_extra[k] = v
    if not toks:
        return True, "env-only line"
    if toks[0] not in ("python", "python3", sys.executable):
        return False, f"unknown command shape: {toks[0]!r}"
    toks = toks[1:]
    if toks[:1] == ["-m"]:
        module = toks[1]
        if module == "pytest":
            ok, out = _run([sys.executable, "-m", "pytest", "--version"],
                           env_extra)
            return ok, out if not ok else "pytest available"
        ok, out = _run([sys.executable, "-m", module, "--help"], env_extra)
        return ok, out if not ok else f"-m {module} --help ran"
    script = ROOT / toks[0]
    if not script.exists():
        return False, f"missing script {toks[0]}"
    try:
        py_compile.compile(str(script), doraise=True)
    except py_compile.PyCompileError as e:
        return False, str(e)
    return True, f"{toks[0]} exists and compiles"


def check_links(path: pathlib.Path) -> List[str]:
    bad = []
    for target in LINK.findall(path.read_text()):
        target = target.split("#")[0].strip()
        if not target or target.startswith(("http://", "https://")):
            continue
        if not (path.parent / target).exists():
            bad.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
    return bad


def check_orphans() -> List[str]:
    """Every docs/**/*.md must be link-reachable from README.md."""
    reachable = set()
    queue = [ROOT / "README.md"]
    while queue:
        doc = queue.pop()
        if doc in reachable or not doc.exists():
            continue
        reachable.add(doc)
        for target in LINK.findall(doc.read_text()):
            target = target.split("#")[0].strip()
            if not target or target.startswith(("http://", "https://")):
                continue
            if target.endswith(".md"):
                queue.append((doc.parent / target).resolve())
    return [f"orphaned doc (not linked from README.md): "
            f"{d.relative_to(ROOT)}"
            for d in doc_files() if d.resolve() not in reachable]


def check_source_paths(path: pathlib.Path) -> List[str]:
    """Repo paths mentioned in the doc body must exist on disk."""
    bad = []
    for target in SRC_PATH.findall(path.read_text()):
        if not (ROOT / target).exists():
            bad.append(f"{path.relative_to(ROOT)}: "
                       f"references deleted path -> {target}")
    return bad


def main() -> int:
    failures: List[str] = []
    n_cmds = 0
    failures.extend(check_orphans())
    for doc in doc_files():
        failures.extend(check_links(doc))
        failures.extend(check_source_paths(doc))
        for line_no, cmd in extract_commands(doc):
            n_cmds += 1
            ok, detail = check_command(cmd)
            tag = "ok" if ok else "FAIL"
            print(f"[{tag}] {doc.relative_to(ROOT)}:{line_no}: {cmd}"
                  + ("" if ok else f"\n       {detail}"))
            if not ok:
                failures.append(f"{doc.relative_to(ROOT)}:{line_no}: {cmd}")
    if not n_cmds:
        failures.append("no commands found in docs: checker misconfigured?")
    if failures:
        print(f"\n{len(failures)} docs check(s) failed:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nall {n_cmds} documented commands smoke-checked OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
