"""repro-lint core: files, pragmas, diagnostics, and the rule registry.

The engine is deliberately small and stdlib-only (``ast`` + ``re``):

* :class:`SourceFile` parses one file once and pre-computes its
  suppression pragmas (``# repro-lint: allow(<rule>[, <rule>...])``,
  effective on the pragma's own line and the line directly below — so
  a standalone comment line can annotate the statement it precedes).
* :class:`Rule` subclasses implement ``check(file)`` for per-file AST
  passes and/or ``finalize(project)`` for whole-tree passes (the
  cross-environment parity rule needs to see several files at once).
* :func:`run_paths` walks the requested paths, applies every selected
  rule, filters suppressed diagnostics, and returns the rest in a
  stable order — ``(path, line, col, rule, message)`` — so two runs
  over the same tree always print byte-identical output (the linter
  practices the determinism it preaches).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

PRAGMA = re.compile(r"#\s*repro-lint:\s*allow\(([\w\-, ]+)\)")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding, anchored to a source line."""
    path: str   # repo-relative, posix separators
    line: int   # 1-based
    col: int    # 0-based (ast convention)
    rule: str
    message: str

    def sort_key(self):
        return (self.path, self.line, self.col, self.rule, self.message)

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule}: {self.message}")


class SourceFile:
    """One parsed source file plus its pragma map."""

    def __init__(self, abspath: str, relpath: str):
        self.abspath = abspath
        self.path = relpath.replace(os.sep, "/")
        with open(abspath, "r", encoding="utf-8") as f:
            self.text = f.read()
        self.tree = ast.parse(self.text, filename=self.path)
        # line -> rule names allowed there.  A pragma suppresses its own
        # line and the line below, so both inline tail comments and
        # standalone comment lines above a statement work.
        self._allow: Dict[int, set] = {}
        for i, line in enumerate(self.text.splitlines(), 1):
            m = PRAGMA.search(line)
            if m:
                names = {n.strip() for n in m.group(1).split(",")
                         if n.strip()}
                self._allow.setdefault(i, set()).update(names)
                self._allow.setdefault(i + 1, set()).update(names)

    @property
    def parts(self) -> tuple:
        """Path segments (for rule scoping, e.g. ``"tests" in parts``)."""
        return tuple(self.path.split("/"))

    def suppressed(self, diag: Diagnostic) -> bool:
        return diag.rule in self._allow.get(diag.line, ())

    def diag(self, node: ast.AST, rule: str, message: str) -> Diagnostic:
        return Diagnostic(self.path, getattr(node, "lineno", 1),
                          getattr(node, "col_offset", 0), rule, message)


class Project:
    """Every file of one lint run (whole-tree context for finalize)."""

    def __init__(self, files: Sequence[SourceFile]):
        self.files = list(files)

    def classes(self) -> Iterator[tuple]:
        """Yield ``(file, ClassDef)`` for every top-level class."""
        for f in self.files:
            for node in f.tree.body:
                if isinstance(node, ast.ClassDef):
                    yield f, node


class Rule:
    """Base class: subclass, set ``name``/``summary``, register."""

    name = ""
    summary = ""

    def check(self, f: SourceFile) -> Iterable[Diagnostic]:
        return ()

    def finalize(self, project: Project) -> Iterable[Diagnostic]:
        return ()


RULES: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule to the global registry."""
    assert cls.name and cls.name not in RULES, \
        f"rule name missing or duplicated: {cls.name!r}"
    RULES[cls.name] = cls()
    return cls


# -- helpers shared by rules -------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.AST) -> Dict[str, str]:
    """Local name -> fully qualified module/attribute, from imports.

    ``import numpy as np`` -> ``{"np": "numpy"}``;
    ``from time import perf_counter as pc`` ->
    ``{"pc": "time.perf_counter"}``.  Star imports are ignored.
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve_call(node: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    """Fully-qualified dotted name of a call target, via the file's
    import aliases (``np.random.rand`` -> ``numpy.random.rand``)."""
    dn = dotted_name(node.func)
    if dn is None:
        return None
    head, _, rest = dn.partition(".")
    full = aliases.get(head, head)
    return f"{full}.{rest}" if rest else full


def walk_functions(tree: ast.AST) -> Iterator[ast.AST]:
    """Every function/async-function definition, any nesting depth."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# -- discovery + the run loop ------------------------------------------------

def _iter_py_files(paths: Sequence[str], root: str) -> Iterator[tuple]:
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            yield ap, os.path.relpath(ap, root)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__"
                                     and not d.startswith("."))
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        full = os.path.join(dirpath, fn)
                        yield full, os.path.relpath(full, root)


def run_paths(paths: Sequence[str], *, root: Optional[str] = None,
              select: Optional[Sequence[str]] = None,
              ignore: Sequence[str] = ()) -> List[Diagnostic]:
    """Lint ``paths`` (files or directories) and return the surviving
    diagnostics, stably ordered.  ``root`` anchors the relative paths
    reported in diagnostics (default: cwd).  ``select``/``ignore``
    filter the rule set by name."""
    root = os.path.abspath(root or os.getcwd())
    active = {n: r for n, r in RULES.items()
              if (select is None or n in select) and n not in ignore}
    unknown = set(select or ()) - set(RULES) | set(ignore) - set(RULES)
    assert not unknown, f"unknown rule(s): {sorted(unknown)}"
    files: List[SourceFile] = []
    out: List[Diagnostic] = []
    for abspath, relpath in _iter_py_files(paths, root):
        try:
            f = SourceFile(abspath, relpath)
        except SyntaxError as e:
            out.append(Diagnostic(relpath.replace(os.sep, "/"),
                                  e.lineno or 1, 0, "parse-error", str(e)))
            continue
        files.append(f)
        for rule in active.values():
            for d in rule.check(f):
                if not f.suppressed(d):
                    out.append(d)
    project = Project(files)
    by_path = {f.path: f for f in files}
    for rule in active.values():
        for d in rule.finalize(project):
            f = by_path.get(d.path)
            if f is None or not f.suppressed(d):
                out.append(d)
    return sorted(set(out), key=Diagnostic.sort_key)
