"""CLI: ``python -m tools.repro_lint [paths...]``.

Exits 0 when the tree is clean, 1 when any diagnostic survives
suppression — CI runs it as a required job (see .github/workflows/
ci.yml ``lint``), so a replay-contract violation fails the build with
a ``path:line:col: rule: message`` pointing at the offending line.
"""
from __future__ import annotations

import argparse
import sys

from . import RULES, run_paths

DEFAULT_PATHS = ["src", "tests", "benchmarks", "tools"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description=("Determinism linter enforcing the replay contract "
                     "(docs/determinism.md): simulator and live engine "
                     "must replay byte-identical, timestamp-free event "
                     "logs from seeded inputs."))
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files or directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", default=None,
                    help="directory diagnostics are reported relative "
                         "to (default: cwd)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule names to run "
                         "(default: all)")
    ap.add_argument("--ignore", default="",
                    help="comma-separated rule names to skip")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in sorted(RULES):
            print(f"{name:24s} {RULES[name].summary}")
        return 0

    select = ([s.strip() for s in args.select.split(",") if s.strip()]
              if args.select else None)
    ignore = [s.strip() for s in args.ignore.split(",") if s.strip()]
    diags = run_paths(args.paths or DEFAULT_PATHS, root=args.root,
                      select=select, ignore=ignore)
    for d in diags:
        print(d)
    n = len(diags)
    print(f"repro-lint: {n} diagnostic{'s' if n != 1 else ''}"
          + ("" if n else " — replay contract holds"))
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main())
