"""The shipped rule set: six determinism invariants, mechanically checked.

Each rule is a small AST pass grounded in one way cross-environment
replay has broken (or nearly broken) in this repo.  The contract they
enforce — and the reasoning behind each — is docs/determinism.md; the
table there mirrors the ``summary`` strings below.

Scoping conventions:

* ``src/`` is replay-relevant production code: the wall-clock ban
  applies there (``launch/``/``training/``/``serving/`` annotate their
  legitimate timing sites with pragmas).
* ``tests/``/``benchmarks/``/``tools/`` measure and report — wall
  clocks are fine there, but unseeded RNG and direct ``hypothesis``
  imports are not.
* Dicts are insertion-ordered in every supported Python (>= 3.7) and
  the event logs rely on that; **sets are not order-stable for str
  keys across processes** (hash randomization), which is why
  ``ordered-iteration`` bans set-typed replay state outright instead
  of trying to prove a particular drain is sorted.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .engine import (Diagnostic, Project, Rule, SourceFile, dotted_name,
                     import_aliases, register, resolve_call,
                     walk_functions)

# -- no-wall-clock -----------------------------------------------------------

WALL_CLOCK_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}


@register
class NoWallClock(Rule):
    name = "no-wall-clock"
    summary = ("wall-clock reads are banned in src/ (virtual clocks "
               "only); annotate legitimate timing sites with a pragma")

    def check(self, f: SourceFile) -> Iterator[Diagnostic]:
        if "src" not in f.parts[:1]:
            return
        aliases = import_aliases(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            target = resolve_call(node, aliases)
            if target in WALL_CLOCK_CALLS:
                yield f.diag(
                    node, self.name,
                    f"{target}() reads the wall clock; replayed state "
                    f"must come from the virtual clock or seeded "
                    f"inputs")


# -- seeded-rng --------------------------------------------------------------

# the legacy module-level numpy API draws from one hidden global state;
# the repo threads explicit numpy.random.Generator objects instead
LEGACY_NP_RANDOM = {
    "seed", "random", "rand", "randn", "randint", "random_integers",
    "random_sample", "ranf", "sample", "choice", "shuffle",
    "permutation", "uniform", "normal", "standard_normal", "poisson",
    "exponential", "binomial", "bytes", "get_state", "set_state",
}


@register
class SeededRng(Rule):
    name = "seeded-rng"
    summary = ("global-state RNG (stdlib random, legacy numpy.random.*) "
               "is banned; thread a seeded numpy.random.Generator")

    def check(self, f: SourceFile) -> Iterator[Diagnostic]:
        aliases = import_aliases(f.tree)
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "random" or a.name.startswith("random."):
                        yield f.diag(
                            node, self.name,
                            "stdlib 'random' draws from hidden global "
                            "state; use numpy.random.default_rng(seed)")
            elif isinstance(node, ast.ImportFrom):
                if node.level == 0 and node.module and \
                        (node.module == "random"
                         or node.module.startswith("random.")):
                    yield f.diag(
                        node, self.name,
                        "stdlib 'random' draws from hidden global "
                        "state; use numpy.random.default_rng(seed)")
            elif isinstance(node, ast.Call):
                target = resolve_call(node, aliases)
                if target is None:
                    continue
                if target.startswith("numpy.random.") and \
                        target.rsplit(".", 1)[1] in LEGACY_NP_RANDOM:
                    yield f.diag(
                        node, self.name,
                        f"{target}() uses numpy's hidden global RNG "
                        f"state; thread a seeded "
                        f"numpy.random.Generator instead")


# -- ordered-iteration -------------------------------------------------------

def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


def _is_set_annotation(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Subscript):
        node = node.value
    dn = dotted_name(node)
    return dn is not None and dn.split(".")[-1] in ("Set", "FrozenSet",
                                                    "set", "frozenset")


def _appends_replay_log(fn: ast.AST) -> bool:
    """Does this function append to a replay log?  Direct forms only:
    ``<x>.events.append(...)``, ``push_event(...)``, ``<x>._emit(...)``
    (the fairness log wrapper)."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if isinstance(func, ast.Attribute):
            if func.attr in ("push_event", "_emit"):
                return True
            if func.attr == "append" and \
                    isinstance(func.value, ast.Attribute) and \
                    func.value.attr == "events":
                return True
        elif isinstance(func, ast.Name) and \
                func.id in ("push_event", "_emit"):
            return True
    return False


def _class_has_event_log(cls: ast.ClassDef) -> bool:
    """Does any method assign ``self.events``?"""
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Attribute) and t.attr == "events" \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self":
                    return True
    return False


@register
class OrderedIteration(Rule):
    name = "ordered-iteration"
    summary = ("set iteration and set-typed state are banned near "
               "replay logs (str hashing is per-process random); use "
               "an insertion-ordered dict or sorted() the drain")

    def check(self, f: SourceFile) -> Iterator[Diagnostic]:
        # (a) iterating a set inside a function that appends to a
        # replay log: the loop body's emission order leaks hash order
        for fn in walk_functions(f.tree):
            if not _appends_replay_log(fn):
                continue
            set_names = self._local_set_names(fn)
            for loop_iter in self._iteration_sites(fn):
                if self._is_unordered(loop_iter, set_names):
                    yield f.diag(
                        loop_iter, self.name,
                        "iterating a set inside a function that "
                        "appends to a replay event log: emission "
                        "order follows per-process hash order; drain "
                        "through sorted(...) or keep an "
                        "insertion-ordered dict")
        # (b) set-typed attribute state in a class that owns a replay
        # log: any future drain of that attribute is a replay hazard,
        # so the state itself is banned (Dict[key, None] is the
        # insertion-ordered replacement)
        for node in f.tree.body:
            if not isinstance(node, ast.ClassDef) or \
                    not _class_has_event_log(node):
                continue
            for sub in ast.walk(node):
                if isinstance(sub, ast.AnnAssign) and \
                        isinstance(sub.target, ast.Attribute) and \
                        isinstance(sub.target.value, ast.Name) and \
                        sub.target.value.id == "self" and \
                        (_is_set_annotation(sub.annotation)
                         or (sub.value is not None
                             and _is_set_expr(sub.value))):
                    yield self._state_diag(f, sub, sub.target.attr)
                elif isinstance(sub, ast.Assign) and sub.value is not None \
                        and _is_set_expr(sub.value):
                    for t in sub.targets:
                        if isinstance(t, ast.Attribute) and \
                                isinstance(t.value, ast.Name) and \
                                t.value.id == "self":
                            yield self._state_diag(f, sub, t.attr)

    def _state_diag(self, f: SourceFile, node: ast.AST,
                    attr: str) -> Diagnostic:
        return f.diag(
            node, self.name,
            f"self.{attr} is set-typed state in a class that owns a "
            f"replay event log; any drain replays in per-process hash "
            f"order — use an insertion-ordered Dict[key, None]")

    @staticmethod
    def _local_set_names(fn: ast.AST) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _is_set_expr(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
            elif isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name) and \
                    (_is_set_annotation(node.annotation)
                     or (node.value is not None
                         and _is_set_expr(node.value))):
                names.add(node.target.id)
        return names

    @staticmethod
    def _iteration_sites(fn: ast.AST) -> Iterator[ast.AST]:
        for node in ast.walk(fn):
            if isinstance(node, ast.For):
                yield node.iter
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.GeneratorExp, ast.DictComp)):
                for gen in node.generators:
                    yield gen.iter

    @staticmethod
    def _is_unordered(node: ast.AST, set_names: Set[str]) -> bool:
        if _is_set_expr(node):
            return True
        if isinstance(node, ast.Name) and node.id in set_names:
            return True
        return False


# -- timestamp-free-events ---------------------------------------------------

CLOCK_NAMES = {"now", "t0", "t1", "tnow", "wall", "clock"}
CLOCK_ATTRS = {"now", "_clock", "arrival", "t_first_token"}


@register
class TimestampFreeEvents(Rule):
    name = "timestamp-free-events"
    summary = ("tuples appended to replay event logs must not embed "
               "clock values (now, self._clock, time.*)")

    def check(self, f: SourceFile) -> Iterator[Diagnostic]:
        aliases = import_aliases(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if not (isinstance(func, ast.Attribute)
                    and func.attr == "append"
                    and isinstance(func.value, ast.Attribute)
                    and func.value.attr == "events"):
                continue
            for arg in node.args:
                leak = self._clock_leak(arg, aliases)
                if leak:
                    yield f.diag(
                        node, self.name,
                        f"event appended to a replay log embeds the "
                        f"clock value {leak!r}; logs must be "
                        f"timestamp-free so both environments replay "
                        f"byte-identically")

    @staticmethod
    def _clock_leak(arg: ast.AST,
                    aliases: Dict[str, str]) -> Optional[str]:
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Name) and sub.id in CLOCK_NAMES:
                return sub.id
            if isinstance(sub, ast.Attribute) and \
                    sub.attr in CLOCK_ATTRS:
                return dotted_name(sub) or sub.attr
            if isinstance(sub, ast.Call):
                target = resolve_call(sub, aliases)
                if target in WALL_CLOCK_CALLS:
                    return target
        return None


# -- hypothesis-via-shim -----------------------------------------------------

@register
class HypothesisViaShim(Rule):
    name = "hypothesis-via-shim"
    summary = ("tests import the offline seeded shim "
               "(tests/_hypothesis_compat), never hypothesis directly")

    def check(self, f: SourceFile) -> Iterator[Diagnostic]:
        if "tests" not in f.parts or \
                f.parts[-1] == "_hypothesis_compat.py":
            return
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "hypothesis" or \
                            a.name.startswith("hypothesis."):
                        yield self._diag(f, node)
            elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                    and node.module and \
                    (node.module == "hypothesis"
                     or node.module.startswith("hypothesis.")):
                yield self._diag(f, node)

    def _diag(self, f: SourceFile, node: ast.AST) -> Diagnostic:
        return f.diag(
            node, self.name,
            "import property-test helpers from _hypothesis_compat "
            "(offline seeded replay shim), not hypothesis directly — "
            "tier-1 must collect and pass without the package")


# -- cross-env-parity --------------------------------------------------------

# (simulator class, counterpart classes): every replay-relevant
# keyword-only knob on the simulator must exist on the counterpart —
# same name, a known alias, or a pragma naming why it is env-only
PARITY_PAIRS: List[Tuple[str, Tuple[str, ...]]] = [
    ("ServingSimulator", ("LiveEngine",)),
    ("FleetSimulator", ("LiveFleet",)),
]
# param-name aliases between the environments (the storage tier is the
# `store`/`cluster` positional in the live classes; the decode table is
# `decode_table` on the engine)
PARITY_ALIASES: Dict[str, Tuple[str, ...]] = {
    "storage": ("store", "cluster"),
    "table": ("decode_table",),
}


def _init_args(cls: ast.ClassDef) -> Optional[ast.arguments]:
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == "__init__":
            return node.args
    return None


def _all_param_names(args: ast.arguments) -> Set[str]:
    names = {a.arg for a in (args.posonlyargs + args.args
                             + args.kwonlyargs)}
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    names.discard("self")
    return names


@register
class CrossEnvParity(Rule):
    name = "cross-env-parity"
    summary = ("every keyword-only knob on a simulator __init__ needs "
               "a counterpart on its live-environment class (or a "
               "pragma naming why it is simulator-only)")

    def finalize(self, project: Project) -> Iterator[Diagnostic]:
        index: Dict[str, List[Tuple[SourceFile, ast.ClassDef]]] = {}
        for f, cls in project.classes():
            index.setdefault(cls.name, []).append((f, cls))
        for sim_name, live_names in PARITY_PAIRS:
            for f, sim_cls in index.get(sim_name, []):
                sim_args = _init_args(sim_cls)
                if sim_args is None:
                    continue
                for live_name in live_names:
                    for _, live_cls in index.get(live_name, []):
                        live_args = _init_args(live_cls)
                        if live_args is None:
                            continue
                        yield from self._compare(
                            f, sim_name, sim_args, live_name,
                            _all_param_names(live_args))

    def _compare(self, f: SourceFile, sim_name: str,
                 sim_args: ast.arguments, live_name: str,
                 live_params: Set[str]) -> Iterator[Diagnostic]:
        for a in sim_args.kwonlyargs:
            candidates = (a.arg,) + PARITY_ALIASES.get(a.arg, ())
            if any(c in live_params for c in candidates):
                continue
            yield f.diag(
                a, self.name,
                f"{sim_name} keyword {a.arg!r} has no counterpart on "
                f"{live_name}: a replay-relevant knob reachable in "
                f"only one environment lets the two drift apart "
                f"silently")
