"""repro-lint: AST-based determinism linter for the replay contract.

Every subsystem in this repo (WAN transport, storage churn, fairness,
fleet routing) stakes its correctness on one invariant: the simulator
and the live engine replay **byte-identical, timestamp-free event
logs** from seeded inputs.  This package makes that invariant a
build-time guarantee instead of a reviewer convention: a stdlib-only
static analyzer with a pluggable rule registry, stable-ordered
diagnostics, and inline suppression pragmas.

Run it over the tree::

    python -m tools.repro_lint src tests benchmarks tools

Suppress a justified violation on its line (or the line above)::

    t0 = time.time()  # repro-lint: allow(no-wall-clock) -- progress log

The rule catalogue, the contract it enforces, and how to add a rule
are documented in docs/determinism.md.
"""
from .engine import (Diagnostic, Project, Rule,  # noqa: F401
                     RULES, register, run_paths)
from . import rules  # noqa: F401  (importing registers the rule set)

__all__ = ["Diagnostic", "Project", "Rule", "RULES", "register",
           "run_paths"]
