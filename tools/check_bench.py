#!/usr/bin/env python
"""Benchmark perf-regression gate (ISSUE 4, bench-gate CI job).

Parses the ``name,us_per_call,derived`` CSV that ``python -m
benchmarks.run`` prints and compares every **gated** row — the derived
speedup/retention ratios, where higher is better — against the
committed ``benchmarks/baselines.json``.  A gated row regressing more
than the baseline file's tolerance (default 25%) fails the job, so the
storage/WAN/pipelining wins cannot rot unnoticed:

    PYTHONPATH=src python -m benchmarks.run --only ttft > ttft.csv
    python tools/check_bench.py ttft.csv

After an intentional perf change, refresh the baselines and commit:

    python tools/check_bench.py ttft.csv --update

Rules
-----
* gated rows are those whose name contains ``speedup`` or ``retained``
  (ratios where bigger is better; raw TTFT seconds are machine-speed
  dependent and are NOT gated — only ratios are stable across runners)
* a gated row in the CSV but not in the baselines fails, with one
  aggregated message naming every missing row and the exact --update
  command to refresh
* a malformed data row (has a comma but fewer than 3 columns) fails —
  silently skipping it would un-gate the ratio it carries
* a baseline row missing from the CSV fails (a silently dropped
  comparison is a regression of the gate itself)
* any ``<module>.FAILED`` row fails
* improvements pass; baselines are refreshed deliberately, not ratcheted
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

ROOT = pathlib.Path(__file__).resolve().parents[1]
DEFAULT_BASELINES = ROOT / "benchmarks" / "baselines.json"
GATE_MARKERS = ("speedup", "retained")
DEFAULT_TOLERANCE = 0.25


def parse_csv(path: pathlib.Path) -> Tuple[Dict[str, float], List[str]]:
    """-> ({row_name: derived}, [failed_module_rows])."""
    rows: Dict[str, float] = {}
    failed: List[str] = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#") or \
                line.startswith("name,us_per_call"):
            continue
        parts = line.split(",")
        if len(parts) == 1:
            continue  # prose/log line, not a data row
        if len(parts) < 3:
            # a comma means this was meant to be a data row; dropping it
            # silently would un-gate the ratio it carries
            failed.append(f"{parts[0]} (malformed row {line!r}: "
                          f"expected name,us_per_call,derived)")
            continue
        name = parts[0]
        if name.endswith(".FAILED"):
            failed.append(name)
            continue
        try:
            rows[name] = float(parts[2].split("#")[0])
        except ValueError:
            failed.append(f"{name} (unparseable derived {parts[2]!r})")
    return rows, failed


def gated(rows: Dict[str, float]) -> Dict[str, float]:
    return {k: v for k, v in rows.items()
            if any(m in k for m in GATE_MARKERS)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("csv", type=pathlib.Path,
                    help="CSV printed by `python -m benchmarks.run`")
    ap.add_argument("--baselines", type=pathlib.Path,
                    default=DEFAULT_BASELINES)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baselines from this CSV and exit")
    args = ap.parse_args(argv)

    rows, failed = parse_csv(args.csv)
    gate = gated(rows)
    if args.update:
        if failed:
            print(f"refusing to --update from a CSV with failures: "
                  f"{failed}", file=sys.stderr)
            return 1
        args.baselines.write_text(json.dumps(
            {"tolerance": DEFAULT_TOLERANCE,
             "rows": dict(sorted(gate.items()))}, indent=2) + "\n")
        print(f"wrote {len(gate)} baseline row(s) -> {args.baselines}")
        return 0

    base = json.loads(args.baselines.read_text())
    tol = float(base.get("tolerance", DEFAULT_TOLERANCE))
    baseline_rows: Dict[str, float] = base["rows"]
    problems: List[str] = [f"bench module failed: {f}" for f in failed]
    for name, want in sorted(baseline_rows.items()):
        got = gate.get(name)
        if got is None:
            problems.append(f"{name}: baseline row missing from CSV")
            continue
        floor = want * (1.0 - tol)
        verdict = "ok" if got >= floor else "REGRESSED"
        print(f"[{verdict}] {name}: {got:.4g} "
              f"(baseline {want:.4g}, floor {floor:.4g})")
        if got < floor:
            problems.append(
                f"{name}: {got:.4g} < {floor:.4g} "
                f"(baseline {want:.4g} - {tol:.0%})")
    missing = sorted(set(gate) - set(baseline_rows))
    if missing:
        names = ", ".join(missing)
        problems.append(
            f"{len(missing)} gated row(s) have no baseline: {names}\n"
            f"    -> refresh with: python tools/check_bench.py "
            f"{args.csv} --update  (then commit "
            f"{args.baselines.name})")
    if problems:
        print(f"\n{len(problems)} bench-gate failure(s):",
              file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(f"\nall {len(baseline_rows)} gated ratio(s) within "
          f"{tol:.0%} of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
