"""Workload generation: Poisson request traces with long-context prompts
and a reuse threshold (paper §5.2: rate 0.2 req/s, >=40K-token prompts
reuse remote KV), plus shared-prefix corpora for the live engine."""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.scheduler import Request


def poisson_trace(rng: np.random.Generator, *, n_requests: int = 20,
                  rate: float = 0.2,
                  prompt_lens: Sequence[int] = (20_000, 200_000),
                  reuse_threshold: int = 40_000,
                  suffix_tokens: int = 1_000,
                  max_new_tokens: int = 32) -> List[Request]:
    t = 0.0
    out: List[Request] = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        reuse = plen - suffix_tokens if plen >= reuse_threshold else 0
        out.append(Request(rid=rid, arrival=t, prompt_len=plen,
                           reuse_tokens=max(reuse, 0),
                           prefix=f"pfx{rid}" if reuse else None,
                           max_new_tokens=max_new_tokens))
    return out


def fixed_context_trace(context_len: int, *, n_requests: int = 4,
                        gap: float = 30.0, suffix_tokens: int = 1_000,
                        max_new_tokens: int = 32) -> List[Request]:
    """Back-to-back fetching requests of one context length (Fig. 18/21)."""
    return [Request(rid=i, arrival=i * gap, prompt_len=context_len,
                    reuse_tokens=context_len - suffix_tokens,
                    prefix=f"pfx{i}", max_new_tokens=max_new_tokens)
            for i in range(n_requests)]


def shared_prefix_tokens(rng: np.random.Generator, vocab: int,
                         prefix_len: int, n_requests: int,
                         suffix_len: int) -> tuple:
    """(prefix, [full_prompt_i]) token arrays for the live engine."""
    prefix = rng.integers(0, vocab, prefix_len)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, vocab, suffix_len)])
               for _ in range(n_requests)]
    return prefix, prompts
