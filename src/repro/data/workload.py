"""Workload generation: Poisson request traces with long-context prompts
and a reuse threshold (paper §5.2: rate 0.2 req/s, >=40K-token prompts
reuse remote KV), shared-prefix corpora for the live engine, the
Zipf-over-a-prefix-trie popularity workload the storage-tier benchmarks
drive, and seeded node-churn schedules for the failover scenarios
(docs/storage_tier.md)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.scheduler import Request


def poisson_trace(rng: np.random.Generator, *, n_requests: int = 20,
                  rate: float = 0.2,
                  prompt_lens: Sequence[int] = (20_000, 200_000),
                  reuse_threshold: int = 40_000,
                  suffix_tokens: int = 1_000,
                  max_new_tokens: int = 32) -> List[Request]:
    t = 0.0
    out: List[Request] = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        reuse = plen - suffix_tokens if plen >= reuse_threshold else 0
        out.append(Request(rid=rid, arrival=t, prompt_len=plen,
                           reuse_tokens=max(reuse, 0),
                           prefix=f"pfx{rid}" if reuse else None,
                           max_new_tokens=max_new_tokens))
    return out


def fixed_context_trace(context_len: int, *, n_requests: int = 4,
                        gap: float = 30.0, suffix_tokens: int = 1_000,
                        max_new_tokens: int = 32) -> List[Request]:
    """Back-to-back fetching requests of one context length (Fig. 18/21)."""
    return [Request(rid=i, arrival=i * gap, prompt_len=context_len,
                    reuse_tokens=context_len - suffix_tokens,
                    prefix=f"pfx{i}", max_new_tokens=max_new_tokens)
            for i in range(n_requests)]


def wan_burst_trace(rng: np.random.Generator, context_len: int, *,
                    n_requests: int = 4, window: float = 2.0,
                    suffix_tokens: int = 1_000,
                    weights: Optional[Sequence[float]] = None,
                    max_new_tokens: int = 32) -> List[Request]:
    """A burst of fetching requests whose arrivals land (seeded-uniform,
    sorted) inside one short ``window`` — the adaptive-transport stress
    shape: flows join a contended link at staggered instants, so fair
    shares (and, with ``ramp="slowstart"``, ramp factors) shift while
    chunks are mid-flight.  Optional per-request link ``weights`` drive
    weighted-fair / DRR arbitration.  Deterministic for a given rng."""
    arrivals = np.sort(rng.uniform(0.0, window, n_requests))
    return [Request(rid=i, arrival=float(arrivals[i]),
                    prompt_len=context_len,
                    reuse_tokens=context_len - suffix_tokens,
                    prefix=f"pfx{i}", max_new_tokens=max_new_tokens,
                    weight=(float(weights[i]) if weights is not None
                            else 1.0))
            for i in range(n_requests)]


@dataclasses.dataclass(frozen=True)
class PrefixSpec:
    """One node of the reusable-prefix trie: a registered prefix of
    ``n_tokens`` tokens whose longest registered ancestor is ``parent``
    (None for roots).  Children extend their parent's token sequence, so
    a stored parent is a valid *partial* hit for a child's ask."""
    key: str
    n_tokens: int
    parent: Optional[str] = None


def prefix_trie_specs(n_roots: int, depth: int, *,
                      base_tokens: int = 40_000,
                      ext_tokens: int = 20_000) -> List[PrefixSpec]:
    """A forest of prefix chains: ``n_roots`` roots of ``base_tokens``
    tokens, each extended ``depth - 1`` times by ``ext_tokens`` (root ->
    child -> grandchild ...).  Keys are deterministic (``trie.r2.d1``) so
    seeded workloads replay identically everywhere."""
    specs: List[PrefixSpec] = []
    for r in range(n_roots):
        parent = None
        for d in range(depth):
            key = f"trie.r{r}.d{d}"
            specs.append(PrefixSpec(key=key,
                                    n_tokens=base_tokens + d * ext_tokens,
                                    parent=parent))
            parent = key
    return specs


def zipf_prefix_trace(rng: np.random.Generator,
                      specs: Sequence[PrefixSpec], *,
                      n_requests: int = 24, alpha: float = 1.1,
                      gap: float = 30.0, suffix_tokens: int = 1_000,
                      max_new_tokens: int = 32) -> List[Request]:
    """Requests whose prefix popularity follows a Zipf law over the trie:
    spec ``i`` (0-based) is drawn with probability proportional to
    ``(i + 1) ** -alpha``.  Each request asks to reuse its spec's full
    prefix; whether that resolves to a full hit, a partial (ancestor)
    hit, or a miss is the storage tier's call at fetch-dispatch time."""
    ranks = np.arange(1, len(specs) + 1, dtype=np.float64)
    p = ranks ** -alpha
    p /= p.sum()
    out: List[Request] = []
    for rid in range(n_requests):
        spec = specs[int(rng.choice(len(specs), p=p))]
        out.append(Request(rid=rid, arrival=rid * gap,
                           prompt_len=spec.n_tokens + suffix_tokens,
                           reuse_tokens=spec.n_tokens, prefix=spec.key,
                           max_new_tokens=max_new_tokens))
    return out


def session_trace(rng: np.random.Generator,
                  specs: Sequence[PrefixSpec], *,
                  n_sessions: int = 4, continue_p: float = 0.9,
                  session_gap: float = 60.0, think_time: float = 120.0,
                  suffix_tokens: int = 1_000,
                  max_new_tokens: int = 32) -> List[Request]:
    """Session-continuation requests over the prefix trie: each session
    opens at a (uniformly drawn) trie root and, with probability
    ``continue_p`` per turn, comes back after ``think_time`` seconds
    asking for a *child* of the prefix it just reused — the multi-turn
    shape whose next ask extends the previous one, which is exactly the
    signal the prefetch predictor's session-continuation term exploits
    (a hit on P heats P's children; docs/prefetch.md).  Sessions open
    ``session_gap`` apart in expectation.  Deterministic for a given
    rng; requests are returned in arrival order with dense rids."""
    children: dict = {}
    for s in specs:
        children.setdefault(s.parent, []).append(s)
    roots = children.get(None, [])
    assert roots, "specs contain no trie roots"
    raw: List[tuple] = []
    t = 0.0
    for _ in range(n_sessions):
        t += rng.exponential(session_gap)
        spec, ta = roots[int(rng.integers(len(roots)))], t
        while True:
            raw.append((ta, spec))
            kids = children.get(spec.key, [])
            if not kids or rng.random() >= continue_p:
                break
            spec = kids[int(rng.integers(len(kids)))]
            ta += rng.exponential(think_time)
    raw.sort(key=lambda p: p[0])
    return [Request(rid=rid, arrival=ta,
                    prompt_len=spec.n_tokens + suffix_tokens,
                    reuse_tokens=spec.n_tokens, prefix=spec.key,
                    max_new_tokens=max_new_tokens)
            for rid, (ta, spec) in enumerate(raw)]


def zipf_user_population(rng: np.random.Generator,
                         specs: Sequence[PrefixSpec], *,
                         n_users: int = 12, n_requests: int = 36,
                         alpha: float = 1.2,
                         tiers: Sequence[str] = ("premium", "standard",
                                                 "free"),
                         n_abusers: int = 1, abuse_burst: int = 8,
                         abuse_at: Optional[int] = None,
                         gap: float = 8.0, suffix_tokens: int = 1_000,
                         max_new_tokens: int = 8) -> List[Request]:
    """Multi-tenant request trace: a Zipf user population with scripted
    abusive tenants (the FairServe experiment shape, SNIPPETS.md #2).

    ``n_users`` well-behaved users ``user000..`` send ``n_requests``
    background requests whose per-user traffic follows a Zipf law over
    user rank (rank ``i`` drawn with probability ``(i+1) ** -alpha``;
    ``user000`` is the heaviest) with seeded-exponential inter-arrival
    ``gap``; each request reuses a seeded-uniform prefix from ``specs``.
    SLO tiers stripe by rank (``tiers[rank % len(tiers)]``).

    ``n_abusers`` scripted abusive tenants ``abuser00..`` — always the
    *lowest* tier (``tiers[-1]``) — each inject a flood of
    ``abuse_burst`` back-to-back requests, all at the arrival instant
    of background request index ``abuse_at`` (default
    ``n_requests // 3``) and all hammering the hottest prefix
    ``specs[0]``: the starvation shape the fairness bench and the
    cross-env replay test drive (docs/fairness.md).

    Deterministic for a given rng: identical seeds replay identical
    traces everywhere.  Requests come back in arrival order (the flood
    sits contiguously right after its trigger request) with dense rids
    and ``user``/``slo_tier`` stamped."""
    assert specs and n_users >= 1 and tiers
    users = [f"user{i:03d}" for i in range(n_users)]
    tier_of = {u: tiers[i % len(tiers)] for i, u in enumerate(users)}
    ranks = np.arange(1, n_users + 1, dtype=np.float64)
    p = ranks ** -alpha
    p /= p.sum()
    raw: List[tuple] = []
    t = 0.0
    for _ in range(n_requests):
        t += rng.exponential(gap)
        u = users[int(rng.choice(n_users, p=p))]
        spec = specs[int(rng.integers(len(specs)))]
        raw.append((t, u, tier_of[u], spec))
    cut = min(abuse_at if abuse_at is not None else n_requests // 3,
              len(raw) - 1)
    t_flood = raw[cut][0]
    flood = [(t_flood, f"abuser{a:02d}", tiers[-1], specs[0])
             for a in range(n_abusers) for _ in range(abuse_burst)]
    raw = raw[:cut + 1] + flood + raw[cut + 1:]
    return [Request(rid=rid, arrival=ta,
                    prompt_len=spec.n_tokens + suffix_tokens,
                    reuse_tokens=spec.n_tokens, prefix=spec.key,
                    max_new_tokens=max_new_tokens,
                    user=u, slo_tier=tier)
            for rid, (ta, u, tier, spec) in enumerate(raw)]


def churn_schedule(rng: np.random.Generator,
                   node_ids: Sequence[str], *,
                   n_failures: int = 1, t_start: float = 100.0,
                   gap: float = 400.0, downtime: Optional[float] = 200.0
                   ) -> tuple:
    """Seeded storage-node churn: ``n_failures`` fail events starting at
    ``t_start`` spaced ``gap`` seconds apart, each node drawn uniformly
    (never failing a node that is still down).  Returns ``(fail_at,
    recover_at)`` lists shaped for ``ServingSimulator(fail_at=...,
    recover_at=...)``; ``downtime=None`` means nodes never recover.
    Deterministic for a given rng seed, so simulator and live engine
    can replay the identical churn trace."""
    fail_at: List[tuple] = []
    recover_at: List[tuple] = []
    down_until: dict = {}
    t = t_start
    for _ in range(n_failures):
        up = [n for n in node_ids if down_until.get(n, -1.0) < t]
        if len(up) <= 1:
            break  # never fail the last alive node (the cluster —
            # and StorageCluster.fail_node — require one survivor)
        nid = up[int(rng.integers(len(up)))]
        fail_at.append((t, nid))
        if downtime is not None:
            recover_at.append((t + downtime, nid))
            down_until[nid] = t + downtime
        else:
            down_until[nid] = float("inf")
        t += gap
    return fail_at, recover_at


def shared_prefix_tokens(rng: np.random.Generator, vocab: int,
                         prefix_len: int, n_requests: int,
                         suffix_len: int) -> tuple:
    """(prefix, [full_prompt_i]) token arrays for the live engine."""
    prefix = rng.integers(0, vocab, prefix_len)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, vocab, suffix_len)])
               for _ in range(n_requests)]
    return prefix, prompts
