"""Synthetic data pipeline: deterministic, seedable batch streams for every
architecture family (decoder LM, VLM, audio encoder) with next-token
labels, plus markovian token streams so KV caches exhibit the
token-adjacent structure the codec exploits."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0


def _zipf_tokens(rng, vocab: int, shape) -> np.ndarray:
    """Zipf-ish marginal with markov repetition (natural-text-like)."""
    base = rng.zipf(1.3, size=shape)
    toks = np.minimum(base - 1, vocab - 1).astype(np.int32)
    rep = rng.random(shape) < 0.2
    out = toks.copy()
    out[..., 1:] = np.where(rep[..., 1:], out[..., :-1], toks[..., 1:])
    return out


def batches(cfg: ModelConfig, dcfg: DataConfig
            ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(dcfg.seed)
    b, s = dcfg.batch_size, dcfg.seq_len
    while True:
        if cfg.is_encoder:  # audio: frame embeddings + unit labels + mask
            yield {
                "frame_embeds": rng.standard_normal(
                    (b, s, cfg.d_model)).astype(np.float32) * 0.02,
                "labels": rng.integers(0, cfg.vocab_size,
                                       (b, s)).astype(np.int32),
                "mask": (rng.random((b, s)) < 0.2),
            }
        elif cfg.frontend == "vision":
            n_text = max(s - cfg.num_patch_tokens, 8)
            toks = _zipf_tokens(rng, cfg.vocab_size, (b, n_text))
            yield {
                "tokens": toks,
                "labels": toks,
                "patch_embeds": rng.standard_normal(
                    (b, cfg.num_patch_tokens, cfg.d_model)
                ).astype(np.float32) * 0.02,
            }
        else:
            toks = _zipf_tokens(rng, cfg.vocab_size, (b, s))
            yield {"tokens": toks, "labels": toks}
