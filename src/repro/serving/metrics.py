"""Serving metrics: TTFT / TPOT aggregation over finished requests."""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.scheduler import Request


def summarize(requests: Iterable[Request]) -> Dict[str, float]:
    reqs = [r for r in requests if r.t_first_token is not None]
    ttfts = np.array([r.ttft for r in reqs], np.float64)
    tpots = np.array([r.tpot for r in reqs if r.tpot is not None],
                     np.float64)
    out: Dict[str, float] = {"n": float(len(reqs))}
    if ttfts.size:
        out.update(ttft_mean=float(ttfts.mean()),
                   ttft_p50=float(np.percentile(ttfts, 50)),
                   ttft_p99=float(np.percentile(ttfts, 99)),
                   ttft_max=float(ttfts.max()))
    if tpots.size:
        out.update(tpot_mean=float(tpots.mean()),
                   tpot_p99=float(np.percentile(tpots, 99)))
    return out


def split_summary(requests: Iterable[Request]) -> Dict[str, Dict[str, float]]:
    reqs = list(requests)
    return {
        "all": summarize(reqs),
        "fetching": summarize([r for r in reqs if r.needs_fetch]),
        "non_reuse": summarize([r for r in reqs if not r.needs_fetch]),
    }
