"""Live serving engine: real compute, real codec, real paged memory.

This is the integration proof of the full KVFetcher path on actual small
models: fetching-aware scheduling, background fetch with frame-wise
restoration into paged memory via the Pallas kernel, suffix prefill over
restored prefix KV, and continuously-batched paged decode.

Fetching runs through the event-driven `repro.core.fetch_controller` —
the same transmit -> decode -> restore pipeline state machine the
cluster simulator uses.  Two operating modes:

  * wall clock (default, ``bandwidth=None``): fetches complete
    synchronously at dispatch, timestamps are ``time.monotonic()`` — the
    original engine behaviour, kept for integration tests.
  * virtual clock (``bandwidth=`` a BandwidthTrace): network transmit
    and decode latencies are modeled on a virtual clock while the codec
    and paged-memory mechanics stay real.  ``fetch_mode="async"`` pumps
    the controller from ``step()`` so restoration overlaps compute and a
    request can start suffix prefill while later layer groups are still
    in flight (Appx A.3 early admission); ``fetch_mode="sync"`` drains
    the pipeline serially at dispatch — the pre-pipelining baseline.

In virtual-clock mode the network is a WAN-grade model: concurrent
fetches split the trace via `repro.cluster.network.SharedLink` (weighted
``fair`` fluid sharing or ``drr`` chunk round-robin, ``link_policy=``;
``link_ramp="slowstart"`` shapes joins like a congestion window) and a
seeded ``loss=`` `LossModel` (including cross-flow correlated bursts)
drops chunk attempts which the controller retransmits under a per-flow
Jacobson/Karels adaptive timeout (``rto_mode=``) — restoration stays
bit-exact, only timing moves.

The ``store`` may be a flat `KVStore` or a multi-node `StorageCluster`
(docs/storage_tier.md): with a cluster, every fetch resolves through a
longest-prefix-match over the prompt tokens — full hit, partial
(ancestor) hit with tail recompute, or miss with full-prefill fallback —
and transmits over the serving node's own link.  The cluster is
fault-tolerant: ``engine.fail_node(node_id)`` kills a node mid-serve
(keys re-route to ring successors, heals restore replication), TTLs
expire stale copies lazily at lookup, and the delayed write-on-miss
re-admits a missed prefix only once its fallback prefill produced the
first token (`notify_recompute_done`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.adaptive import DecodeTable
from repro.core.chunks import KVManifest
from repro.core.codec import KVCodec
from repro.core.fetch import (FetchPlan, PlannedChunk, build_plan,
                              sharded_layers_ready, split_plan_shards)
from repro.core.fetch_controller import (ActiveFetch, FetchController,
                                         FetchHooks, PipelineConfig)
from repro.core.layout import IntraLayout
from repro.core.scheduler import FetchingAwareScheduler, ReqState, Request
from repro.cluster.costmodel import CHIPS, EngineCostModel
from repro.cluster.decodepool import DecodePool
from repro.cluster.network import LossModel, make_link
from repro.cluster.storage import StorageCluster
from repro.models.attention import attend
from repro.models.common import rms_norm
from repro.models.transformer import lm_logits
from repro.paged.cache import PagedKVCache
from repro.serving import paged_model


# Shadow rids for mesh-sharded fetches live far above any real rid so
# the per-shard controller flows can never collide with request flows.
_SHADOW_RID_BASE = 10_000_000


@dataclasses.dataclass
class EngineStats:
    restore_buffer_high_water: int = 0
    restored_tokens: int = 0
    fetched_bytes: int = 0
    steps: int = 0
    prefill_stall_time: float = 0.0  # virtual time spent waiting for KV


class _EngineHooks(FetchHooks):
    """Real codec restoration driven by the controller's restore events."""

    def __init__(self, engine: "LiveEngine"):
        self.engine = engine

    def restore_seconds(self, fetch: ActiveFetch, pc: PlannedChunk) -> float:
        return 0.002  # frame-wise restoration cost (matches the simulator)

    def on_restored(self, fetch: ActiveFetch, pc: PlannedChunk,
                    now: float) -> None:
        self.engine._restore_chunk(fetch.req, fetch.plan, pc)

    def comp_times(self, req: Request):
        eng = self.engine
        if eng.cost is None:
            return None
        suffix = max(req.prompt_len - req.reuse_tokens, 1)
        return eng.cost.layer_comp_times(suffix)


class LiveEngine:
    """Single-node engine over a reduced dense model (real compute)."""

    # ``store`` is a flat KVStore (single implicit node, unbounded) or a
    # multi-node StorageCluster (capacity-bounded eviction, placement,
    # longest-prefix-match partial hits — see docs/storage_tier.md).
    def __init__(self, params, cfg: ModelConfig, store, *,
                 n_pages: int = 256, page_size: int = 16,
                 policy: str = "kvfetcher", max_running: int = 4,
                 resolution: str = "240p",
                 fetch_mode: str = "sync",
                 bandwidth=None,
                 loss: Optional[LossModel] = None,
                 link_policy: Optional[str] = None,  # None -> "fair"
                 link_ramp: Optional[str] = None,  # None -> "instant"
                 rto_mode: str = "adaptive",  # or "fixed" (baseline)
                 use_table_sizes: bool = False,  # model Appx A.2 sizes
                 # ABR selection: None keeps the legacy rule (adaptive
                 # iff a decode table is given); False pins
                 # ``resolution`` even with a table (the fixed-res
                 # baseline the ttft.abr.* rows compare against)
                 adaptive: Optional[bool] = None,
                 # ladder the selector may pick from (None = the full
                 # RESOLUTION_ORDER; narrow it to the registered
                 # manifest ladder for cross-env determinism tests)
                 resolutions: Optional[Tuple[str, ...]] = None,
                 decode_table: Optional[DecodeTable] = None,
                 cost: Optional[EngineCostModel] = None,
                 # speculative prefetch + host staging tier: a
                 # repro.cluster.staging.PrefetchManager over `store`
                 prefetch=None,
                 # user-level fair scheduling: a
                 # repro.cluster.fairness.FairScheduler shared with the
                 # FetchingAwareScheduler (docs/fairness.md); submit()
                 # carries user=/slo_tier= per request
                 fairness=None,
                 # fleet mode (docs/fleet.md): the fleet harness drains
                 # the shared fair backlog centrally and hands ready
                 # fetches to dispatch_fetch(); step() must not race it
                 external_dispatch: bool = False,
                 # streaming client view: called as on_token(req, token,
                 # t) the moment each token exists — first token inside
                 # prefill, then once per decode step.  ``t`` is the
                 # engine clock (virtual under a bandwidth trace), so a
                 # client callback sees the same TTFT/inter-token gaps
                 # the metrics report
                 on_token: Optional[Callable[[Request, int, float],
                                             None]] = None,
                 # shard the paged cache over a jax device mesh
                 # (launch/mesh.py) and run per-shard fetch/decode/
                 # restore plans as independent flows through the one
                 # controller; mesh_shards= overrides the shard count
                 # (e.g. model-parallel degree to emulate on a small
                 # debug mesh)
                 mesh=None, mesh_shards: Optional[int] = None):
        assert fetch_mode in ("sync", "async")
        self.params = params
        self.cfg = cfg
        self.store = store
        self.prefetch = prefetch
        if prefetch is not None:
            assert isinstance(store, StorageCluster), \
                "prefetch= needs a multi-node StorageCluster store"
        self.cache = PagedKVCache(cfg, n_pages, page_size)
        self.external_dispatch = external_dispatch
        # mesh sharding: page arrays live distributed over the mesh's
        # "model" axis (kv heads); fetch plans split into per-shard
        # subplans so each shard restores its slice as its own flow
        self.n_shards = 1
        if mesh is not None or mesh_shards is not None:
            self.n_shards = int(mesh_shards) if mesh_shards is not None \
                else dict(mesh.shape).get("model", 1)
            assert self.n_shards >= 1
            if mesh is not None:
                self._shard_cache(mesh)
        #: rid -> (req, shard subplans) for fetches in sharded flight
        self._sharded: Dict[int, Tuple[Request, List[FetchPlan]]] = {}
        #: shadow rid -> real request (restore callbacks remap through it)
        self._shadow_real: Dict[int, Request] = {}
        self.fairness = fairness
        self.sched = FetchingAwareScheduler(policy, max_running=max_running,
                                            fairness=fairness)
        self.resolution = resolution
        self.fetch_mode = fetch_mode
        self.stats = EngineStats()
        self.prompts: Dict[int, np.ndarray] = {}
        self.outputs: Dict[int, List[int]] = {}
        self.finished: List[Request] = []
        self._clock = 0.0
        self.virtual = bandwidth is not None
        assert self.virtual or (fetch_mode == "sync" and loss is None
                                and link_policy is None
                                and link_ramp is None), \
            "WAN options (async fetch, loss=, link_policy=, link_ramp=) " \
            "need a bandwidth trace (virtual clock)"
        self.on_token = on_token
        self.cost = cost
        self.ctrl: Optional[FetchController] = None
        if isinstance(store, StorageCluster) and (loss is not None
                                                  or link_policy is not None
                                                  or link_ramp is not None):
            assert all(n.link is None for n in store.nodes), \
                "loss=/link_policy=/link_ramp= only shape the default " \
                "link; nodes with their own links must carry their own " \
                "LossModel/policy/ramp: StorageNode(link=make_link(" \
                "trace, policy=, loss=, ramp=))"
        if self.virtual:
            if self.cost is None:
                self.cost = EngineCostModel(cfg, CHIPS["h20"], 1)
            pool = DecodePool(decode_table) if decode_table else None
            # concurrent fetches contend for one WAN link (fair or DRR
            # split, optionally slow-start ramped) and survive seeded
            # chunk loss via adaptive-RTO retransmission — the same link
            # model the simulator pumps
            link = make_link(bandwidth, policy=link_policy, loss=loss,
                             ramp=link_ramp)
            pipe_kw = {}
            if resolutions is not None:
                pipe_kw["resolutions"] = tuple(resolutions)
            self.ctrl = FetchController(
                self.sched, link, table=decode_table, pool=pool,
                config=PipelineConfig(
                    adaptive=(decode_table is not None if adaptive is None
                              else adaptive),
                    fixed_resolution=resolution,
                    pipelined=fetch_mode == "async",
                    layerwise_admission=(fetch_mode == "async"
                                         and policy == "kvfetcher"),
                    use_table_sizes=use_table_sizes,
                    rto_mode=rto_mode, **pipe_kw),
                hooks=_EngineHooks(self), prefetcher=prefetch)
            if isinstance(store, StorageCluster):
                # heal="link" re-replication transfers share the
                # controller's virtual clock + the nodes' links
                store.bind(self.ctrl.push_event)
                self.ctrl.rtt_sink = store.observe_rtt
                # per-resolution usage feedback for rung-level eviction
                self.ctrl.res_sink = store.note_resolution_use
            if prefetch is not None:
                prefetch.bind(self.ctrl.push_event)
        elif prefetch is not None:
            # wall clock has no event queue to stream speculation on
            assert prefetch.transport == "sync", \
                "wall-clock engines need PrefetchManager(transport='sync')"

    # -- time: virtual clock in modeled-network mode, else wall clock -------
    def now(self) -> float:
        # wall-clock mode is the integration-test default (fetches
        # complete synchronously at dispatch); every replayed event log
        # comes from virtual-clock mode, where this branch never runs
        return self._clock if self.virtual \
            else time.monotonic()  # repro-lint: allow(no-wall-clock)

    # -- mesh-sharded paged cache --------------------------------------------
    def _shard_cache(self, mesh) -> None:
        """Lay the paged KV arrays out over ``mesh``: kv heads shard on
        the "model" axis (DEFAULT_RULES), everything else replicates.
        Non-divisible dims fall back to replication, so tiny debug
        models on 1-device meshes stay valid."""
        from repro.sharding import rules
        with rules.activate(mesh):
            ns = rules.named_sharding(
                ("layers", None, None, "kv_heads", None),
                self.cache.k_pages.shape, mesh=mesh)
        self.cache.k_pages = jax.device_put(self.cache.k_pages, ns)
        self.cache.v_pages = jax.device_put(self.cache.v_pages, ns)

    # -- storage-node churn ---------------------------------------------------
    def fail_node(self, node_id: str) -> None:
        """Kill one storage node at the engine's current clock: its keys
        re-route to ring successors and the cluster's heal queue
        restores the replication factor (`docs/storage_tier.md`).
        Subsequent lookups for prefixes it alone held miss and fall back
        to full prefill until healed."""
        assert isinstance(self.store, StorageCluster), \
            "fail_node needs a multi-node StorageCluster store"
        self.store.fail_node(node_id, self.now())

    def recover_node(self, node_id: str) -> None:
        assert isinstance(self.store, StorageCluster)
        self.store.recover_node(node_id, self.now())

    # -- intake -------------------------------------------------------------
    def submit(self, tokens: np.ndarray, reuse_prefix: Optional[str] = None,
               reuse_tokens: int = 0, max_new_tokens: int = 8,
               user: Optional[str] = None,
               slo_tier: Optional[str] = None,
               rid: Optional[int] = None) -> Request:
        # fleet harnesses pass fleet-global rids so one placement log
        # covers every engine; standalone use keeps the local counter
        rid = len(self.prompts) if rid is None else int(rid)
        assert rid not in self.prompts, f"rid {rid} already submitted"
        req = Request(rid=rid, arrival=self.now(), prompt_len=len(tokens),
                      max_new_tokens=max_new_tokens,
                      reuse_tokens=reuse_tokens, prefix=reuse_prefix,
                      user=user, slo_tier=slo_tier)
        self.prompts[rid] = np.asarray(tokens)
        self.outputs[rid] = []
        self.sched.submit(req, req.arrival)
        return req

    # -- fetch dispatch -------------------------------------------------------
    def dispatch_fetch(self, req: Request) -> None:
        """External-dispatch entry point: the fleet harness drained the
        shared fair backlog and placed ``req`` here — start its fetch
        and re-run admission, exactly what step() does internally when
        it owns dispatch."""
        self._start_fetch(req)
        self.sched.schedule(self.now())

    def local_restore(self, req: Request) -> None:
        """Serve ``req`` from this serving node's own resident KV: a
        real restore from the cataloged manifest with ZERO virtual
        network time (the bytes never cross the wire — the affinity
        router already put the request where its prefix lives).
        Fairness sees the same 0-byte "fetched" event the simulator
        logs for a local hit."""
        assert isinstance(self.store, StorageCluster) and req.prefix
        entry = self.store.catalog[req.prefix]
        plan = build_plan(req.rid, entry.manifest)
        self.cache.add_seq(req.rid, req.prompt_len + req.max_new_tokens)
        self._run_fetch_wall(req, plan)

    def _start_fetch(self, req: Request) -> None:
        """Resolve the request's prefix against the store and start the
        fetch.  Against a multi-node `StorageCluster` the resolution is a
        longest-prefix-match over the prompt tokens: a **full** hit
        fetches the whole ask, a **partial** hit fetches the resident
        *ancestor* manifest (the tail becomes extra suffix prefill — same
        tokens, just more compute), and a **miss** falls back to a plain
        full prefill; fetches route over the serving node's own link."""
        link = None
        res_avail = None
        served_key = None
        if isinstance(self.store, StorageCluster):
            tokens = self.prompts[req.rid][:req.reuse_tokens]
            staged = (self.prefetch.host_lookup_tokens(tokens, self.now())
                      if self.prefetch is not None else None)
            if staged is not None:
                # host-first: the speculatively staged copy serves from
                # host DRAM over the staging tier's h2d link — the WAN
                # is off this request's TTFT path entirely
                req.storage_hit = "host"
                req.storage_node = "host"
                req.prefix = staged.key
                self.prefetch.observe(staged.key, self.now())
                man = staged.manifest
                link = self.prefetch.staging.link
            else:
                hit = self.store.lookup_tokens(tokens, self.now())
                if self.prefetch is not None:
                    self.prefetch.observe(
                        hit.entry.key if hit.entry is not None
                        else hit.missed_key, self.now())
                req.storage_hit = hit.kind
                if hit.kind == "miss":
                    req.storage_miss_key = hit.missed_key
                    self.sched.notify_fetch_miss(req, self.now())
                    return
                req.storage_node = hit.node.node_id
                if hit.kind == "partial":
                    req.requested_reuse_tokens = req.reuse_tokens
                    req.reuse_tokens = hit.covered_tokens
                    req.prefix = hit.entry.key  # fetch the ancestor
                man = hit.entry.manifest
                link = hit.node.link
                res_avail = hit.resolutions
                served_key = hit.entry.key
        else:
            man = self.store.lookup(req.prefix)
        assert man is not None, f"prefix {req.prefix} not registered"
        plan = build_plan(req.rid, man)
        self.cache.add_seq(req.rid, req.prompt_len + req.max_new_tokens)
        if self.ctrl is None:
            self._run_fetch_wall(req, plan)
            return
        if self.n_shards > 1:
            self._start_sharded(req, plan, link=link,
                                resolutions=res_avail,
                                served_key=served_key)
            return
        self.ctrl.start(req, plan, self.now(), link=link,
                        resolutions=res_avail, served_key=served_key)
        if self.fetch_mode == "sync":
            # blocking baseline: the engine idles until the (serialized)
            # pipeline finishes; the virtual clock absorbs the whole fetch
            self._clock = max(self._clock, self.ctrl.drain(plan))

    # -- mesh-sharded fetch: per-shard plans as independent flows -------------
    def _start_sharded(self, req: Request, plan: FetchPlan, *,
                       link=None, resolutions=None,
                       served_key=None) -> None:
        """Split the plan by layer-group shard and run every shard's
        fetch/decode/restore stream as its own flow through the ONE
        controller event loop: shards contend on the link like the real
        per-device DMA streams would, and the request is admitted when
        `sharded_layers_ready` over the subplans says its contiguous
        layer prefix landed.  Each shard fetches under a *shadow* of
        the request (fresh rid, state=WAITING) so the controller's
        per-shard completion bookkeeping — fairness charge, scheduler
        notify, early admission — all no-op; the REAL request completes
        exactly once, in `_check_sharded`, when the last shard drains."""
        subplans = split_plan_shards(plan, self.n_shards)
        self._sharded[req.rid] = (req, subplans)
        req.fetch_started = self.now()
        for s, sp in enumerate(subplans):
            shadow = dataclasses.replace(
                req, rid=_SHADOW_RID_BASE + req.rid * 64 + s,
                token_times=[])
            # replace() copied WAITING_FOR_KV; shadows must stay inert
            # for the scheduler (see notify_fetch_done / early admit)
            shadow.state = ReqState.WAITING
            self._shadow_real[shadow.rid] = req
            sp.rid = shadow.rid
            self.ctrl.start(shadow, sp, self.now(), link=link,
                            resolutions=resolutions,
                            served_key=served_key)
        if self.fetch_mode == "sync":
            t = self._clock
            for sp in subplans:
                t = max(t, self.ctrl.drain(sp))
            self._clock = t
            self._check_sharded()

    def _check_sharded(self) -> None:
        """Aggregate per-shard progress into each real request: update
        its ready-layer prefix and fire the single completion (or miss)
        when every shard lands (or any aborts)."""
        for rid in list(self._sharded):
            req, subplans = self._sharded[rid]
            req.layers_ready = sharded_layers_ready(subplans)
            if any(sp.aborted for sp in subplans):
                del self._sharded[rid]
                self.sched.notify_fetch_miss(req, self.now())
            elif all(sp.done for sp in subplans):
                del self._sharded[rid]
                if self.fairness is not None:
                    nbytes = float(sum(
                        pc.sizes.get(pc.resolution or self.resolution, 0)
                        for sp in subplans for pc in sp.chunks))
                    self.fairness.on_fetch_done(req, nbytes)
                self.sched.notify_fetch_done(req, self.now())

    def _run_fetch_wall(self, req: Request, plan: FetchPlan) -> None:
        """Original wall-clock behaviour: fetch synchronously, stamping
        real timestamps (no network model)."""
        req.fetch_started = self.now()
        for pc in plan.chunks:
            pc.resolution = self.resolution
            pc.t_transmit_start = pc.t_transmit_done = self.now()
            self._restore_chunk(req, plan, pc)
            pc.t_decode_done = pc.t_restored = self.now()
        req.layers_ready = plan.layers_ready()
        self.sched.notify_fetch_done(req, self.now())

    # -- frame-wise restoration (real codec + paged scatter) -----------------
    def _restore_chunk(self, req: Request, plan: FetchPlan,
                       pc: PlannedChunk) -> None:
        # sharded fetches restore under shadow requests; the pages
        # belong to the real rid's sequence
        req = self._shadow_real.get(req.rid, req)
        man = plan.manifest
        assert man is not None
        res = pc.resolution or self.resolution
        blob = man.blobs[(pc.ref.chunk_id, res)]
        self.stats.fetched_bytes += len(blob)
        lay = IntraLayout(self.cfg.num_kv_heads, self.cfg.head_dim,
                          *man.layout)
        codec = KVCodec(self.cfg.num_kv_heads, self.cfg.head_dim, lay)
        scales_all = man.scales[pc.ref.kind]
        for toks, qt in codec.iter_decode_frames(blob):
            buf = qt.nbytes * 2  # residual + reference frame
            self.stats.restore_buffer_high_water = max(
                self.stats.restore_buffer_high_water, buf)
            global_toks = toks + pc.ref.token_start
            for li, layer in enumerate(pc.ref.layers):
                self.cache.restore_tokens(
                    layer, pc.ref.kind, req.rid, global_toks,
                    jnp.asarray(qt[:, li]),
                    jnp.asarray(scales_all[layer]))
            self.stats.restored_tokens += len(toks)

    # -- prefill -------------------------------------------------------------
    def _prefill(self, req: Request) -> None:
        tokens = self.prompts[req.rid]
        total = len(tokens) + req.max_new_tokens
        if req.rid not in self.cache.seqs:
            self.cache.add_seq(req.rid, total)
        else:
            self.cache.ensure_capacity(req.rid, total)
        if req.needs_fetch:
            logits = self._suffix_prefill(req, tokens)
        else:
            logits, kvs = paged_model.prefill_collect_kv(
                self.params, self.cfg, jnp.asarray(tokens[None]))
            for layer, (k, v) in enumerate(kvs):
                self.cache.write_prefill(layer, req.rid, k[0], v[0])
            logits = logits[0]
            if self.virtual:
                self._clock += self.cost.prefill_time(len(tokens))
        info = self.cache.seqs[req.rid]
        info.context_len = len(tokens)
        nxt = int(jnp.argmax(logits))
        self.outputs[req.rid].append(nxt)
        req.tokens_out = 1
        req.t_first_token = self.now()
        req.token_times.append(req.t_first_token)
        if self.on_token is not None:
            self.on_token(req, nxt, req.t_first_token)
        if (req.storage_hit == "miss" and req.storage_miss_key
                and isinstance(self.store, StorageCluster)):
            # delayed write-on-miss: only now does the recomputed KV
            # exist for the donor to re-upload
            self.store.notify_recompute_done(req.storage_miss_key,
                                             req.t_first_token)

    def _await_layer(self, req: Request, layer: int) -> None:
        """Async mode: block (on the virtual clock) until ``layer``'s
        prefix KV is restored; pipeline stalls are accounted as stall
        time — zero whenever the Appx A.3 condition held at admission."""
        if self.ctrl is None:
            return
        while req.fetch_done is None and req.layers_ready <= layer:
            t = self.ctrl.pump_next()
            if self._sharded:
                self._check_sharded()
            if t is None:
                if req.fetch_done is not None or req.layers_ready > layer:
                    break  # the final pump completed a sharded fetch
                raise RuntimeError(
                    f"rid={req.rid}: layer {layer} KV never arrived")
            if t > self._clock:
                self.stats.prefill_stall_time += t - self._clock
                self._clock = t

    def _suffix_prefill(self, req: Request, tokens: np.ndarray) -> jax.Array:
        """Prefill only the non-reused suffix, attending over restored
        prefix KV gathered from the paged cache.  Layer k's compute waits
        for layer k's restore event only (layer-wise pipeline)."""
        cfg = self.cfg
        n_pre = req.reuse_tokens
        suffix = jnp.asarray(tokens[None, n_pre:])
        b, s = suffix.shape
        positions = jnp.broadcast_to(
            jnp.arange(n_pre, n_pre + s, dtype=jnp.int32), (b, s))
        pre_pos = jnp.broadcast_to(jnp.arange(n_pre, dtype=jnp.int32),
                                   (b, n_pre))
        info = self.cache.seqs[req.rid]
        bt = np.asarray(info.block_table)
        ps = self.cache.page_size
        rows = bt[np.arange(n_pre) // ps] * ps + np.arange(n_pre) % ps
        comp = (self.cost.layer_comp_times(s) if self.virtual else
                [0.0] * cfg.num_layers)
        x = self.params["embed"][suffix]
        for i in range(cfg.num_layers):
            self._await_layer(req, i)
            lp = paged_model._layer_params(self.params, cfg, i)
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = paged_model._qkv(lp["attn"], h, cfg, positions)
            self.cache.write_prefill(i, req.rid, k[0], v[0],
                                     start_pos=n_pre)
            P = self.cache.n_pages
            pk = self.cache.k_pages[i].reshape(P * ps, cfg.num_kv_heads,
                                               cfg.head_dim)[rows][None]
            pv = self.cache.v_pages[i].reshape(P * ps, cfg.num_kv_heads,
                                               cfg.head_dim)[rows][None]
            k_all = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
            v_all = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
            kpos = jnp.concatenate([pre_pos, positions], axis=1)
            out = attend(q, k_all, v_all, positions, kpos, causal=True,
                         window=cfg.sliding_window)
            x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
            h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + paged_model._mlp_out(lp, h2, cfg)
            self._clock += comp[i]
        return lm_logits(self.params, cfg, x[:, -1:, :])[0, 0]

    # -- main loop ------------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration. Returns False when idle and done."""
        if self.ctrl is not None:
            self.ctrl.pump(self.now())
            if self._sharded:
                self._check_sharded()
        now = self.now()
        self.sched.schedule(now)
        if not self.external_dispatch:
            for req in self.sched.take_fetches():
                self._start_fetch(req)
                self.sched.schedule(self.now())
        if self.prefetch is not None:
            # sglang-style tick: launch speculation for heated prefixes
            # (deferred while demand fetches hold the source link)
            self.prefetch.tick(self.now())
        # newly admitted requests need prefill
        for req in list(self.sched.running):
            if req.t_first_token is None:
                self._prefill(req)
        # one decode step for every running sequence (continuous batching)
        active = [r for r in self.sched.running
                  if r.tokens_out < r.max_new_tokens]
        if active:
            seq_ids = [r.rid for r in active]
            toks = jnp.asarray([self.outputs[r.rid][-1] for r in active],
                               jnp.int32)
            positions = jnp.asarray(
                [len(self.prompts[r.rid]) + r.tokens_out - 1
                 for r in active], jnp.int32)
            logits = paged_model.decode_paged(
                self.params, self.cfg, toks, positions, self.cache, seq_ids)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            if self.virtual:
                ctx = float(np.mean([len(self.prompts[r.rid]) + r.tokens_out
                                     for r in active]))
                self._clock += self.cost.decode_step_time(len(active), ctx)
            tnow = self.now()
            for i, req in enumerate(active):
                self.outputs[req.rid].append(int(nxt[i]))
                req.tokens_out += 1
                req.token_times.append(tnow)
                if self.on_token is not None:
                    self.on_token(req, int(nxt[i]), tnow)
        for req in list(self.sched.running):
            if req.tokens_out >= req.max_new_tokens:
                self.sched.finish(req, self.now())
                self.cache.free_seq(req.rid)
                self.finished.append(req)
        # engine idle but fetches in flight: jump the virtual clock to the
        # next pipeline event so waiting requests make progress
        if (self.virtual and self.ctrl is not None
                and not self.sched.running and not active):
            t = self.ctrl.next_event_time()
            if t is not None:
                self._clock = max(self._clock, t)
                self.ctrl.pump(self._clock)
                if self._sharded:
                    self._check_sharded()
                self.sched.schedule(self._clock)
        self.stats.steps += 1
        return bool(self.sched.running or self.sched.waiting
                    or self.sched.waiting_for_kv)

    def run(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if not self.step():
                break
