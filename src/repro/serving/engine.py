"""Live serving engine: real compute, real codec, real paged memory.

This is the integration proof of the full KVFetcher path on actual small
models (the timing experiments live in repro.cluster.simulator — here only
the mechanics are real): fetching-aware scheduling, background fetch with
frame-wise restoration into paged memory via the Pallas kernel, suffix
prefill over restored prefix KV, and continuously-batched paged decode.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.chunks import KVManifest
from repro.core.codec import KVCodec
from repro.core.fetch import build_plan
from repro.core.layout import IntraLayout
from repro.core.scheduler import FetchingAwareScheduler, ReqState, Request
from repro.cluster.storage import KVStore
from repro.models.attention import attend
from repro.models.common import rms_norm
from repro.models.transformer import lm_logits
from repro.paged.cache import PagedKVCache
from repro.serving import paged_model


@dataclasses.dataclass
class EngineStats:
    restore_buffer_high_water: int = 0
    restored_tokens: int = 0
    fetched_bytes: int = 0
    steps: int = 0


class LiveEngine:
    """Single-node engine over a reduced dense model (real compute)."""

    def __init__(self, params, cfg: ModelConfig, store: KVStore, *,
                 n_pages: int = 256, page_size: int = 16,
                 policy: str = "kvfetcher", max_running: int = 4,
                 resolution: str = "240p"):
        self.params = params
        self.cfg = cfg
        self.store = store
        self.cache = PagedKVCache(cfg, n_pages, page_size)
        self.sched = FetchingAwareScheduler(policy, max_running=max_running)
        self.resolution = resolution
        self.stats = EngineStats()
        self.prompts: Dict[int, np.ndarray] = {}
        self.outputs: Dict[int, List[int]] = {}
        self.finished: List[Request] = []
        self._clock = 0.0

    # -- time: virtual clock advanced by the caller or wall-clock ----------
    def now(self) -> float:
        return time.monotonic()

    # -- intake -------------------------------------------------------------
    def submit(self, tokens: np.ndarray, reuse_prefix: Optional[str] = None,
               reuse_tokens: int = 0, max_new_tokens: int = 8) -> Request:
        rid = len(self.prompts)
        req = Request(rid=rid, arrival=self.now(), prompt_len=len(tokens),
                      max_new_tokens=max_new_tokens,
                      reuse_tokens=reuse_tokens, prefix=reuse_prefix)
        self.prompts[rid] = np.asarray(tokens)
        self.outputs[rid] = []
        self.sched.submit(req, req.arrival)
        return req

    # -- background fetch (synchronous in live mode; the event-driven
    #    overlap is exercised by the simulator) ------------------------------
    def _run_fetch(self, req: Request) -> None:
        man = self.store.lookup(req.prefix)
        assert man is not None, f"prefix {req.prefix} not registered"
        req.fetch_started = self.now()
        plan = build_plan(req.rid, man)
        self.cache.add_seq(req.rid, req.prompt_len + req.max_new_tokens)
        lay = IntraLayout(self.cfg.num_kv_heads, self.cfg.head_dim,
                          *man.layout)
        codec = KVCodec(self.cfg.num_kv_heads, self.cfg.head_dim, lay)
        for pc in plan.chunks:
            blob = man.blobs[(pc.ref.chunk_id, self.resolution)]
            self.stats.fetched_bytes += len(blob)
            scales_all = man.scales[pc.ref.kind]
            for toks, qt in codec.iter_decode_frames(blob):
                buf = qt.nbytes * 2  # residual + reference frame
                self.stats.restore_buffer_high_water = max(
                    self.stats.restore_buffer_high_water, buf)
                global_toks = toks + pc.ref.token_start
                for li, layer in enumerate(pc.ref.layers):
                    self.cache.restore_tokens(
                        layer, pc.ref.kind, req.rid, global_toks,
                        jnp.asarray(qt[:, li]),
                        jnp.asarray(scales_all[layer]))
                self.stats.restored_tokens += len(toks)
            pc.t_restored = self.now()
        req.layers_ready = plan.layers_ready()
        self.sched.notify_fetch_done(req, self.now())

    # -- prefill -------------------------------------------------------------
    def _prefill(self, req: Request) -> None:
        tokens = self.prompts[req.rid]
        total = len(tokens) + req.max_new_tokens
        if req.rid not in self.cache.seqs:
            self.cache.add_seq(req.rid, total)
        else:
            self.cache.ensure_capacity(req.rid, total)
        if req.needs_fetch:
            logits = self._suffix_prefill(req, tokens)
        else:
            logits, kvs = paged_model.prefill_collect_kv(
                self.params, self.cfg, jnp.asarray(tokens[None]))
            for layer, (k, v) in enumerate(kvs):
                self.cache.write_prefill(layer, req.rid, k[0], v[0])
            logits = logits[0]
        info = self.cache.seqs[req.rid]
        info.context_len = len(tokens)
        nxt = int(jnp.argmax(logits))
        self.outputs[req.rid].append(nxt)
        req.tokens_out = 1
        req.t_first_token = self.now()
        req.token_times.append(req.t_first_token)

    def _suffix_prefill(self, req: Request, tokens: np.ndarray) -> jax.Array:
        """Prefill only the non-reused suffix, attending over restored
        prefix KV gathered from the paged cache."""
        cfg = self.cfg
        n_pre = req.reuse_tokens
        suffix = jnp.asarray(tokens[None, n_pre:])
        b, s = suffix.shape
        positions = jnp.broadcast_to(
            jnp.arange(n_pre, n_pre + s, dtype=jnp.int32), (b, s))
        pre_pos = jnp.broadcast_to(jnp.arange(n_pre, dtype=jnp.int32),
                                   (b, n_pre))
        info = self.cache.seqs[req.rid]
        bt = np.asarray(info.block_table)
        ps = self.cache.page_size
        rows = bt[np.arange(n_pre) // ps] * ps + np.arange(n_pre) % ps
        x = self.params["embed"][suffix]
        for i in range(cfg.num_layers):
            lp = paged_model._layer_params(self.params, cfg, i)
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = paged_model._qkv(lp["attn"], h, cfg, positions)
            self.cache.write_prefill(i, req.rid, k[0], v[0],
                                     start_pos=n_pre)
            P = self.cache.n_pages
            pk = self.cache.k_pages[i].reshape(P * ps, cfg.num_kv_heads,
                                               cfg.head_dim)[rows][None]
            pv = self.cache.v_pages[i].reshape(P * ps, cfg.num_kv_heads,
                                               cfg.head_dim)[rows][None]
            k_all = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
            v_all = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
            kpos = jnp.concatenate([pre_pos, positions], axis=1)
            out = attend(q, k_all, v_all, positions, kpos, causal=True,
                         window=cfg.sliding_window)
            x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
            h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + paged_model._mlp_out(lp, h2, cfg)
        return lm_logits(self.params, cfg, x[:, -1:, :])[0, 0]

    # -- main loop ------------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration. Returns False when idle and done."""
        now = self.now()
        self.sched.schedule(now)
        for req in self.sched.take_fetches():
            self._run_fetch(req)  # synchronous in live mode
            self.sched.schedule(self.now())
        # newly admitted requests need prefill
        for req in list(self.sched.running):
            if req.t_first_token is None:
                self._prefill(req)
        # one decode step for every running sequence (continuous batching)
        active = [r for r in self.sched.running
                  if r.tokens_out < r.max_new_tokens]
        if active:
            seq_ids = [r.rid for r in active]
            toks = jnp.asarray([self.outputs[r.rid][-1] for r in active],
                               jnp.int32)
            positions = jnp.asarray(
                [len(self.prompts[r.rid]) + r.tokens_out - 1
                 for r in active], jnp.int32)
            logits = paged_model.decode_paged(
                self.params, self.cfg, toks, positions, self.cache, seq_ids)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            tnow = self.now()
            for i, req in enumerate(active):
                self.outputs[req.rid].append(int(nxt[i]))
                req.tokens_out += 1
                req.token_times.append(tnow)
        for req in list(self.sched.running):
            if req.tokens_out >= req.max_new_tokens:
                self.sched.finish(req, self.now())
                self.cache.free_seq(req.rid)
                self.finished.append(req)
        self.stats.steps += 1
        return bool(self.sched.running or self.sched.waiting
                    or self.sched.waiting_for_kv)

    def run(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if not self.step():
                break
