"""Paged-cache model paths for the live serving engine (dense GQA archs —
the paper's model class: LWM/Yi/Llama families).

``prefill_collect_kv`` runs the prompt and hands back per-layer K/V so the
engine can scatter them into pages; ``decode_paged`` runs one token per
sequence with per-sequence positions (continuous batching) using the
Pallas paged-attention kernel.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import apply_rope, rms_norm
from repro.models.transformer import lm_logits
from repro.paged.cache import PagedKVCache


def _layer_params(params, cfg: ModelConfig, i: int) -> dict:
    n_prefix = len(params["prefix"])
    if i < n_prefix:
        return params["prefix"][i]
    j = i - n_prefix
    cl = len(cfg.layer_pattern)
    n_cycles = 0 if params["cycles"] is None else jax.tree.leaves(
        params["cycles"])[0].shape[0]
    if j < n_cycles * cl:
        cyc = jax.tree.map(lambda x: x[j // cl], params["cycles"])
        return cyc[f"l{j % cl}"]
    return params["rest"][j - n_cycles * cl]


def _qkv(p, h, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp_out(lp, h2, cfg):
    if "moe" in lp:
        out, _ = moe_mod.apply_moe(lp["moe"], h2, cfg)
        return out
    return mlp_mod.apply_mlp(lp["mlp"], h2, cfg.mlp_kind)


def prefill_collect_kv(params, cfg: ModelConfig, tokens: jax.Array
                       ) -> Tuple[jax.Array, List[Tuple[jax.Array,
                                                        jax.Array]]]:
    """tokens [b, s] -> (last-pos logits [b, V], [(k, v)] per layer).

    Full causal attention over the prompt (dense arch assumption).
    """
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens]
    kvs = []
    from repro.models.attention import attend
    for i in range(cfg.num_layers):
        lp = _layer_params(params, cfg, i)
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = _qkv(lp["attn"], h, cfg, positions)
        kvs.append((k, v))
        out = attend(q, k, v, positions, positions, causal=True,
                     window=cfg.sliding_window)
        x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + _mlp_out(lp, h2, cfg)
    return lm_logits(params, cfg, x[:, -1:, :])[:, 0], kvs


def donor_prefix_kv(params, cfg: ModelConfig,
                    tokens) -> Tuple[np.ndarray, np.ndarray]:
    """Run the donor prefill and stack per-layer K/V into the
    [T, L, K, hd] arrays `KVStore.register_prefix` expects."""
    tokens = np.asarray(tokens)
    _, kvs = prefill_collect_kv(params, cfg, jnp.asarray(tokens[None]))
    kv_k = np.stack([np.asarray(k[0]) for k, _ in kvs], axis=1)
    kv_v = np.stack([np.asarray(v[0]) for _, v in kvs], axis=1)
    return kv_k, kv_v


def decode_paged(params, cfg: ModelConfig, tokens: jax.Array,
                 positions: jax.Array, cache: PagedKVCache,
                 seq_ids: List[int]) -> jax.Array:
    """One decode step for a batch of sequences at distinct positions.

    tokens [b] int32; positions [b] int32 (index of the new token).
    Writes the new token's K/V into the pages, then attends over the
    paged cache with the Pallas kernel. Returns logits [b, V].
    """
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :]  # [b, 1, d]
    pos2 = positions[:, None]
    bt = jnp.asarray(cache.block_table_array(seq_ids), jnp.int32)
    context_lens = positions + 1
    for i in range(cfg.num_layers):
        lp = _layer_params(params, cfg, i)
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = _qkv(lp["attn"], h, cfg, pos2)
        for bi, sid in enumerate(seq_ids):
            cache.write_decode_token(i, sid, int(positions[bi]),
                                     k[bi, 0], v[bi, 0])
        out = paged_attention(q[:, 0], cache.k_pages[i], cache.v_pages[i],
                              bt, context_lens)
        x = x + jnp.einsum("bhk,hkd->bd", out, lp["attn"]["wo"])[:, None]
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + _mlp_out(lp, h2, cfg)
    return lm_logits(params, cfg, x)[:, 0]
