"""Adaptive-resolution KV fetching (paper §3.3.2 + Alg. 1 + Appx A.2).

Per chunk: predict bandwidth from history, then pick the resolution whose
*total pipelined time* — ``max(transmission, decode) + switch_penalty``
— is smallest, using profiled (resolution x decoder-pool-concurrency)
latency lookup tables.  In the pipelined fetch the transmit of chunk
``i+1`` overlaps the decode of chunk ``i``, so the steady-state cost of
a resolution is the slower of its two stages (Appx A.3), not their
difference: minimizing the |transmit - decode| *bubble* (the selector's
earlier objective) favors balanced stages even when both are slow,
while the ABR objective (ISSUE 7) favors whichever resolution actually
delivers-and-decodes fastest end to end — minimum total pipelined time,
not maximum compression.

The paper's H20 / L20 / A100 NVDEC tables are reproduced verbatim; a
"host-cpu" table calibrated against this repo's own rANS+restore decode
path is included for the TPU-adapted deployment (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.layout import RESOLUTION_ORDER

GBPS = 1e9 / 8  # bytes per second per Gbps


@dataclasses.dataclass(frozen=True)
class DecodeTable:
    """Decode latency (s) by (resolution, pool concurrency), + penalty."""
    name: str
    n_decoders: int
    latency: Dict[str, Tuple[float, ...]]  # res -> latency[concurrency-1]
    penalty: Dict[str, float]
    chunk_size_mb: Dict[str, float]

    def decode_latency(self, res: str, concurrency: int) -> float:
        lat = self.latency[res]
        return lat[min(max(concurrency, 1), len(lat)) - 1]


# --- paper Appendix A.2, Tables 1-3 (verbatim) -----------------------------

H20_TABLE = DecodeTable(
    name="h20", n_decoders=7,
    latency={
        "240p": (0.21, 0.22, 0.29, 0.32, 0.46, 0.52, 0.62),
        "480p": (0.20, 0.22, 0.30, 0.31, 0.42, 0.43, 0.51),
        "640p": (0.20, 0.21, 0.29, 0.30, 0.37, 0.41, 0.45),
        "1080p": (0.19, 0.19, 0.26, 0.30, 0.35, 0.40, 0.43),
    },
    penalty={"240p": 0.08, "480p": 0.06, "640p": 0.03, "1080p": 0.0},
    chunk_size_mb={"240p": 180, "480p": 205, "640p": 235, "1080p": 256},
)

L20_TABLE = DecodeTable(
    name="l20", n_decoders=3,
    latency={
        "240p": (0.18, 0.18, 0.19),
        "480p": (0.175, 0.178, 0.183),
        "640p": (0.17, 0.175, 0.175),
        "1080p": (0.16, 0.16, 0.161),
    },
    penalty={"240p": 0.06, "480p": 0.06, "640p": 0.04, "1080p": 0.0},
    chunk_size_mb={"240p": 180, "480p": 205, "640p": 235, "1080p": 256},
)

A100_TABLE = DecodeTable(
    name="a100", n_decoders=5,
    latency={
        "240p": (0.25, 0.252, 0.252, 0.26, 0.29),
        "480p": (0.24, 0.241, 0.25, 0.26, 0.27),
        "640p": (0.231, 0.235, 0.24, 0.25, 0.27),
        "1080p": (0.20, 0.21, 0.22, 0.24, 0.25),
    },
    penalty={"240p": 0.04, "480p": 0.04, "640p": 0.03, "1080p": 0.0},
    chunk_size_mb={"240p": 180, "480p": 205, "640p": 235, "1080p": 256},
)

# TPU-adapted deployment: entropy decode runs on the host CPUs fronting each
# chip (measured: rANS ~20 MB/s/worker in this repo, 8 workers/host).
HOST_CPU_TABLE = DecodeTable(
    name="host-cpu", n_decoders=8,
    latency={
        "240p": (0.9, 0.92, 0.95, 1.0, 1.1, 1.2, 1.35, 1.5),
        "480p": (1.0, 1.02, 1.06, 1.12, 1.25, 1.35, 1.5, 1.7),
        "640p": (1.15, 1.18, 1.22, 1.3, 1.4, 1.55, 1.7, 1.9),
        "1080p": (1.3, 1.33, 1.38, 1.45, 1.6, 1.75, 1.9, 2.1),
    },
    penalty={"240p": 0.05, "480p": 0.04, "640p": 0.02, "1080p": 0.0},
    chunk_size_mb={"240p": 180, "480p": 205, "640p": 235, "1080p": 256},
)

TABLES = {t.name: t for t in (H20_TABLE, L20_TABLE, A100_TABLE,
                              HOST_CPU_TABLE)}


# ---------------------------------------------------------------------------
# Bandwidth estimation
# ---------------------------------------------------------------------------

class BandwidthEstimator:
    """EWMA over observed per-chunk throughput (paper: last chunk)."""

    def __init__(self, init_bps: float, alpha: float = 1.0):
        self.est = init_bps
        self.alpha = alpha  # 1.0 == paper's last-chunk estimator

    def observe(self, nbytes: int, seconds: float) -> None:
        if seconds <= 0:
            return
        sample = nbytes / seconds
        self.est = self.alpha * sample + (1 - self.alpha) * self.est


# ---------------------------------------------------------------------------
# Alg. 1 — ABR selection: minimum total pipelined time
# ---------------------------------------------------------------------------

def pipelined_time(bandwidth_bps: float,
                   pool_load: int,
                   table: DecodeTable,
                   resolution: str,
                   sizes_bytes: Optional[Dict[str, int]] = None,
                   active_resolution: Optional[str] = None) -> float:
    """Projected per-chunk pipelined delivery time of ``resolution``:
    ``max(tau_trans, tau_dec) + tau_pen`` (Appx A.3 steady state — the
    transmit of chunk i+1 overlaps the decode of chunk i, the decoder
    reconfiguration penalty is serial).  This is the quantity
    ``select_resolution`` minimizes; exposed separately so property
    tests can brute-force the argmin against the same formula.

    The decode term is the pool's steady-state *drain interval*, not
    one chunk's serial latency: a pipelined fetch keeps every decoder
    it can get busy, so with ``avail`` of the pool's ``n_decoders``
    free (``pool_load`` are taken by other work) the pool retires one
    of this flow's chunks every ``latency(conc) / avail`` seconds,
    profiled at the saturated concurrency ``conc``.  A busy pool both
    shrinks ``avail`` and pushes the latency up its concurrency
    column, so contention still steers the choice toward the rungs
    whose profiles degrade gracefully."""
    ref_size = table.chunk_size_mb[resolution] * 1e6
    size = (sizes_bytes[resolution]
            if sizes_bytes and resolution in sizes_bytes else ref_size)
    tau_trans = size / max(bandwidth_bps, 1.0)
    n = max(table.n_decoders, 1)
    avail = max(n - pool_load, 1)
    conc = min(pool_load + avail, n)
    # decode latency scales with the actual chunk size relative to the
    # profile's reference chunk (same scaling the decode pool applies)
    tau_dec = (table.decode_latency(resolution, conc)
               * max(size / ref_size, 0.05) / avail)
    tau_pen = (table.penalty[resolution]
               if active_resolution is not None
               and resolution != active_resolution else 0.0)
    return max(tau_trans, tau_dec) + tau_pen


def select_resolution(bandwidth_bps: float,
                      pool_load: int,
                      table: DecodeTable,
                      sizes_bytes: Optional[Dict[str, int]] = None,
                      active_resolution: Optional[str] = None,
                      resolutions: Sequence[str] = RESOLUTION_ORDER,
                      ) -> Tuple[str, float]:
    """Returns (r_opt, pipelined_seconds): the resolution minimizing the
    total pipelined per-chunk time (``pipelined_time``) and that time.
    Ties keep the earliest candidate in ``resolutions`` order, so the
    choice is deterministic.  ``sizes_bytes`` overrides the table sizes
    with the chunk's actual encoded sizes when known; ``active_resolution``
    charges the decoder-switch penalty to every *other* resolution, which
    makes the selection sticky: a switch must win by more than the
    reconfiguration it costs."""
    best, best_time = None, float("inf")
    for r in resolutions:
        if r not in table.latency:
            continue
        t = pipelined_time(bandwidth_bps, pool_load, table, r,
                           sizes_bytes=sizes_bytes,
                           active_resolution=active_resolution)
        if t < best_time:
            best, best_time = r, t
    assert best is not None
    return best, best_time
