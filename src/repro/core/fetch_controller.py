"""Event-driven asynchronous fetch controller (paper §3.3, Appx A.3).

One pipeline-state machine drives every in-flight :class:`FetchPlan`
through explicit transmit -> decode -> restore stages against a virtual
clock, shared by the live serving engine (`repro.serving.engine`) and the
cluster simulator (`repro.cluster.simulator`) so the two can never
diverge.  Per chunk the controller

  * selects the resolution with Alg. 1 (`select_resolution`) from the
    bandwidth estimate and decode-pool load,
  * transmits it over the shared link (`repro.cluster.network.SharedLink`
    arbitrates concurrent fetches; a bare `BandwidthTrace` is wrapped into
    a single-flow link) — or, with the multi-node storage tier, over the
    *storage node's own* link passed per fetch via ``start(link=...)``,
    so placement changes the observed path — retrying per-chunk on WAN
    loss: a transmission
    attempt the `LossModel` drops is detected ``retransmit_timeout``
    seconds after its wire time and resent, while — in pipelined mode —
    later chunks keep streaming (selective repeat),
  * decodes it on the decode pool (or the CacheGen-style serialized GPU
    decompressor, or instantly for raw transfers), and
  * fires a restore event, at which the environment hook performs the
    actual (or modeled) frame-wise restoration.

After every restore the controller re-evaluates the Appx A.3 layer-wise
condition and, when satisfied, calls
``scheduler.notify_early_admissible`` so suffix prefill can start while
later layer groups are still in flight.  A fetch with any retransmit
outstanding is never admitted early: the lost chunk's layer group is not
actually buffered, so admitting would stall compute (the chunk-latency
estimate also inflates naturally, since latencies are measured from the
*first* transmission attempt).

Environment differences (real codec work vs. analytic cost models, real
blob sizes vs. ratio-derived sizes) live behind :class:`FetchHooks`; the
stage ordering, pipelining, retransmission, and admission logic are
written once here — both `_SimHooks` and `_EngineHooks` pump this same
retry/fair-share state machine (the "no second pipeline" rule).

See ``docs/fetch_pipeline.md`` for the full state machine and timeline.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.adaptive import (BandwidthEstimator, DecodeTable,
                                 select_resolution)
from repro.core.fetch import FetchPlan, PlannedChunk
from repro.core.layout import RESOLUTION_ORDER
from repro.core.pipelining import non_blocking_ok
from repro.core.scheduler import ReqState, Request
from repro.cluster.network import make_link


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Method-level switches of the fetch pipeline."""
    adaptive: bool = True  # Alg. 1 per-chunk resolution selection
    fixed_resolution: str = "1080p"
    # Overlap transmit/decode/restore of successive chunks.  False models
    # the synchronous baseline: chunk i+1 is not requested until chunk i
    # is fully restored (the pre-pipelining live-engine behaviour).
    pipelined: bool = True
    layerwise_admission: bool = True  # Appx A.3 early admission
    blocking_fetch: bool = False  # LMCache: one bulk transfer, no overlap
    gpu_decomp_tokens_per_s: float = 0.0  # CacheGen CUDA decompression
    use_table_sizes: bool = False  # Appx A.2 table sizes, not real bytes
    resolutions: Tuple[str, ...] = RESOLUTION_ORDER
    # WAN loss handling: a dropped attempt is detected this many seconds
    # after its wire transfer would have completed (ack timeout), then the
    # chunk is resent at the same resolution.
    retransmit_timeout: float = 0.05
    max_attempts: int = 64  # hard cap per chunk (stalled-link guard)


class FetchHooks:
    """Environment-specific callbacks; defaults fit real-manifest plans."""

    def chunk_bytes(self, fetch: "ActiveFetch", pc: PlannedChunk,
                    res: str) -> float:
        return float(pc.sizes[res])

    def restore_seconds(self, fetch: "ActiveFetch",
                        pc: PlannedChunk) -> float:
        return 0.0

    def gpu_decomp_seconds(self, fetch: "ActiveFetch",
                           pc: PlannedChunk) -> float:
        return 0.0

    def buffer_bytes(self, fetch: "ActiveFetch",
                     pc: PlannedChunk) -> float:
        """Peak decompress-buffer bytes while restoring this chunk."""
        return 0.0

    def bulk_buffer_bytes(self, fetch: "ActiveFetch") -> float:
        """Peak buffer for the blocking (non-pipelined bulk) path."""
        return 0.0

    def on_restored(self, fetch: "ActiveFetch", pc: PlannedChunk,
                    now: float) -> None:
        """Perform the actual restoration work (live engine) — or nothing
        (simulator, where restoration is purely a timing event)."""

    def comp_times(self, req: Request) -> Optional[Sequence[float]]:
        """Per-layer prefill compute times for the Appx A.3 condition.
        Returning None disables early admission for this request."""
        return None


@dataclasses.dataclass
class ActiveFetch:
    """Controller-side state of one in-flight fetch."""
    req: Request
    plan: FetchPlan
    est: BandwidthEstimator
    trans_free_at: float
    # the SharedLink this fetch transmits over: the controller's default
    # link, or — multi-node storage tier — the storage node's own link,
    # so placement decisions change the observed network path.
    link: Optional[object] = None
    active_res: Optional[str] = None
    gpu_decomp_until: float = 0.0
    chunk_latencies: List[float] = dataclasses.field(default_factory=list)
    pending_retx: Set[int] = dataclasses.field(default_factory=set)
    retransmits: int = 0  # dropped attempts resent so far


class FetchController:
    """Event-driven pipeline over all in-flight fetches.

    ``bandwidth`` is a `repro.cluster.network.SharedLink` (multi-flow
    arbitration + optional `LossModel`) or anything providing ``bw_at(t)``
    and ``transmit(nbytes, t0)`` — e.g. a bare ``BandwidthTrace``, which
    is wrapped into a single-flow link.  ``pool`` (optional) must provide
    ``decode(res, t_ready, size_scale)`` and ``load_at(t)`` (see
    `repro.cluster.decodepool.DecodePool`).
    """

    def __init__(self, sched, bandwidth, *,
                 table: Optional[DecodeTable] = None,
                 pool=None,
                 config: Optional[PipelineConfig] = None,
                 hooks: Optional[FetchHooks] = None):
        self.sched = sched
        self.link = make_link(bandwidth)
        self.link.bind(self._push)
        self.bw = self.link  # link-rate view for estimator seeding
        if table is None and pool is not None:
            table = pool.table  # decode scaling needs the pool's profile
        self.table = table
        self.pool = pool
        self.config = config or PipelineConfig()
        self.hooks = hooks or FetchHooks()
        self.active: Dict[int, ActiveFetch] = {}
        self.now = 0.0
        self.buffer_high_water = 0.0
        self.retransmits_total = 0  # across all fetches (WAN stats)
        self._events: List[Tuple[float, int, Callable[[float], None]]] = []
        self._eid = 0

    # -- event queue --------------------------------------------------------
    def _push(self, t: float, fn: Callable[[float], None]) -> None:
        self._eid += 1
        heapq.heappush(self._events, (t, self._eid, fn))

    def push_event(self, t: float, fn: Callable[[float], None]) -> None:
        """Public event-queue handle for external producers sharing this
        controller's virtual clock — the storage tier binds it
        (`StorageCluster.bind`) so ``heal="link"`` re-replication
        transfers complete through the same ``pump()`` the fetch
        pipeline runs on, and heal flows contend with live fetches on
        the nodes' `SharedLink`\\ s."""
        self._push(t, fn)

    def pump(self, until: float) -> None:
        """Process every pipeline event with timestamp <= ``until``."""
        while self._events and self._events[0][0] <= until:
            t, _, fn = heapq.heappop(self._events)
            self.now = max(self.now, t)
            fn(t)

    def pump_next(self) -> Optional[float]:
        """Process the single next event; returns its time (None if idle)."""
        if not self._events:
            return None
        t, _, fn = heapq.heappop(self._events)
        self.now = max(self.now, t)
        fn(t)
        return t

    def next_event_time(self) -> Optional[float]:
        return self._events[0][0] if self._events else None

    def drain(self, plan: FetchPlan) -> float:
        """Run this plan's pipeline to completion (the ``sync`` mode);
        returns the completion time on the virtual clock."""
        t = self.now
        while not plan.done:
            nt = self.pump_next()
            if nt is None:
                raise RuntimeError(
                    f"fetch pipeline stalled for rid={plan.rid}")
            t = nt
        return t

    @property
    def busy(self) -> bool:
        return bool(self._events or self.active)

    # -- fetch lifecycle ----------------------------------------------------
    def start(self, req: Request, plan: FetchPlan, now: float, *,
              link=None) -> ActiveFetch:
        """Begin fetching ``plan``.  ``link`` (optional) routes this fetch
        over a specific `SharedLink` — e.g. the storage node holding the
        prefix — instead of the controller's default link; per-fetch links
        share this controller's event queue."""
        req.fetch_started = now
        lnk = self.link if link is None else make_link(link)
        lnk.bind(self._push)
        f = ActiveFetch(req, plan, BandwidthEstimator(lnk.bw_at(now)),
                        trans_free_at=now, link=lnk)
        self.active[req.rid] = f
        lnk.open_flow(req.rid, weight=getattr(req, "weight", 1.0))
        if self.config.blocking_fetch:
            self._start_blocking(f, now)
        else:
            self._send_next(f, now)
        return f

    def _start_blocking(self, f: ActiveFetch, now: float) -> None:
        """LMCache-style inference-blocking fetch: one bulk transfer of
        every chunk, bulk decode, chunk-wise restoration buffer.  The bulk
        stream monopolizes the link (no per-chunk arbitration); WAN loss
        becomes a goodput haircut of ``1 / (1 - mean_loss_rate)`` since a
        byte-stream transfer retransmits inline."""
        res = self.config.fixed_resolution
        total = 0.0
        for pc in f.plan.chunks:
            pc.resolution = res
            pc.t_transmit_start = now
            total += self._chunk_bytes(f, pc, res)
        if f.link.loss is not None:
            total /= max(1.0 - f.link.loss.mean_loss_rate(), 1e-3)
        t_done = f.link.transmit(total, now)
        if self.pool is not None:
            _, t_done = self.pool.decode(res, t_done,
                                         size_scale=len(f.plan.chunks))
        self.buffer_high_water = max(self.buffer_high_water,
                                     self.hooks.bulk_buffer_bytes(f))

        def on_bulk_done(t: float, f=f) -> None:
            for pc in f.plan.chunks:
                pc.t_transmit_done = pc.t_decode_done = pc.t_restored = t
                self.hooks.on_restored(f, pc, t)
            self._finish(f, t)

        self._push(t_done, on_bulk_done)

    # -- per-chunk pipeline -------------------------------------------------
    def _chunk_bytes(self, f: ActiveFetch, pc: PlannedChunk,
                     res: str) -> float:
        if self.config.use_table_sizes and self.table is not None \
                and res in self.table.chunk_size_mb:
            return self.table.chunk_size_mb[res] * 1e6
        return self.hooks.chunk_bytes(f, pc, res)

    def _available_res(self, pc: PlannedChunk) -> Tuple[str, ...]:
        if pc.sizes:
            return tuple(r for r in self.config.resolutions
                         if r in pc.sizes)
        return self.config.resolutions

    def _choose_resolution(self, f: ActiveFetch, pc: PlannedChunk,
                           now: float) -> str:
        avail = self._available_res(pc)
        if not self.config.adaptive or self.table is None:
            res = self.config.fixed_resolution
            if not avail or res in avail:
                return res
            # fixed resolution not encoded for this chunk: nearest
            # available, preferring the next one below
            want = RESOLUTION_ORDER.index(res)
            lower = [r for r in avail
                     if RESOLUTION_ORDER.index(r) <= want]
            return lower[-1] if lower else avail[0]
        sizes = (None if self.config.use_table_sizes else
                 {r: int(self._chunk_bytes(f, pc, r)) for r in avail})
        load = self.pool.load_at(now) if self.pool else 0
        res, _ = select_resolution(f.est.est, load, self.table,
                                   sizes_bytes=sizes,
                                   active_resolution=f.active_res,
                                   resolutions=avail)
        return res

    def _send_next(self, f: ActiveFetch, now: float) -> None:
        plan = f.plan
        if plan.next_to_send >= len(plan.chunks):
            return
        seq = plan.next_to_send
        pc = plan.chunks[seq]
        plan.next_to_send += 1
        res = self._choose_resolution(f, pc, now)
        pc.resolution = res
        f.active_res = res
        self._transmit(f, pc, seq, attempt=1, now=now)

    def _transmit(self, f: ActiveFetch, pc: PlannedChunk, seq: int,
                  attempt: int, now: float) -> None:
        """Submit one transmission attempt of chunk ``seq`` to the link.
        Retransmissions resend the same resolution (the blob already
        chosen); ``pc.t_transmit_start`` keeps the *first* attempt's start
        so latency stats include the full loss penalty."""
        nbytes = self._chunk_bytes(f, pc, pc.resolution)
        t_start = max(now, f.trans_free_at)
        pc.attempts = attempt
        if attempt == 1:
            pc.t_transmit_start = t_start
        f.link.submit(
            f.req.rid, nbytes, t_start,
            lambda t, f=f, pc=pc, seq=seq, attempt=attempt, nbytes=nbytes,
            t_start=t_start: self._on_wire(f, pc, seq, attempt, nbytes,
                                           t_start, t))

    def _on_wire(self, f: ActiveFetch, pc: PlannedChunk, seq: int,
                 attempt: int, nbytes: float, t_start: float,
                 now: float) -> None:
        """Wire transfer of one attempt finished: either the chunk landed
        (advance to decode) or the loss model dropped it (arm the
        retransmit timer).  Pipelined mode streams the next chunk either
        way — selective repeat keeps the pipe busy during loss recovery."""
        if self.config.pipelined and attempt == 1:
            self._send_next(f, now)
        loss = f.link.loss
        if (loss is not None and attempt < self.config.max_attempts
                and loss.dropped(f.req.rid, seq, attempt)):
            f.pending_retx.add(seq)
            f.retransmits += 1
            self.retransmits_total += 1
            t_retry = now + self.config.retransmit_timeout
            self._push(t_retry,
                       lambda t, f=f, pc=pc, seq=seq, attempt=attempt:
                       self._transmit(f, pc, seq, attempt + 1, t))
            return
        f.pending_retx.discard(seq)
        # goodput sample over the full chunk history (first attempt start
        # -> landing), so the estimate degrades under loss/contention
        f.est.observe(int(nbytes), now - pc.t_transmit_start)
        self._on_transmitted(f, pc, nbytes, pc.t_transmit_start, now)

    def _on_transmitted(self, f: ActiveFetch, pc: PlannedChunk,
                        nbytes: float, t_start: float, now: float) -> None:
        pc.t_transmit_done = now
        if self.pool is not None:
            ref = self.table.chunk_size_mb[pc.resolution] * 1e6
            _, t_dec = self.pool.decode(pc.resolution, now,
                                        size_scale=max(nbytes / ref, 0.05))
        elif self.config.gpu_decomp_tokens_per_s:
            dur = self.hooks.gpu_decomp_seconds(f, pc)
            t_dec = max(now, f.gpu_decomp_until) + dur
            f.gpu_decomp_until = t_dec
        else:
            t_dec = now  # raw transfer: nothing to decode
        pc.t_decode_done = t_dec
        self.buffer_high_water = max(self.buffer_high_water,
                                     self.hooks.buffer_bytes(f, pc))
        t_done = t_dec + self.hooks.restore_seconds(f, pc)
        f.chunk_latencies.append(t_done - t_start)
        self._push(t_done, lambda t, f=f, pc=pc: self._on_restored(f, pc, t))

    def _on_restored(self, f: ActiveFetch, pc: PlannedChunk,
                     now: float) -> None:
        pc.t_restored = now
        self.hooks.on_restored(f, pc, now)
        req = f.req
        req.layers_ready = f.plan.layers_ready()
        if not self.config.pipelined:
            self._send_next(f, now)  # serialized: request the next chunk
        if f.plan.done:
            self._finish(f, now)
            return
        if (self.config.layerwise_admission and not req.early_admitted
                and req.state is ReqState.WAITING_FOR_KV):
            self._maybe_admit_early(f, now)

    def _finish(self, f: ActiveFetch, now: float) -> None:
        f.req.layers_ready = f.plan.layers_ready()
        self.active.pop(f.req.rid, None)
        f.link.close_flow(f.req.rid)
        self.sched.notify_fetch_done(f.req, now)

    # -- Appx A.3 layer-wise early admission --------------------------------
    def _maybe_admit_early(self, f: ActiveFetch, now: float) -> None:
        if f.pending_retx:
            # A dropped chunk's layer group is NOT buffered even though
            # later chunks may already be restored; admitting now would
            # stall compute at that group.  Wait for the retransmit.
            return
        comp = self.hooks.comp_times(f.req)
        if comp is None:
            return
        L = len(comp)
        total = max(f.plan.n_layers_total, 1)
        buffered = int(round(f.req.layers_ready * L / total))
        rate = (float(np.mean(f.chunk_latencies[-4:]))
                if f.chunk_latencies else 1.0)
        per_layer_dec = rate * len(f.plan.chunks) / max(L, 1)
        dec = [per_layer_dec] * L
        if non_blocking_ok(dec, comp, buffered):
            self.sched.notify_early_admissible(f.req, now)
