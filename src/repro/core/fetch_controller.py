"""Event-driven asynchronous fetch controller (paper §3.3, Appx A.3).

One pipeline-state machine drives every in-flight :class:`FetchPlan`
through explicit transmit -> decode -> restore stages against a virtual
clock, shared by the live serving engine (`repro.serving.engine`) and the
cluster simulator (`repro.cluster.simulator`) so the two can never
diverge.  Per chunk the controller

  * selects the resolution with Alg. 1 (`select_resolution`) — ABR
    style (ISSUE 7): minimum total pipelined time from the flow's live
    bandwidth estimate (the Jacobson/Karels `RttEstimator` service-time
    view once it has samples, rescaled by the flow's current
    `SharedLink.flow_share` and halved per outstanding lost chunk) vs
    the per-resolution decode-table projection at the pool's load.
    When the share structure collapses mid-fetch — a flow joins the
    link, a slow-start ramp epoch re-shares it, or a loss burst is
    confirmed — the controller re-evaluates immediately and
    down-switches the *remaining* chunks, recording a deterministic
    ``resolution_switch`` event ``(rid, chunk_seq, from, to, reason)``
    that replays identically in the simulator and the live engine
    (the decisions are pure functions of wire timings and link state,
    never of wall-clock interleaving),
  * transmits it over the shared link (`repro.cluster.network.SharedLink`
    arbitrates concurrent fetches; a bare `BandwidthTrace` is wrapped into
    a single-flow link) — or, with the multi-node storage tier, over the
    *storage node's own* link passed per fetch via ``start(link=...)``,
    so placement changes the observed path — arming a retransmit timer
    at each attempt's submit time: the deadline comes from a per-flow
    Jacobson/Karels SRTT/RTTVAR estimator over observed chunk service
    times (``rto_mode="adaptive"``, ``rto = srtt + 4*rttvar`` clamped to
    ``[min_rto, max_rto]`` with exponential backoff) or from the
    projected wire time plus the fixed ``retransmit_timeout`` grace
    (``rto_mode="fixed"``).  A timer that fires resends the chunk while
    — in pipelined mode — later chunks keep streaming (selective
    repeat); a resend that duplicated a copy which later delivers is a
    *spurious* retransmit: the duplicate is cancelled on the link and
    counted separately from loss-driven retransmits,
  * decodes it on the decode pool (or the CacheGen-style serialized GPU
    decompressor, or instantly for raw transfers), and
  * fires a restore event, at which the environment hook performs the
    actual (or modeled) frame-wise restoration.

After every restore the controller re-evaluates the Appx A.3 layer-wise
condition and, when satisfied, calls
``scheduler.notify_early_admissible`` so suffix prefill can start while
later layer groups are still in flight.  A fetch with any retransmit
outstanding is never admitted early: the lost chunk's layer group is not
actually buffered, so admitting would stall compute.  The per-layer
delivery estimate is the Appx A.3 per-resolution projection from the
live bandwidth estimate and the profiled decode table (loss-rate
inflation applies only when the flow's link actually carries a
`LossModel`), so admission stays tight under ramp/loss jitter instead
of chasing a lagging mean of observed chunk latencies.

A chunk that exhausts ``max_attempts`` with every copy lost does not
stall its request forever: the fetch is aborted and routed through
``scheduler.notify_fetch_miss`` so the request falls back to a full
prefill (for an already-early-admitted request the cap is instead
lifted — the engine is attending over restored prefix KV and a fallback
is no longer possible).

Environment differences (real codec work vs. analytic cost models, real
blob sizes vs. ratio-derived sizes) live behind :class:`FetchHooks`; the
stage ordering, pipelining, retransmission, and admission logic are
written once here — both `_SimHooks` and `_EngineHooks` pump this same
retry/fair-share state machine (the "no second pipeline" rule).

See ``docs/fetch_pipeline.md`` for the full state machine and timeline.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.adaptive import (BandwidthEstimator, DecodeTable,
                                 select_resolution)
from repro.core.fetch import FetchPlan, PlannedChunk
from repro.core.layout import RESOLUTION_ORDER
from repro.core.pipelining import non_blocking_ok
from repro.core.scheduler import ReqState, Request
from repro.cluster.network import RttEstimator, make_link


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Method-level switches of the fetch pipeline."""
    adaptive: bool = True  # Alg. 1 per-chunk resolution selection
    fixed_resolution: str = "1080p"
    # Overlap transmit/decode/restore of successive chunks.  False models
    # the synchronous baseline: chunk i+1 is not requested until chunk i
    # is fully restored (the pre-pipelining live-engine behaviour).
    pipelined: bool = True
    layerwise_admission: bool = True  # Appx A.3 early admission
    blocking_fetch: bool = False  # LMCache: one bulk transfer, no overlap
    gpu_decomp_tokens_per_s: float = 0.0  # CacheGen CUDA decompression
    use_table_sizes: bool = False  # Appx A.2 table sizes, not real bytes
    resolutions: Tuple[str, ...] = RESOLUTION_ORDER
    # WAN retransmission: every transmission attempt arms a retransmit
    # timer at its submit time — a real sender only learns about loss
    # from a missing ack, so the old model's drop detection at the
    # actual wire-completion instant (an oracle no transport has) is
    # gone.  rto_mode="adaptive" (default) derives the deadline from
    # the per-flow Jacobson/Karels estimator — rto = srtt + 4*rttvar
    # over observed chunk service times, clamped to [min_rto, max_rto],
    # doubled on consecutive fires for the same chunk; "fixed" keeps a
    # constant retransmit_timeout grace beyond the projected wire time
    # (the non-adaptive baseline the ttft.wan.adaptive.* bench rows
    # compare against).
    rto_mode: str = "adaptive"
    # RACK-style fast retransmit (RFC 8985 in spirit): the delivery of a
    # later-sent chunk reveals the sequence gap left by an earlier chunk
    # whose every copy is known lost, so the sender resends immediately
    # instead of waiting out the full RTO.  It only acts on
    # confirmed-loss state (no copy in flight), so it can never fire a
    # spurious duplicate; the timer stays as the last resort for tail
    # losses with no later delivery to ack past them.  Applies to both
    # rto modes — it is a recovery mechanism, not a deadline policy.
    fast_retransmit: bool = True
    # fixed-mode grace beyond the projected wire time; also pads the
    # adaptive pre-sample seed (3x projected service + this grace).
    retransmit_timeout: float = 0.05
    min_rto: float = 0.02
    max_rto: float = 10.0
    # Hard cap of transmission attempts per chunk.  A chunk that
    # exhausts it with every copy lost aborts the fetch and falls back
    # to full prefill via notify_fetch_miss (no eternal stall).
    max_attempts: int = 64
    # Explicit ACK/NACK propagation delay in the retransmit race: a real
    # sender cannot observe a missing ack before the ack itself would
    # have crossed the reverse path, so every retransmit timer arms at
    # submit + rto + ack_delay.  The default 0 keeps every existing
    # trace byte-identical.
    ack_delay: float = 0.0


class FetchHooks:
    """Environment-specific callbacks; defaults fit real-manifest plans."""

    def chunk_bytes(self, fetch: "ActiveFetch", pc: PlannedChunk,
                    res: str) -> float:
        return float(pc.sizes[res])

    def restore_seconds(self, fetch: "ActiveFetch",
                        pc: PlannedChunk) -> float:
        return 0.0

    def gpu_decomp_seconds(self, fetch: "ActiveFetch",
                           pc: PlannedChunk) -> float:
        return 0.0

    def buffer_bytes(self, fetch: "ActiveFetch",
                     pc: PlannedChunk) -> float:
        """Peak decompress-buffer bytes while restoring this chunk."""
        return 0.0

    def bulk_buffer_bytes(self, fetch: "ActiveFetch") -> float:
        """Peak buffer for the blocking (non-pipelined bulk) path."""
        return 0.0

    def on_restored(self, fetch: "ActiveFetch", pc: PlannedChunk,
                    now: float) -> None:
        """Perform the actual restoration work (live engine) — or nothing
        (simulator, where restoration is purely a timing event)."""

    def comp_times(self, req: Request) -> Optional[Sequence[float]]:
        """Per-layer prefill compute times for the Appx A.3 condition.
        Returning None disables early admission for this request."""
        return None


@dataclasses.dataclass
class _ChunkTx:
    """Transmit-side bookkeeping for one chunk under the send-time
    retransmit-timer model (ISSUE 5)."""
    # attempt number -> SharedLink handle of the copy on the wire
    in_flight: Dict[int, object] = dataclasses.field(default_factory=dict)
    # resend attempt -> the in-flight copies it duplicated at fire time;
    # classified spurious when one of them delivers, genuine (a real
    # retransmit) once every one of them is lost.
    pending_dups: Dict[int, Set[int]] = dataclasses.field(
        default_factory=dict)
    timer_attempt: int = 0  # attempt the armed retransmit timer covers
    fires: int = 0  # consecutive timer fires (backoff exponent)
    last_submit: float = 0.0  # submit time of the newest attempt


@dataclasses.dataclass
class ActiveFetch:
    """Controller-side state of one in-flight fetch."""
    req: Request
    plan: FetchPlan
    est: BandwidthEstimator
    trans_free_at: float
    # the SharedLink this fetch transmits over: the controller's default
    # link, or — multi-node storage tier — the storage node's own link,
    # so placement decisions change the observed network path.
    link: Optional[object] = None
    active_res: Optional[str] = None
    # resolutions actually resident at the serving storage node (None =
    # unrestricted): with per-resolution eviction a node may hold only
    # part of the encoded ladder, and the ABR selection must not pick a
    # rung that was evicted (`StorageHit.resolutions`)
    avail_res: Optional[Tuple[str, ...]] = None
    # storage key this fetch serves (for the per-resolution usage sink)
    served_key: Optional[str] = None
    # link share fraction at the last goodput sample: selection rescales
    # the estimate by share_now/est_share when the structure moves
    est_share: float = 1.0
    # deterministic ABR event log: (rid, chunk_seq, from, to, reason)
    resolution_switches: List[Tuple[int, int, str, str, str]] = \
        dataclasses.field(default_factory=list)
    gpu_decomp_until: float = 0.0
    chunk_latencies: List[float] = dataclasses.field(default_factory=list)
    pending_retx: Set[int] = dataclasses.field(default_factory=set)
    retransmits: int = 0  # loss-driven (genuine) resends so far
    spurious_retransmits: int = 0  # resends of copies that delivered
    est_samples: int = 0  # goodput samples folded into ``est`` so far
    # per-flow Jacobson/Karels service-time estimator driving the RTO
    rtt: RttEstimator = dataclasses.field(default_factory=RttEstimator)
    tx: Dict[int, _ChunkTx] = dataclasses.field(default_factory=dict)


class FetchController:
    """Event-driven pipeline over all in-flight fetches.

    ``bandwidth`` is a `repro.cluster.network.SharedLink` (multi-flow
    arbitration + optional `LossModel`) or anything providing ``bw_at(t)``
    and ``transmit(nbytes, t0)`` — e.g. a bare ``BandwidthTrace``, which
    is wrapped into a single-flow link.  ``pool`` (optional) must provide
    ``decode(res, t_ready, size_scale)`` and ``load_at(t)`` (see
    `repro.cluster.decodepool.DecodePool`).
    """

    def __init__(self, sched, bandwidth, *,
                 table: Optional[DecodeTable] = None,
                 pool=None,
                 config: Optional[PipelineConfig] = None,
                 hooks: Optional[FetchHooks] = None,
                 prefetcher=None):
        self.sched = sched
        self.link = make_link(bandwidth)
        self.link.bind(self._push)
        self.bw = self.link  # link-rate view for estimator seeding
        if table is None and pool is not None:
            table = pool.table  # decode scaling needs the pool's profile
        self.table = table
        self.pool = pool
        self.config = config or PipelineConfig()
        self.hooks = hooks or FetchHooks()
        # speculative prefetch (repro.cluster.staging.PrefetchManager):
        # demand fetches starting on a link cancel speculation riding it
        self.prefetcher = prefetcher
        # per-node smoothed-RTT sink (StorageCluster.observe_rtt): each
        # completed fetch reports its flow's RTT estimate keyed by the
        # serving storage node, driving RTT-aware replica selection
        self.rtt_sink: Optional[Callable[[str, float], None]] = None
        # per-resolution usage sink (StorageCluster.note_resolution_use):
        # each completed fetch reports which encoded resolutions it
        # actually pulled, keyed by (node, key) — cost-aware eviction
        # uses the counts to keep hot resolutions and shed cold ones
        self.res_sink: Optional[Callable[[str, str, str], None]] = None
        self.active: Dict[int, ActiveFetch] = {}
        self.now = 0.0
        self.buffer_high_water = 0.0
        self.retransmits_total = 0  # across all fetches (WAN stats)
        self.spurious_retransmits_total = 0  # duplicates of live copies
        # global ABR event log across fetches, in decision order:
        # (rid, chunk_seq, from_res, to_res, reason) — reasons are
        # "estimate" (chunk-boundary re-selection), "flow_join" /
        # "ramp_epoch" (link share collapse), "loss" (confirmed drop).
        # Deterministic given the access sequence: cross-env replay
        # tests assert simulator == live engine on this log.
        self.resolution_switches: List[Tuple[int, int, str, str, str]] = []
        self._events: List[Tuple[float, int, Callable[[float], None]]] = []
        self._eid = 0
        self.link.on_share_change(self._on_share_change)

    # -- event queue --------------------------------------------------------
    def _push(self, t: float, fn: Callable[[float], None]) -> None:
        self._eid += 1
        heapq.heappush(self._events, (t, self._eid, fn))

    def push_event(self, t: float, fn: Callable[[float], None]) -> None:
        """Public event-queue handle for external producers sharing this
        controller's virtual clock — the storage tier binds it
        (`StorageCluster.bind`) so ``heal="link"`` re-replication
        transfers complete through the same ``pump()`` the fetch
        pipeline runs on, and heal flows contend with live fetches on
        the nodes' `SharedLink`\\ s."""
        self._push(t, fn)

    def pump(self, until: float) -> None:
        """Process every pipeline event with timestamp <= ``until``."""
        while self._events and self._events[0][0] <= until:
            t, _, fn = heapq.heappop(self._events)
            self.now = max(self.now, t)
            fn(t)

    def pump_next(self) -> Optional[float]:
        """Process the single next event; returns its time (None if idle)."""
        if not self._events:
            return None
        t, _, fn = heapq.heappop(self._events)
        self.now = max(self.now, t)
        fn(t)
        return t

    def next_event_time(self) -> Optional[float]:
        return self._events[0][0] if self._events else None

    def drain(self, plan: FetchPlan) -> float:
        """Run this plan's pipeline to completion (the ``sync`` mode);
        returns the completion time on the virtual clock.  An aborted
        plan (``max_attempts`` exhausted, fetch fell back to prefill)
        drains to the abort instant instead of spinning forever."""
        t = self.now
        while not (plan.done or plan.aborted):
            nt = self.pump_next()
            if nt is None:
                raise RuntimeError(
                    f"fetch pipeline stalled for rid={plan.rid}")
            t = nt
        return t

    @property
    def busy(self) -> bool:
        return bool(self._events or self.active)

    # -- fetch lifecycle ----------------------------------------------------
    def start(self, req: Request, plan: FetchPlan, now: float, *,
              link=None, resolutions: Optional[Sequence[str]] = None,
              served_key: Optional[str] = None) -> ActiveFetch:
        """Begin fetching ``plan``.  ``link`` (optional) routes this fetch
        over a specific `SharedLink` — e.g. the storage node holding the
        prefix — instead of the controller's default link; per-fetch links
        share this controller's event queue.  ``resolutions`` (optional)
        restricts the ABR selection to the encodings actually resident at
        the serving node (per-resolution eviction may have shed part of
        the ladder); ``served_key`` names the stored prefix for the
        per-resolution usage sink."""
        req.fetch_started = now
        lnk = self.link if link is None else make_link(link)
        lnk.bind(self._push)
        lnk.on_share_change(self._on_share_change)
        if self.prefetcher is not None:
            # demand traffic needs this link: in-flight speculation on
            # it is cancelled before the flow opens (host-tier fetches
            # cancel nothing — they ride the staging link)
            self.prefetcher.demand_started(req, lnk, now)
        f = ActiveFetch(req, plan, BandwidthEstimator(lnk.bw_at(now)),
                        trans_free_at=now, link=lnk,
                        avail_res=(tuple(resolutions)
                                   if resolutions else None),
                        served_key=served_key)
        self.active[req.rid] = f
        lnk.open_flow(req.rid, weight=getattr(req, "weight", 1.0), t=now)
        if self.config.blocking_fetch:
            self._start_blocking(f, now)
        else:
            self._send_next(f, now)
        return f

    def _start_blocking(self, f: ActiveFetch, now: float) -> None:
        """LMCache-style inference-blocking fetch: one bulk transfer of
        every chunk, bulk decode, chunk-wise restoration buffer.  The bulk
        stream monopolizes the link (no per-chunk arbitration); WAN loss
        becomes a goodput haircut of ``1 / (1 - mean_loss_rate)`` since a
        byte-stream transfer retransmits inline."""
        res = self.config.fixed_resolution
        total = 0.0
        for pc in f.plan.chunks:
            pc.resolution = res
            pc.t_transmit_start = now
            total += self._chunk_bytes(f, pc, res)
        total = self._loss_inflate(f.link, total)
        t_done = f.link.transmit(total, now)
        if self.pool is not None:
            _, t_done = self.pool.decode(res, t_done,
                                         size_scale=len(f.plan.chunks))
        self.buffer_high_water = max(self.buffer_high_water,
                                     self.hooks.bulk_buffer_bytes(f))

        def on_bulk_done(t: float, f=f) -> None:
            for pc in f.plan.chunks:
                pc.t_transmit_done = pc.t_decode_done = pc.t_restored = t
                self.hooks.on_restored(f, pc, t)
            self._finish(f, t)

        self._push(t_done, on_bulk_done)

    # -- per-chunk pipeline -------------------------------------------------
    @staticmethod
    def _loss_inflate(link, estimate: float) -> float:
        """Inflate a transfer-time/byte estimate by the expected
        retransmission rate of the flow's OWN link.  A lossless (e.g.
        storage-node) link pays no haircut even when other links carry a
        LossModel, and a zero-rate model (scripted) is a no-op."""
        loss = link.loss if link is not None else None
        if loss is not None:
            rate = loss.mean_loss_rate()
            if rate > 0:
                return estimate / max(1.0 - rate, 1e-3)
        return estimate

    def _decode_size_scale(self, nbytes: float, res: str) -> float:
        """Decode cost scales with actual bytes relative to the decode
        table's reference chunk (floored: tiny chunks still pay setup)."""
        return max(nbytes / (self.table.chunk_size_mb[res] * 1e6), 0.05)

    def _chunk_bytes(self, f: ActiveFetch, pc: PlannedChunk,
                     res: str) -> float:
        if self.config.use_table_sizes and self.table is not None \
                and res in self.table.chunk_size_mb:
            return self.table.chunk_size_mb[res] * 1e6
        return self.hooks.chunk_bytes(f, pc, res)

    def _available_res(self, f: Optional[ActiveFetch],
                       pc: PlannedChunk) -> Tuple[str, ...]:
        if pc.sizes:
            base = tuple(r for r in self.config.resolutions
                         if r in pc.sizes)
        else:
            base = self.config.resolutions
        if f is not None and f.avail_res:
            # resolutions evicted at the serving node are not fetchable
            restricted = tuple(r for r in base if r in f.avail_res)
            if restricted:
                return restricted
        return base

    def _sel_bw(self, f: ActiveFetch, now: float) -> float:
        """Bandwidth estimate feeding the ABR selection (bytes/sec):
        the flow's achieved rate — the Jacobson/Karels `RttEstimator`
        smoothed service time over the active resolution's chunk bytes
        once it has samples (Karn-filtered, so retransmission ambiguity
        never pollutes it), the raw goodput estimator before that —
        rescaled by how the flow's link share has moved since the last
        sample (``flow_share(now) / est_share``: a flow join or ramp
        epoch is visible *immediately*, not one smoothed sample later),
        and halved per outstanding lost chunk (multiplicative decrease
        while a loss burst is in progress).  Every input is wire-side
        state, so the resulting switch decisions are deterministic
        across environments with matching wire timings."""
        rate = f.est.est
        if f.rtt.srtt is not None and f.active_res is not None:
            plan = f.plan
            pc = plan.chunks[min(plan.next_to_send, len(plan.chunks) - 1)]
            if not pc.sizes or f.active_res in pc.sizes:
                rate = (self._chunk_bytes(f, pc, f.active_res)
                        / max(f.rtt.srtt, 1e-9))
        if hasattr(f.link, "flow_share"):
            rate *= (f.link.flow_share(f.req.rid)
                     / max(f.est_share, 1e-9))
        rate /= 2.0 ** min(len(f.pending_retx), 8)
        return max(rate, 1.0)

    def _select(self, f: ActiveFetch, pc: PlannedChunk,
                now: float) -> str:
        """One ABR selection (Alg. 1, minimum total pipelined time) for
        ``pc`` from the live share-adjusted bandwidth estimate and the
        decode pool's current load."""
        avail = self._available_res(f, pc)
        sizes = (None if self.config.use_table_sizes else
                 {r: int(self._chunk_bytes(f, pc, r)) for r in avail})
        load = self.pool.load_at(now) if self.pool else 0
        res, _ = select_resolution(self._sel_bw(f, now), load, self.table,
                                   sizes_bytes=sizes,
                                   active_resolution=f.active_res,
                                   resolutions=avail)
        return res

    def _choose_resolution(self, f: ActiveFetch, pc: PlannedChunk,
                           now: float) -> str:
        avail = self._available_res(f, pc)
        if not self.config.adaptive or self.table is None:
            res = self.config.fixed_resolution
            if not avail or res in avail:
                return res
            # fixed resolution not encoded for this chunk: nearest
            # available, preferring the next one below
            want = RESOLUTION_ORDER.index(res)
            lower = [r for r in avail
                     if RESOLUTION_ORDER.index(r) <= want]
            return lower[-1] if lower else avail[0]
        return self._select(f, pc, now)

    def _record_switch(self, f: ActiveFetch, seq: int, old: str,
                       new: str, reason: str) -> None:
        evt = (f.req.rid, seq, old, new, reason)
        f.resolution_switches.append(evt)
        self.resolution_switches.append(evt)

    def _on_share_change(self, t: float, reason: str) -> None:
        """A subscribed link's share structure moved (flow join / leave,
        slow-start ramp epoch): re-evaluate every active adaptive fetch
        so the *remaining* chunks down-switch at the collapse instant
        instead of a chunk boundary later.  Fetches on an unrelated
        link see an unchanged ``flow_share`` and re-select identically
        (no event); a leave only grows the survivors' shares, so no
        down-switch can be missed by skipping it."""
        if reason == "flow_leave":
            return
        for f in list(self.active.values()):
            self._reconsider(f, t, reason)

    def _reconsider(self, f: ActiveFetch, now: float,
                    reason: str) -> None:
        """Re-run the ABR selection for the remaining chunks of one
        active fetch at a share-collapse signal.  Only *down*-switches
        apply mid-fetch — the collapse evidence is structural (join /
        ramp re-share / confirmed loss), while an upgrade safely waits
        for the next chunk boundary's own selection — and an applied
        switch is recorded as a deterministic ``resolution_switch``
        event against the first not-yet-sent chunk."""
        if (not self.config.adaptive or self.table is None
                or f.active_res is None):
            return
        plan = f.plan
        if plan.aborted or plan.next_to_send >= len(plan.chunks):
            return
        res = self._select(f, plan.chunks[plan.next_to_send], now)
        if res == f.active_res:
            return
        order = RESOLUTION_ORDER
        if (res in order and f.active_res in order
                and order.index(res) >= order.index(f.active_res)):
            return  # an up-switch: leave it to the next chunk boundary
        self._record_switch(f, plan.next_to_send, f.active_res, res,
                            reason)
        f.active_res = res

    def _send_next(self, f: ActiveFetch, now: float) -> None:
        plan = f.plan
        if plan.aborted or plan.next_to_send >= len(plan.chunks):
            return
        seq = plan.next_to_send
        pc = plan.chunks[seq]
        plan.next_to_send += 1
        res = self._choose_resolution(f, pc, now)
        if f.active_res is not None and res != f.active_res:
            self._record_switch(f, seq, f.active_res, res, "estimate")
        pc.resolution = res
        f.active_res = res
        self._transmit(f, pc, seq, attempt=1, now=now)

    def _transmit(self, f: ActiveFetch, pc: PlannedChunk, seq: int,
                  attempt: int, now: float) -> None:
        """Submit one transmission attempt of chunk ``seq`` to the link
        and arm its retransmit timer at the submit time (the sender's
        view: the clock starts when the chunk leaves, not when its bytes
        happen to land).  Retransmissions resend the same resolution (the
        blob already chosen); ``pc.t_transmit_start`` keeps the *first*
        attempt's start so latency stats include the full loss penalty."""
        nbytes = self._chunk_bytes(f, pc, pc.resolution)
        t_start = max(now, f.trans_free_at)
        pc.attempts = max(pc.attempts, attempt)
        if attempt == 1:
            pc.t_transmit_start = t_start
        st = f.tx.setdefault(seq, _ChunkTx())
        handle = f.link.submit(
            f.req.rid, nbytes, t_start,
            lambda t, f=f, pc=pc, seq=seq, attempt=attempt, nbytes=nbytes,
            t_start=t_start: self._on_wire(f, pc, seq, attempt, nbytes,
                                           t_start, t))
        st.in_flight[attempt] = handle
        st.timer_attempt = attempt
        st.last_submit = t_start
        deadline = (t_start + self._rto(f, nbytes, st.fires)
                    + self.config.ack_delay)
        self._push(deadline,
                   lambda t, f=f, pc=pc, seq=seq, attempt=attempt:
                   self._on_timeout(f, pc, seq, attempt, t))

    def _rto(self, f: ActiveFetch, nbytes: float, fires: int) -> float:
        """Retransmit deadline offset for the next attempt of a chunk of
        ``nbytes`` bytes, after ``fires`` consecutive timer fires (each
        fire doubles the deadline — classic exponential backoff).  For
        the flow's *tail* chunk — nothing left unsent, so no later
        delivery will ever reveal its loss to ``_fast_retransmit`` — the
        adaptive deadline tightens to a TLP-style probe (~2x srtt beyond
        the projected service time, RFC 8985): a tail loss otherwise
        idles for the full jitter-padded RTO at the worst possible
        moment, right before the fetch completes."""
        cfg = self.config
        expected = nbytes / max(f.est.est, 1.0)  # projected service time
        if f.est_samples == 0:
            # cold start: the estimator still holds the raw trace rate,
            # but the sender at least knows how many flows its own link
            # carries and its own slow-start window — project the
            # (ramp-scaled) fair share, not the full pipe
            expected *= max(getattr(f.link, "n_flows", 1), 1)
            if hasattr(f.link, "ramp_factor"):
                expected /= max(f.link.ramp_factor(f.req.rid), 1e-3)
        if cfg.rto_mode == "adaptive":
            base = f.rtt.rto(cfg.min_rto, cfg.max_rto)
            if base is None:
                # no service-time sample yet: seed conservatively, like
                # TCP's large initial RTO (3x the projected wire time)
                base = 3.0 * expected + cfg.retransmit_timeout
            elif (cfg.fast_retransmit and f.rtt.srtt is not None
                    and f.plan.next_to_send >= len(f.plan.chunks)):
                base = min(base, max(expected, f.rtt.srtt)
                           + 2.0 * f.rtt.srtt)  # tail loss probe
        else:
            base = expected + cfg.retransmit_timeout
        # never cap below the base: a deadline ahead of the *projected*
        # completion would guarantee a duplicate storm
        return min(base * (2.0 ** fires), max(cfg.max_rto, base))

    def _self_in_flight(self, f: ActiveFetch) -> int:
        """Transmission attempts of this flow currently on the wire."""
        return sum(len(st.in_flight) for st in f.tx.values())

    def _on_timeout(self, f: ActiveFetch, pc: PlannedChunk, seq: int,
                    attempt: int, now: float) -> None:
        """Retransmit timer fired for ``attempt`` of chunk ``seq``.  If
        the chunk already landed (or the fetch ended) the timer is stale.
        Otherwise resend — classifying the resend as a genuine retransmit
        when every prior copy is known lost, or keeping it *provisional*
        while copies are still in flight (resolved at their delivery /
        loss: see ``_on_wire``)."""
        st = f.tx.get(seq)
        if (st is None or pc.t_transmit_done is not None
                or f.req.rid not in self.active):
            return  # chunk landed or fetch finished: stale timer
        if attempt != st.timer_attempt:
            return  # superseded by a newer attempt's timer
        if attempt in st.in_flight and self._self_in_flight(f) > 1:
            # The sender can account for its own multiplexing: another
            # of this flow's transfers shares the wire with this one, so
            # the missing ack is self-explained — defer rather than fire
            # a duplicate.  (Cross-flow contention stays invisible, as
            # for a real transport, and genuinely fires spuriously.)
            nbytes = self._chunk_bytes(f, pc, pc.resolution)
            self._push(now + self._rto(f, nbytes, st.fires)
                       + self.config.ack_delay,
                       lambda t, f=f, pc=pc, seq=seq, attempt=attempt:
                       self._on_timeout(f, pc, seq, attempt, t))
            return
        nxt = pc.attempts + 1
        if nxt > self.config.max_attempts:
            if not f.req.early_admitted:
                # not yet admitted (waiting_for_kv, or parked in the
                # fetch_agnostic FCFS queue): a full-prefill fallback is
                # still possible
                if not st.in_flight:
                    self._abort(f, now)  # every copy lost: fall back
                return  # copies still on the wire may yet land
            # early-admitted request: the engine is already attending
            # over restored prefix KV, a fallback prefill is no longer
            # possible — lift the cap and keep retrying instead
        st.fires += 1
        dup_of = set(st.in_flight)
        if dup_of:
            st.pending_dups[nxt] = dup_of  # classified at resolution
        else:
            f.retransmits += 1  # every prior copy known lost: genuine
            self.retransmits_total += 1
        f.pending_retx.add(seq)
        self._transmit(f, pc, seq, nxt, now)

    def _on_wire(self, f: ActiveFetch, pc: PlannedChunk, seq: int,
                 attempt: int, nbytes: float, t_start: float,
                 now: float) -> None:
        """Wire transfer of one attempt finished: either the chunk landed
        (advance to decode; superseded duplicates are cancelled and any
        provisional resends counted spurious) or the loss model dropped
        it (provisional resends that only duplicated lost copies become
        genuine retransmits).  Pipelined mode streams the next chunk
        either way — selective repeat keeps the pipe busy during loss
        recovery."""
        st = f.tx.setdefault(seq, _ChunkTx())
        st.in_flight.pop(attempt, None)
        if self.config.pipelined and attempt == 1:
            self._send_next(f, now)
        if pc.t_transmit_done is not None:
            return  # a duplicate of an already-landed chunk
        loss = f.link.loss
        if loss is not None and loss.dropped(f.req.rid, seq, attempt, now):
            f.pending_retx.add(seq)
            genuine = 0
            for r, dup in list(st.pending_dups.items()):
                dup.discard(attempt)
                if not dup:  # duplicated copies all lost: was necessary
                    genuine += 1
                    del st.pending_dups[r]
            f.retransmits += genuine
            self.retransmits_total += genuine
            # a confirmed drop is a share-collapse signal: down-switch
            # the remaining chunks now (the goodput estimator only sees
            # the burst when the retransmitted chunk finally lands)
            self._reconsider(f, now, "loss")
            self._maybe_dead(f, pc, seq, st, now)
            return
        # landed: the first delivered copy wins
        if attempt == 1:
            # Karn's algorithm: only unambiguous (first-attempt) service
            # times feed the RTO estimator
            f.rtt.observe(now - t_start)
        for handle in st.in_flight.values():
            f.link.cancel(handle, now)  # cancel superseded duplicates
        st.in_flight.clear()
        for r in list(st.pending_dups):
            if r == attempt:  # the resend itself delivered first
                f.retransmits += 1
                self.retransmits_total += 1
            else:  # duplicated a copy that delivered: wasted bytes
                f.spurious_retransmits += 1
                self.spurious_retransmits_total += 1
        st.pending_dups.clear()
        f.pending_retx.discard(seq)
        # goodput sample over the full chunk history (first attempt start
        # -> landing), so the estimate degrades under loss/contention
        f.est.observe(int(nbytes), now - pc.t_transmit_start)
        f.est_samples += 1
        if hasattr(f.link, "flow_share"):
            # the sample embodies the share the flow held while this
            # chunk was on the wire; selection rescales by the ratio of
            # the *current* share to this one (see _sel_bw)
            f.est_share = f.link.flow_share(f.req.rid)
        if self.config.fast_retransmit:
            self._fast_retransmit(f, t_start, now)
        self._on_transmitted(f, pc, nbytes, pc.t_transmit_start, now)

    def _fast_retransmit(self, f: ActiveFetch, acked_submit: float,
                         now: float) -> None:
        """RACK-style loss recovery: this delivery acks a chunk submitted
        at ``acked_submit``, so any earlier-submitted chunk whose every
        copy is already known lost has a confirmed sequence gap — resend
        it now instead of waiting for its (possibly backed-off) RTO
        timer.  Only fires on confirmed-loss state (``in_flight`` empty),
        so the resend is always a genuine retransmit, never spurious."""
        for seq in sorted(f.pending_retx):
            st = f.tx.get(seq)
            pc = f.plan.chunks[seq]
            if (st is None or st.in_flight
                    or pc.t_transmit_done is not None
                    or st.last_submit >= acked_submit):
                continue
            nxt = pc.attempts + 1
            if (nxt > self.config.max_attempts
                    and not f.req.early_admitted):
                continue  # cap exhausted: the abort path owns this chunk
            # the delivery is fresh evidence the path is alive: the
            # resend's timer restarts from the un-backed-off RTO
            st.fires = 0
            f.retransmits += 1
            self.retransmits_total += 1
            self._transmit(f, pc, seq, nxt, now)

    def _maybe_dead(self, f: ActiveFetch, pc: PlannedChunk, seq: int,
                    st: _ChunkTx, now: float) -> None:
        """Abort the fetch when a chunk has exhausted ``max_attempts``
        with no copy left on the wire (nothing can deliver it anymore)."""
        if (pc.t_transmit_done is None and not st.in_flight
                and pc.attempts >= self.config.max_attempts
                and not f.req.early_admitted
                and f.req.rid in self.active):
            self._abort(f, now)

    def _abort(self, f: ActiveFetch, now: float) -> None:
        """``max_attempts`` exhausted with every copy lost: abandon the
        fetch and route the request through ``notify_fetch_miss`` so it
        falls back to a full prefill instead of hanging in
        ``waiting_for_kv`` forever."""
        f.plan.aborted = True
        for st in f.tx.values():
            for handle in st.in_flight.values():
                f.link.cancel(handle, now)
            st.in_flight.clear()
            st.pending_dups.clear()
        self.active.pop(f.req.rid, None)
        f.link.close_flow(f.req.rid, now)
        fair = getattr(self.sched, "fairness", None)
        if fair is not None:
            # the tenant still consumed every byte that DID deliver
            fair.on_fetch_abort(f.req, sum(
                self._chunk_bytes(f, pc, pc.resolution
                                  or self.config.fixed_resolution)
                for pc in f.plan.chunks
                if pc.t_transmit_done is not None))
        self.sched.notify_fetch_miss(f.req, now)

    def _on_transmitted(self, f: ActiveFetch, pc: PlannedChunk,
                        nbytes: float, t_start: float, now: float) -> None:
        pc.t_transmit_done = now
        if self.pool is not None:
            _, t_dec = self.pool.decode(
                pc.resolution, now,
                size_scale=self._decode_size_scale(nbytes, pc.resolution))
        elif self.config.gpu_decomp_tokens_per_s:
            dur = self.hooks.gpu_decomp_seconds(f, pc)
            t_dec = max(now, f.gpu_decomp_until) + dur
            f.gpu_decomp_until = t_dec
        else:
            t_dec = now  # raw transfer: nothing to decode
        pc.t_decode_done = t_dec
        self.buffer_high_water = max(self.buffer_high_water,
                                     self.hooks.buffer_bytes(f, pc))
        t_done = t_dec + self.hooks.restore_seconds(f, pc)
        f.chunk_latencies.append(t_done - t_start)
        self._push(t_done, lambda t, f=f, pc=pc: self._on_restored(f, pc, t))

    def _on_restored(self, f: ActiveFetch, pc: PlannedChunk,
                     now: float) -> None:
        pc.t_restored = now
        self.hooks.on_restored(f, pc, now)
        req = f.req
        req.layers_ready = f.plan.layers_ready()
        if not self.config.pipelined:
            self._send_next(f, now)  # serialized: request the next chunk
        if f.plan.done:
            self._finish(f, now)
            return
        if (self.config.layerwise_admission and not req.early_admitted
                and req.state is ReqState.WAITING_FOR_KV):
            self._maybe_admit_early(f, now)

    def _finish(self, f: ActiveFetch, now: float) -> None:
        f.req.layers_ready = f.plan.layers_ready()
        self.active.pop(f.req.rid, None)
        f.link.close_flow(f.req.rid, now)
        if self.rtt_sink is not None and f.rtt.srtt is not None \
                and f.req.storage_node:
            self.rtt_sink(f.req.storage_node, f.rtt.srtt)
        if self.res_sink is not None and f.served_key:
            # report which encoded rungs this fetch actually used, in
            # ladder order (deterministic): cost-aware per-resolution
            # eviction keeps hot rungs and sheds cold ones
            used = {pc.resolution for pc in f.plan.chunks
                    if pc.resolution}
            for r in sorted(used, key=lambda r: (
                    RESOLUTION_ORDER.index(r)
                    if r in RESOLUTION_ORDER else -1)):
                self.res_sink(f.req.storage_node or "", f.served_key, r)
        fair = getattr(self.sched, "fairness", None)
        if fair is not None:
            # charge the tenant's virtual counter with the fetch's wire
            # bytes BEFORE notifying (the scheduler's own fallback then
            # sees the slot already released and is a no-op); chunk
            # bytes are a pure function of token counts / table sizes,
            # so both environments charge identically
            fair.on_fetch_done(f.req, sum(
                self._chunk_bytes(f, pc, pc.resolution
                                  or self.config.fixed_resolution)
                for pc in f.plan.chunks))
        self.sched.notify_fetch_done(f.req, now)

    # -- Appx A.3 layer-wise early admission --------------------------------
    def _projected_chunk_interval(self, f: ActiveFetch,
                                  now: float) -> float:
        """Appx A.3 per-resolution projection of the steady-state chunk
        delivery interval: transmit time from the live bandwidth estimate
        (inflated by the expected retransmission rate only when THIS
        flow's link carries a `LossModel`) and decode time from the
        profiled decode table at the pool's current load.  Replaces the
        mean of recent observed chunk latencies, which lags badly under
        the jitter a slow-start ramp or bursty loss introduces.  Without
        a decode table the observed-latency fallback remains."""
        if self.table is None:
            return (float(np.mean(f.chunk_latencies[-4:]))
                    if f.chunk_latencies else 1.0)
        plan = f.plan
        pc = plan.chunks[min(plan.next_to_send, len(plan.chunks) - 1)]
        res = pc.resolution or f.active_res or self.config.fixed_resolution
        avail = self._available_res(f, pc)
        if avail and res not in avail:
            res = avail[0]
        nbytes = self._chunk_bytes(f, pc, res)
        # lossless links pay no goodput haircut (satellite regression)
        tau_trans = self._loss_inflate(f.link,
                                       nbytes / max(f.est.est, 1.0))
        if self.pool is not None and res in self.table.latency \
                and self.table.chunk_size_mb.get(res):
            tau_dec = self.table.decode_latency(
                res, self.pool.load_at(now) + 1) \
                * self._decode_size_scale(nbytes, res)
        elif self.config.gpu_decomp_tokens_per_s:
            tau_dec = self.hooks.gpu_decomp_seconds(f, pc)
        else:
            tau_dec = 0.0
        tau_restore = self.hooks.restore_seconds(f, pc)
        if self.config.pipelined:
            # transmit and decode of successive chunks overlap: the
            # steady-state interval is the slower stage, plus the
            # (serial) restore event
            return max(tau_trans, tau_dec) + tau_restore
        return tau_trans + tau_dec + tau_restore

    def _maybe_admit_early(self, f: ActiveFetch, now: float) -> None:
        if f.pending_retx:
            # A dropped chunk's layer group is NOT buffered even though
            # later chunks may already be restored; admitting now would
            # stall compute at that group.  Wait for the retransmit.
            return
        comp = self.hooks.comp_times(f.req)
        if comp is None:
            return
        L = len(comp)
        total = max(f.plan.n_layers_total, 1)
        buffered = int(round(f.req.layers_ready * L / total))
        per_layer_dec = (self._projected_chunk_interval(f, now)
                         * len(f.plan.chunks) / max(L, 1))
        dec = [per_layer_dec] * L
        if non_blocking_ok(dec, comp, buffered):
            self.sched.notify_early_admissible(f.req, now)
