"""Fetch plans: the per-request chunk schedule the fetch controller walks.

Chunks are ordered layer-group-major (all token-chunks of layer group 0,
then group 1, ...), interleaving K and V of the same group, so layers
become ready front-to-back — exactly what the layer-wise
fetching-inference pipeline (Appx A.3) needs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.chunks import ChunkRef, KVManifest, layer_groups_of


@dataclasses.dataclass
class PlannedChunk:
    ref: ChunkRef
    sizes: Dict[str, int]  # resolution -> bytes
    resolution: Optional[str] = None  # chosen at fetch time (Alg. 1)
    # t_transmit_start is the FIRST attempt's start; with WAN loss the
    # chunk may be resent (attempts > 1) before t_transmit_done lands.
    attempts: int = 0
    t_transmit_start: Optional[float] = None
    t_transmit_done: Optional[float] = None
    t_decode_done: Optional[float] = None
    t_restored: Optional[float] = None


@dataclasses.dataclass
class FetchPlan:
    rid: int
    manifest: Optional[KVManifest]  # None for synthetic (simulator) plans
    chunks: List[PlannedChunk]
    n_layers_total: int
    next_to_send: int = 0
    # Set when the controller abandons the fetch (a chunk exhausted
    # max_attempts with every copy lost); the request falls back to a
    # full prefill via notify_fetch_miss and the plan never completes.
    aborted: bool = False

    def layers_ready(self) -> int:
        """Contiguous prefix of layers whose K and V are fully restored."""
        done_groups = 0
        per_group: Dict[int, List[bool]] = {}
        for pc in self.chunks:
            per_group.setdefault(pc.ref.group, []).append(
                pc.t_restored is not None)
        ready = 0
        groups = sorted(per_group)
        for g in groups:
            if all(per_group[g]):
                first = next(pc.ref.layers
                             for pc in self.chunks if pc.ref.group == g)
                ready += len(first)
            else:
                break
        return ready

    @property
    def done(self) -> bool:
        return all(pc.t_restored is not None for pc in self.chunks)


def build_plan(rid: int, manifest: KVManifest) -> FetchPlan:
    by_key: Dict[Tuple[int, int, str], ChunkRef] = {}
    for ref in manifest.refs:
        by_key[(ref.group, ref.chunk, ref.kind)] = ref
    ordered: List[PlannedChunk] = []
    groups = sorted({r.group for r in manifest.refs})
    chunks = sorted({r.chunk for r in manifest.refs})
    for g in groups:
        for c in chunks:
            for kind in ("k", "v"):
                ref = by_key.get((g, c, kind))
                if ref is None:
                    continue
                sizes = {res: len(manifest.blobs[(ref.chunk_id, res)])
                         for res in manifest.resolutions}
                ordered.append(PlannedChunk(ref=ref, sizes=sizes))
    n_layers = sum(len(g) for g in manifest.layer_groups)
    return FetchPlan(rid=rid, manifest=manifest, chunks=ordered,
                     n_layers_total=n_layers)


def split_plan_shards(plan: FetchPlan, n_shards: int) -> List[FetchPlan]:
    """Partition ``plan`` into per-shard subplans by layer group
    (``ref.group % n_shards``) for a mesh-sharded paged cache: each
    shard's fetch/decode/restore stream runs as its own flow through the
    one FetchController event loop.  The `PlannedChunk` objects are
    SHARED with the parent plan (not copied), so restore timestamps
    recorded by a shard are visible to `sharded_layers_ready` and to the
    parent plan's own ``layers_ready``/``done``.  Empty shards (more
    shards than layer groups) are dropped."""
    assert n_shards >= 1
    subs: List[FetchPlan] = []
    for s in range(n_shards):
        chunks = [pc for pc in plan.chunks if pc.ref.group % n_shards == s]
        if chunks:
            subs.append(FetchPlan(rid=plan.rid, manifest=plan.manifest,
                                  chunks=chunks,
                                  n_layers_total=plan.n_layers_total))
    return subs


def sharded_layers_ready(plans: List[FetchPlan]) -> int:
    """Contiguous ready-layer prefix across shard subplans: the union of
    their chunks is exactly the parent plan's chunk set, so this is the
    aggregate the engine gates admission on while shards restore
    independently."""
    merged = FetchPlan(
        rid=plans[0].rid if plans else -1, manifest=None,
        chunks=[pc for sp in plans for pc in sp.chunks],
        n_layers_total=plans[0].n_layers_total if plans else 0)
    return merged.layers_ready()


def synthetic_plan(rid: int, reuse_tokens: int, n_attn_layers: int,
                   tokens_per_chunk: int) -> FetchPlan:
    """Plan without a real manifest: chunk geometry only (byte sizes come
    from the controller's hooks).  Used by the cluster simulator and by
    controller unit tests."""
    groups = layer_groups_of(max(n_attn_layers, 1))
    per_group = max(1, -(-reuse_tokens // tokens_per_chunk))
    chunks: List[PlannedChunk] = []
    for g, layers in enumerate(groups):
        for c in range(per_group):
            t0 = c * tokens_per_chunk
            t1 = max(t0 + 1, min(reuse_tokens, t0 + tokens_per_chunk))
            for kind in ("k", "v"):
                chunks.append(PlannedChunk(
                    ref=ChunkRef(kind, g, c, t0, t1, tuple(layers)),
                    sizes={}))
    return FetchPlan(rid=rid, manifest=None, chunks=chunks,
                     n_layers_total=sum(len(g) for g in groups))
