"""Interleaved multi-lane rANS entropy coder (lossless, byte alphabet).

This is the "bitstream engine" of the KV codec: the sequential entropy
stage that on GPUs lives inside NVENC/NVDEC and here runs on the host CPUs
fronting each TPU chip (see DESIGN.md hardware-adaptation table). It is a
real, self-contained compressor: static per-chunk frequency tables (12-bit
precision, add-1 smoothed so every byte is codable), 64-bit-state rANS with
32-bit renormalization (emits at most one u32 per symbol -> fully
vectorizable across N interleaved lanes with numpy).

Wire format of ``encode``:
  [u8 lanes_log2][u32 n_symbols][256 x u16 freq table][u32 n_words]
  [n_words x u32 stream][lanes x u64 final states]
"""
from __future__ import annotations

import numpy as np

PROB_BITS = 12
PROB_SCALE = 1 << PROB_BITS
RANS_L = np.uint64(1) << np.uint64(31)
MASK32 = np.uint64(0xFFFFFFFF)
DEFAULT_LANES = 256


# ---------------------------------------------------------------------------
# Frequency tables
# ---------------------------------------------------------------------------

def build_freq_table(data: np.ndarray) -> np.ndarray:
    """Normalized (sum=4096) add-1-smoothed byte frequency table."""
    counts = np.bincount(data.reshape(-1), minlength=256).astype(np.float64)
    counts += 1.0
    freq = np.floor(counts * (PROB_SCALE - 256) / counts.sum()).astype(
        np.int64) + 1
    # fix rounding so the table sums exactly to PROB_SCALE
    diff = PROB_SCALE - int(freq.sum())
    if diff != 0:
        # add/remove from the most frequent symbols (keeps all >= 1)
        order = np.argsort(-freq)
        i = 0
        step = 1 if diff > 0 else -1
        while diff != 0:
            s = order[i % 256]
            if freq[s] + step >= 1:
                freq[s] += step
                diff -= step
            i += 1
    return freq.astype(np.uint16)


def entropy_bits(data: np.ndarray) -> float:
    """Shannon bound in bits for `data` under its empirical distribution."""
    counts = np.bincount(data.reshape(-1), minlength=256).astype(np.float64)
    p = counts / max(counts.sum(), 1)
    nz = p > 0
    return float(-(counts[nz] * np.log2(p[nz])).sum())


# ---------------------------------------------------------------------------
# Encode
# ---------------------------------------------------------------------------

def encode(data: np.ndarray, lanes: int = DEFAULT_LANES) -> bytes:
    """Encode uint8 array -> bytes (losslessly decodable with `decode`)."""
    data = np.ascontiguousarray(data, dtype=np.uint8).reshape(-1)
    n = data.size
    freq = build_freq_table(data)
    cum = np.zeros(257, np.uint32)
    cum[1:] = np.cumsum(freq.astype(np.uint32))

    lanes = max(1, min(lanes, 1 << 15))
    rounds = -(-max(n, 1) // lanes)
    pad = rounds * lanes - n
    # pad with symbol 0 (freq >= 1 by smoothing); count stored in header
    padded = np.concatenate([data, np.zeros(pad, np.uint8)])
    grid = padded.reshape(rounds, lanes)

    f64 = freq.astype(np.uint64)
    c64 = cum.astype(np.uint64)
    x = np.full(lanes, RANS_L, np.uint64)
    chunks = []  # per-round emitted u32 words (lane order), reverse order
    shift32 = np.uint64(32)
    shiftp = np.uint64(PROB_BITS)

    for r in range(rounds - 1, -1, -1):
        syms = grid[r]
        f = f64[syms]
        c = c64[syms]
        x_max = ((RANS_L >> shiftp) << shift32) * f
        m = x >= x_max
        if m.any():
            chunks.append((x[m] & MASK32).astype(np.uint32))
            x = np.where(m, x >> shift32, x)
        x = ((x // f) << shiftp) + (x % f) + c

    words = (np.concatenate(chunks[::-1]) if chunks
             else np.zeros(0, np.uint32))
    head = np.zeros(1, np.uint8)
    head[0] = int(np.log2(lanes)) if lanes & (lanes - 1) == 0 else 255
    out = bytearray()
    out += head.tobytes()
    out += np.uint32(lanes).tobytes()
    out += np.uint32(n).tobytes()
    out += freq.tobytes()
    out += np.uint32(words.size).tobytes()
    out += words.tobytes()
    out += x.tobytes()
    return bytes(out)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class StreamDecoder:
    """Incremental rANS decoder: call ``read(n)`` repeatedly.

    Memory held: lane states + the (mmap-able) word stream; suitable for
    frame-wise decoding where only a frame's worth of symbols is
    materialized at a time.
    """

    def __init__(self, blob: bytes):
        buf = memoryview(blob)
        self.lanes = int(np.frombuffer(buf[1:5], np.uint32)[0])
        self.n = int(np.frombuffer(buf[5:9], np.uint32)[0])
        freq = np.frombuffer(buf[9:9 + 512], np.uint16).astype(np.uint64)
        off = 9 + 512
        n_words = int(np.frombuffer(buf[off:off + 4], np.uint32)[0])
        off += 4
        self.words = np.frombuffer(buf[off:off + 4 * n_words], np.uint32)
        off += 4 * n_words
        self.x = np.frombuffer(buf[off:off + 8 * self.lanes],
                               np.uint64).copy()
        self.freq = freq
        self.cum = np.zeros(257, np.uint64)
        self.cum[1:] = np.cumsum(freq)
        self.sym_of = np.zeros(PROB_SCALE, np.uint8)
        for s in range(256):
            if freq[s]:
                self.sym_of[int(self.cum[s]):int(self.cum[s + 1])] = s
        self.wpos = 0
        self.spos = 0  # symbols emitted so far
        self._leftover = np.zeros(0, np.uint8)

    def read(self, count: int) -> np.ndarray:
        count = min(count, self.n - self.spos + self._leftover.size)
        chunks = [self._leftover]
        got = self._leftover.size
        maskp = np.uint64(PROB_SCALE - 1)
        shiftp = np.uint64(PROB_BITS)
        shift32 = np.uint64(32)
        x, words = self.x, self.words
        while got < count and self.spos < self.n:
            slot = x & maskp
            syms = self.sym_of[slot]
            f = self.freq[syms]
            c = self.cum[syms.astype(np.int64)]
            x = f * (x >> shiftp) + slot - c
            m = x < RANS_L
            k = int(m.sum())
            if k:
                refill = words[self.wpos:self.wpos + k].astype(np.uint64)
                self.wpos += k
                x[m] = (x[m] << shift32) | refill
            take = min(self.lanes, self.n - self.spos)
            chunks.append(syms[:take])
            self.spos += take
            got += take
        self.x = x
        flat = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
        out, self._leftover = flat[:count], flat[count:]
        return out


def decode(blob: bytes, max_symbols: int = -1) -> np.ndarray:
    """Decode; `max_symbols` >= 0 stops early (streaming/frame-wise use)."""
    dec = StreamDecoder(blob)
    n = dec.n if max_symbols < 0 else min(dec.n, max_symbols)
    return dec.read(n)


# ---------------------------------------------------------------------------
# Size estimate (exact coded size without running the coder; used by the
# layout search where only relative sizes matter)
# ---------------------------------------------------------------------------

def coded_size_bound(data: np.ndarray) -> int:
    """Static-table cross-entropy size in bytes + header overhead."""
    return int(np.ceil(entropy_bits(data) / 8)) + 512 + 17 + 8 * 4
