"""KVFetcher core: the paper's contribution (codec + efficient fetcher)."""
from repro.core.codec import CodecOptions, KVCodec  # noqa: F401
from repro.core.chunks import (  # noqa: F401
    KVManifest, encode_prefix, decode_chunk_tokens,
    encode_state_snapshot, decode_state_snapshot, prefix_key,
)
from repro.core.adaptive import (  # noqa: F401
    TABLES, BandwidthEstimator, DecodeTable, select_resolution,
)
from repro.core.scheduler import (  # noqa: F401
    FetchingAwareScheduler, ReqState, Request,
)
from repro.core.pipelining import max_admission_buffer, non_blocking_ok  # noqa: F401
from repro.core.fetch import FetchPlan, build_plan, synthetic_plan  # noqa: F401
from repro.core.fetch_controller import (  # noqa: F401
    ActiveFetch, FetchController, FetchHooks, PipelineConfig,
)
