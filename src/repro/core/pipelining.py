"""Layer-wise fetching-inference pipeline admission (paper Appx. A.3).

A fetching request may enter the running queue before all its layers'
KV has been restored iff, for every unbuffered layer k,

    sum_{j<=k} T_decode(j)  <=  sum_{j<=k-1} T_comp(j)

i.e. layer k's KV is ready just before the engine finishes computing layer
k-1 — no execution stall. Chunked prefill makes T_comp predictable.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def non_blocking_ok(decode_times: Sequence[float],
                    comp_times: Sequence[float],
                    buffered_layers: int) -> bool:
    """True if early admission causes no pipeline stall."""
    d = np.asarray(decode_times, np.float64)
    c = np.asarray(comp_times, np.float64)
    L = d.size
    assert c.size == L
    if buffered_layers >= L:
        return True
    dec_cum = np.cumsum(d)
    comp_cum = np.concatenate([[0.0], np.cumsum(c)[:-1]])  # sum_{j<=k-1}
    ks = np.arange(buffered_layers, L)  # 0-based k
    return bool((dec_cum[ks] <= comp_cum[ks]).all())


def max_admission_buffer(decode_times: Sequence[float],
                         comp_times: Sequence[float]) -> int:
    """Smallest L_buf satisfying the non-blocking condition."""
    L = len(decode_times)
    for lb in range(L + 1):
        if non_blocking_ok(decode_times, comp_times, lb):
            return lb
    return L
