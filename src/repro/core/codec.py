"""KVCodec: quantized KV chunk [T, nl<=3, H, D] <-> compressed video chunk.

Pipeline (encode): intra-frame tiling -> inter-frame frame packing ->
per-plane prediction mode decision -> zigzag -> per-channel rANS streams.
Everything after quantization is bit-exact invertible.

Wire format:
  magic "KVF1" | u16 version | u16 T | u16 n_layers | u16 H | u16 D |
  u16 hr | u16 dr | u8 res_id | u8 pad | u32 F |
  modes (F*3 u8) | 3 x (u32 len | stream)

Residual symbols are frame-major per channel, so ``iter_decode_frames``
can entropy-decode incrementally and reconstruct frame-by-frame with a
single reference frame — the frame-wise restoration memory property.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import entropy
from repro.core.layout import (
    RESOLUTION_ORDER,
    FrameGeometry,
    IntraLayout,
    frame_geometry,
    intra_candidates,
    pack_frames,
    unpack_frames,
    unpack_single_frame,
)
from repro.core.prediction import (
    ZIGZAG,
    predict_decode,
    predict_decode_frame,
    predict_encode,
)

MAGIC = b"KVF1"
_HDR = struct.Struct("<4sHHHHHHHBBI")


@dataclasses.dataclass(frozen=True)
class CodecOptions:
    lanes: int = 256
    allow_temporal: bool = True
    allow_intra: bool = True


@dataclasses.dataclass
class ChunkInfo:
    T: int
    n_layers: int
    H: int
    D: int
    layout: IntraLayout
    resolution: str
    geom: FrameGeometry


class KVCodec:
    """Codec for one architecture's KV geometry (H heads x D dims)."""

    def __init__(self, H: int, D: int,
                 layout: Optional[IntraLayout] = None,
                 options: CodecOptions = CodecOptions()):
        self.H, self.D = H, D
        self.layout = layout or IntraLayout(H, D, H, 1)  # identity-ish
        self.options = options

    # -- layout search (paper Fig. 14; offline, input-agnostic) ---------
    def search_layout(self, sample_q: np.ndarray,
                      resolution: str = "1080p",
                      log: Optional[list] = None) -> IntraLayout:
        """Pick the intra layout minimizing predicted+entropy-coded size
        over the O(log H x log D) candidate grid."""
        from repro.core.layout import layout_fits
        best, best_cost = None, None
        for cand in intra_candidates(self.H, self.D):
            if not layout_fits(cand, resolution):
                if log is not None:
                    log.append((cand.hr, cand.dr, float("inf")))
                continue
            cost = self._layout_cost(sample_q, cand, resolution)
            if log is not None:
                log.append((cand.hr, cand.dr, cost))
            if best_cost is None or cost < best_cost:
                best, best_cost = cand, cost
        self.layout = best
        return best

    def _layout_cost(self, q: np.ndarray, lay: IntraLayout,
                     resolution: str) -> int:
        q3 = _to_3ch(q)
        geom = frame_geometry(q3.shape[0], lay, resolution)
        video = pack_frames(q3, lay, geom)
        zres, _ = predict_encode(video, self.options.allow_temporal,
                                 self.options.allow_intra)
        return entropy.coded_size_bound(zres)

    # -- encode ----------------------------------------------------------
    def encode_chunk(self, q: np.ndarray, resolution: str) -> bytes:
        """q [T, nl<=3, H, D] uint8 -> chunk bytes."""
        T, nl, H, D = q.shape
        assert (H, D) == (self.H, self.D) and nl <= 3
        q3 = _to_3ch(q)
        lay = self.layout
        geom = frame_geometry(T, lay, resolution)
        video = pack_frames(q3, lay, geom)
        zres, modes = predict_encode(video, self.options.allow_temporal,
                                     self.options.allow_intra)
        out = bytearray()
        out += _HDR.pack(MAGIC, 1, T, nl, H, D, lay.hr, lay.dr,
                         RESOLUTION_ORDER.index(resolution), 0,
                         geom.n_frames)
        out += modes.tobytes()
        # two entropy contexts per channel (the CABAC-context analogue):
        # I-planes (raw/left) and P-planes (temporal) have very different
        # statistics; mixing them in one table costs ~0.5 bits/symbol.
        from repro.core.prediction import MODE_TEMPORAL
        for c in range(3):
            is_p = modes[:, c] == MODE_TEMPORAL
            i_syms = zres[~is_p, :, :, c].reshape(-1)
            p_syms = zres[is_p, :, :, c].reshape(-1)
            for syms in (i_syms, p_syms):
                stream = entropy.encode(syms, self.options.lanes)
                out += struct.pack("<I", len(stream))
                out += stream
        return bytes(out)

    # -- decode ----------------------------------------------------------
    def _parse(self, blob: bytes):
        magic, ver, T, nl, H, D, hr, dr, res_id, _, F = _HDR.unpack_from(
            blob, 0)
        assert magic == MAGIC and ver == 1
        lay = IntraLayout(H, D, hr, dr)
        resolution = RESOLUTION_ORDER[res_id]
        geom = frame_geometry(T, lay, resolution)
        assert geom.n_frames == F
        off = _HDR.size
        modes = np.frombuffer(blob, np.uint8, F * 3, off).reshape(F, 3)
        off += F * 3
        streams = []  # [(i_stream, p_stream)] per channel
        for _ in range(3):
            pair = []
            for _ in range(2):
                (ln,) = struct.unpack_from("<I", blob, off)
                off += 4
                pair.append(blob[off:off + ln])
                off += ln
            streams.append(tuple(pair))
        return ChunkInfo(T, nl, H, D, lay, resolution, geom), modes, streams

    def decode_chunk(self, blob: bytes) -> np.ndarray:
        """chunk bytes -> q [T, nl, H, D] uint8 (bulk path)."""
        info, modes, streams = self._parse(blob)
        from repro.core.prediction import MODE_TEMPORAL
        fh, fw, _ = info.geom.frame_shape
        zres = np.empty((info.geom.n_frames, fh, fw, 3), np.uint8)
        for c in range(3):
            is_p = modes[:, c] == MODE_TEMPORAL
            i_dec = entropy.decode(streams[c][0])
            p_dec = entropy.decode(streams[c][1])
            zres[~is_p, :, :, c] = i_dec.reshape(-1, fh, fw)
            zres[is_p, :, :, c] = p_dec.reshape(-1, fh, fw)
        video = predict_decode(zres, modes)
        q3 = unpack_frames(video, info.layout, info.geom)
        return q3[:, :info.n_layers]

    def iter_decode_frames(self, blob: bytes
                           ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Frame-wise decode: yields (token_ids, q [n, nl, H, D]).

        Holds only one reference frame + one residual frame in memory
        (per channel) — the decompress-buffer bound of §3.3.2.
        """
        info, modes, streams = self._parse(blob)
        from repro.core.prediction import MODE_TEMPORAL
        fh, fw, _ = info.geom.frame_shape
        fsz = fh * fw
        decoders = [(entropy.StreamDecoder(si), entropy.StreamDecoder(sp))
                    for si, sp in streams]
        prev = None
        for f in range(info.geom.n_frames):
            zres_f = np.empty((fh, fw, 3), np.uint8)
            for c in range(3):
                which = 1 if modes[f, c] == MODE_TEMPORAL else 0
                zres_f[:, :, c] = decoders[c][which].read(fsz).reshape(fh, fw)
            frame = predict_decode_frame(zres_f, modes[f], prev)
            prev = frame
            toks, qt = unpack_single_frame(frame, info.layout, info.geom, f)
            yield toks, qt[:, :info.n_layers]

    def frame_count(self, blob: bytes) -> int:
        info, _, _ = self._parse(blob)
        return info.geom.n_frames


def _to_3ch(q: np.ndarray) -> np.ndarray:
    """Zero-pad the layer axis to 3 (channels code independently)."""
    T, nl = q.shape[:2]
    if nl == 3:
        return q
    pad = np.zeros((T, 3 - nl) + q.shape[2:], np.uint8)
    return np.concatenate([q, pad], axis=1)
