"""Predictive coding over video frames (the H.265 lossless analogue).

Per (frame, channel) plane we pick the cheapest prediction mode by entropy
estimate — TEMPORAL (previous frame, i.e. the paper's inter-frame
prediction along the token axis), LEFT (intra-frame left-neighbor), or RAW
(I-plane) — and emit mod-256 residuals plus a mode map. All modes are
bit-exact invertible. Residuals are zigzag-mapped so small +/- deltas land
on small byte values for the entropy coder.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

MODE_RAW = 0
MODE_TEMPORAL = 1
MODE_LEFT = 2
MODE_NAMES = {0: "raw", 1: "temporal", 2: "left"}

# zigzag LUT: interpret byte as signed delta in [-128, 127], interleave
_s = ((np.arange(256) + 128) % 256).astype(np.int16) - 128
ZIGZAG = np.where(_s >= 0, 2 * _s, -2 * _s - 1).astype(np.uint8)
UNZIGZAG = np.zeros(256, np.uint8)
UNZIGZAG[ZIGZAG] = np.arange(256, dtype=np.uint8)


def _left_residual(plane: np.ndarray) -> np.ndarray:
    r = plane.copy()
    r[:, 1:] = plane[:, 1:] - plane[:, :-1]
    return r


def _left_reconstruct(res: np.ndarray) -> np.ndarray:
    # cumulative sum mod 256 along width
    return np.cumsum(res.astype(np.uint64), axis=1).astype(np.uint8)


def _cost(res: np.ndarray) -> float:
    """Entropy proxy of a residual plane (bits)."""
    z = ZIGZAG[res]
    counts = np.bincount(z.reshape(-1), minlength=256).astype(np.float64)
    p = counts / counts.sum()
    nz = p > 0
    return float(-(counts[nz] * np.log2(p[nz])).sum())


def predict_encode(video: np.ndarray,
                   allow_temporal: bool = True,
                   allow_intra: bool = True
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """video [F, H, W, 3] uint8 -> (zigzagged residuals, modes [F, 3])."""
    F, H, W, C = video.shape
    res = np.empty_like(video)
    modes = np.zeros((F, C), np.uint8)
    for f in range(F):
        for c in range(C):
            plane = video[f, :, :, c]
            cands = [(MODE_RAW, plane)]
            if allow_intra:
                cands.append((MODE_LEFT, _left_residual(plane)))
            if allow_temporal and f > 0:
                cands.append((MODE_TEMPORAL, plane - video[f - 1, :, :, c]))
            best = min(cands, key=lambda mr: _cost(mr[1]))
            modes[f, c] = best[0]
            res[f, :, :, c] = best[1]
    return ZIGZAG[res], modes


def predict_decode(zres: np.ndarray, modes: np.ndarray) -> np.ndarray:
    """Inverse of predict_encode."""
    res = UNZIGZAG[zres]
    F, H, W, C = res.shape
    video = np.empty_like(res)
    for f in range(F):
        for c in range(C):
            m = modes[f, c]
            if m == MODE_RAW:
                video[f, :, :, c] = res[f, :, :, c]
            elif m == MODE_LEFT:
                video[f, :, :, c] = _left_reconstruct(res[f, :, :, c])
            else:  # TEMPORAL: reference frame is the previous decoded frame
                video[f, :, :, c] = video[f - 1, :, :, c] + res[f, :, :, c]
    return video


def predict_decode_frame(zres_f: np.ndarray, modes_f: np.ndarray,
                         prev_frame) -> np.ndarray:
    """Single-frame inverse (frame-wise restoration path).

    zres_f [H, W, 3]; prev_frame [H, W, 3] or None. Memory: one reference
    frame — this is the <=4-reference-frames / frame-wise-buffer property.
    """
    res = UNZIGZAG[zres_f]
    out = np.empty_like(res)
    for c in range(res.shape[-1]):
        m = modes_f[c]
        if m == MODE_RAW:
            out[:, :, c] = res[:, :, c]
        elif m == MODE_LEFT:
            out[:, :, c] = _left_reconstruct(res[:, :, c])
        else:
            assert prev_frame is not None
            out[:, :, c] = prev_frame[:, :, c] + res[:, :, c]
    return out
