"""Codec-friendly tensor layout (paper §3.2).

Inter-frame layout: a KV chunk is T token-slices of 3 layers; token t maps
to frame ``t % F`` at slot ``t // F`` so consecutive tokens occupy the same
spatial position in consecutive frames (maximal temporal redundancy), and
the 3 layers map to the 3 independently-coded color channels.

Intra-frame layout: per token/layer the [H, D] matrix is tiled as
``(hr, hc) x (dr, dc)`` with ``hr*hc == H``, ``dr*dc == D`` — head blocks
stay contiguous (rule i), within-head element order is preserved (rule ii),
head order is untouched (rule iii), so the search space is the
O(log H x log D) grid of power-of-two splits (paper Fig. 14).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np

# (height, width) pixel budgets; names follow the paper's presets.
RESOLUTIONS: Dict[str, Tuple[int, int]] = {
    "240p": (240, 432),
    "480p": (480, 854),
    "640p": (640, 960),
    "1080p": (1080, 1920),
}
RESOLUTION_ORDER = ("240p", "480p", "640p", "1080p")


@dataclasses.dataclass(frozen=True)
class IntraLayout:
    """Power-of-two split of (H, D) into a (hr*dr, hc*dc) tile."""
    H: int
    D: int
    hr: int  # head rows   (hc = H // hr heads per row)
    dr: int  # dim rows    (dc = D // dr dims per row)

    @property
    def hc(self) -> int:
        return self.H // self.hr

    @property
    def dc(self) -> int:
        return self.D // self.dr

    @property
    def tile(self) -> Tuple[int, int]:
        return self.hr * self.dr, self.hc * self.dc


def pow2_divisors(n: int) -> List[int]:
    out = [1]
    d = 2
    while n % d == 0:
        out.append(d)
        d *= 2
    return out


def intra_candidates(H: int, D: int) -> List[IntraLayout]:
    """The O(log H x log D) candidate grid of rules i-iii."""
    return [IntraLayout(H, D, hr, dr)
            for hr in pow2_divisors(H) for dr in pow2_divisors(D)]


def tile_forward(x: np.ndarray, lay: IntraLayout) -> np.ndarray:
    """[..., H, D] -> [..., hr*dr, hc*dc]."""
    lead = x.shape[:-2]
    x = x.reshape(lead + (lay.hr, lay.hc, lay.dr, lay.dc))
    x = np.moveaxis(x, -3, -2)  # -> [..., hr, dr, hc, dc]
    return x.reshape(lead + (lay.hr * lay.dr, lay.hc * lay.dc))


def tile_inverse(t: np.ndarray, lay: IntraLayout) -> np.ndarray:
    lead = t.shape[:-2]
    t = t.reshape(lead + (lay.hr, lay.dr, lay.hc, lay.dc))
    t = np.moveaxis(t, -2, -3)
    return t.reshape(lead + (lay.H, lay.D))


# ---------------------------------------------------------------------------
# Frame packing (inter-frame layout)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FrameGeometry:
    resolution: str
    tile: Tuple[int, int]
    grid: Tuple[int, int]  # tiles per frame (gh, gw)
    n_frames: int
    n_tokens: int

    @property
    def slots_per_frame(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def frame_shape(self) -> Tuple[int, int, int]:
        th, tw = self.tile
        return self.grid[0] * th, self.grid[1] * tw, 3

    def token_of(self, frame: int, slot: int) -> int:
        return slot * self.n_frames + frame

    def tokens_in_frame(self, frame: int) -> np.ndarray:
        toks = frame + self.n_frames * np.arange(self.slots_per_frame)
        return toks[toks < self.n_tokens]


def layout_fits(lay: IntraLayout, resolution: str) -> bool:
    fh, fw = RESOLUTIONS[resolution]
    th, tw = lay.tile
    return th <= fh and tw <= fw


def frame_geometry(n_tokens: int, lay: IntraLayout,
                   resolution: str) -> FrameGeometry:
    """Frame geometry for a chunk: F frames on a (gh, gw) tile grid.

    The grid is cropped to the slots actually used, so short chunks don't
    pay entropy/transmission for padding pixels (a real encoder would crop
    the canvas the same way; decode-latency tables key on the resolution
    preset, i.e. the upper bound).
    """
    fh, fw = RESOLUTIONS[resolution]
    th, tw = lay.tile
    gh, gw = max(fh // th, 1), max(fw // tw, 1)
    slots = gh * gw
    n_frames = max(1, -(-n_tokens // slots))
    used = -(-n_tokens // n_frames)  # slots needed per frame
    gw = min(gw, used)
    gh = -(-used // gw)
    return FrameGeometry(resolution, (th, tw), (gh, gw), n_frames, n_tokens)


def pack_frames(q_chunk: np.ndarray, lay: IntraLayout,
                geom: FrameGeometry) -> np.ndarray:
    """q_chunk [T, 3, H, D] uint8 -> video [F, FH, FW, 3] uint8."""
    T = q_chunk.shape[0]
    F = geom.n_frames
    gh, gw = geom.grid
    th, tw = geom.tile
    slots = gh * gw
    tiles = tile_forward(q_chunk, lay)  # [T, 3, th, tw]
    pad = slots * F - T
    if pad:
        tiles = np.concatenate(
            [tiles, np.zeros((pad,) + tiles.shape[1:], np.uint8)], axis=0)
    # token t -> (slot=t//F, frame=t%F)
    tiles = tiles.reshape(slots, F, 3, th, tw)
    tiles = tiles.reshape(gh, gw, F, 3, th, tw)
    video = tiles.transpose(2, 0, 4, 1, 5, 3)  # [F, gh, th, gw, tw, 3]
    return np.ascontiguousarray(
        video.reshape(F, gh * th, gw * tw, 3))


def unpack_frames(video: np.ndarray, lay: IntraLayout,
                  geom: FrameGeometry) -> np.ndarray:
    """Inverse of pack_frames -> [T, 3, H, D] uint8."""
    F = geom.n_frames
    gh, gw = geom.grid
    th, tw = geom.tile
    v = video.reshape(F, gh, th, gw, tw, 3)
    tiles = v.transpose(1, 3, 0, 5, 2, 4)  # [gh, gw, F, 3, th, tw]
    tiles = tiles.reshape(gh * gw * F, 3, th, tw)[:geom.n_tokens]
    return tile_inverse(tiles, lay)


def unpack_single_frame(frame: np.ndarray, lay: IntraLayout,
                        geom: FrameGeometry, frame_idx: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """One decoded frame -> (token_ids, q_tokens [n, 3, H, D]).

    This is the frame-wise restoration primitive: memory is one frame.
    """
    gh, gw = geom.grid
    th, tw = geom.tile
    v = frame.reshape(gh, th, gw, tw, 3)
    tiles = v.transpose(0, 2, 4, 1, 3).reshape(gh * gw, 3, th, tw)
    toks = geom.tokens_in_frame(frame_idx)
    slots = (toks - frame_idx) // geom.n_frames
    return toks, tile_inverse(tiles[slots], lay)


# ---------------------------------------------------------------------------
# Baseline layouts (for benchmark comparisons; see bench_slicing)
# ---------------------------------------------------------------------------

def layer_slice_frames(q: np.ndarray) -> np.ndarray:
    """llm.265-style: slice along layers; frame f = layers [3f, 3f+3) as
    [T, H*D, 3]."""
    T, L, H, D = q.shape
    L3 = (L // 3) * 3
    v = q[:, :L3].reshape(T, L3 // 3, 3, H * D)
    return np.ascontiguousarray(v.transpose(1, 0, 3, 2))  # [F, T, HD, 3]


def head_slice_frames(q: np.ndarray) -> np.ndarray:
    """Slice along heads: frame h = head h as [T, L*D] replicated to 3ch."""
    T, L, H, D = q.shape
    v = q.transpose(2, 0, 1, 3).reshape(H, T, L * D)
    return np.repeat(v[..., None], 3, axis=-1)


def token_stitched_single_frame(q_chunk: np.ndarray,
                                lay: IntraLayout) -> np.ndarray:
    """Fig. 12 baseline: all token tiles stitched spatially in ONE frame."""
    tiles = tile_forward(q_chunk, lay)  # [T, 3, th, tw]
    T = tiles.shape[0]
    cols = int(np.ceil(np.sqrt(T)))
    rows = -(-T // cols)
    th, tw = lay.tile
    out = np.zeros((1, rows * th, cols * tw, 3), np.uint8)
    for t in range(T):
        r, c = divmod(t, cols)
        out[0, r * th:(r + 1) * th, c * tw:(c + 1) * tw] = \
            tiles[t].transpose(1, 2, 0)
    return out
