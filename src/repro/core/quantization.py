"""CacheGen-style grouped integer quantization for KV tensors.

Per-(layer, head) symmetric int8 quantization stored as uint8 (offset 128).
This is the only lossy step in the pipeline (identical in spirit to
CacheGen/ShadowServe, as the paper states); everything downstream —
layout, prediction, entropy coding — is bit-exact.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

QOFF = 128


def quantize(kv: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """kv [T, L, H, D] float -> (q uint8 [T,L,H,D], scales fp32 [L,H])."""
    kv = np.asarray(kv, np.float32)
    absmax = np.abs(kv).max(axis=(0, 3))  # [L, H]
    scales = np.maximum(absmax, 1e-8) / 127.0
    q = np.clip(np.rint(kv / scales[None, :, :, None]), -127, 127)
    return (q + QOFF).astype(np.uint8), scales.astype(np.float32)


def dequantize(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Inverse of quantize (exact for the stored integers)."""
    return (q.astype(np.float32) - QOFF) * scales[None, :, :, None]


def quantize_jnp(kv, scales=None):
    """jnp variant for on-device use (kernels / restoration path)."""
    import jax.numpy as jnp
    kv = kv.astype(jnp.float32)
    if scales is None:
        absmax = jnp.abs(kv).max(axis=(0, 3))
        scales = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(kv / scales[None, :, :, None]), -127, 127)
    return (q + QOFF).astype(jnp.uint8), scales


def dequantize_jnp(q, scales):
    import jax.numpy as jnp
    return (q.astype(jnp.float32) - QOFF) * scales[None, :, :, None]
