"""Chunking and manifests: a model prefix's KV cache <-> a set of encoded
video chunks (paper §3.1: KV caches are chunked — 3 layers x token-chunk —
compressed offline in multiple resolutions, and registered as reusable).

Also covers the state-snapshot path for SSM / RG-LRU layers (DESIGN.md
§Arch-applicability): recurrent states have no token axis, so snapshots are
coded with intra-frame prediction + entropy only.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import entropy
from repro.core.codec import CodecOptions, KVCodec
from repro.core.layout import RESOLUTION_ORDER, IntraLayout
from repro.core.prediction import ZIGZAG, UNZIGZAG
from repro.core.quantization import dequantize, quantize

DEFAULT_TOKENS_PER_CHUNK = 10_000  # paper §4: 10K tokens x 3 layers


def prefix_key(token_ids: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(token_ids).tobytes()
                          ).hexdigest()[:16]


@dataclasses.dataclass
class ChunkRef:
    kind: str  # "k" | "v"
    group: int  # 3-layer group index
    chunk: int  # token-chunk index
    token_start: int
    token_end: int
    layers: Tuple[int, ...]  # absolute layer ids in the group

    @property
    def chunk_id(self) -> str:
        return f"{self.kind}.g{self.group}.c{self.chunk}"


@dataclasses.dataclass
class KVManifest:
    """All encoded artifacts for one reusable prefix."""
    prefix: str
    n_tokens: int
    layer_groups: List[Tuple[int, ...]]
    refs: List[ChunkRef]
    scales: Dict[str, np.ndarray]  # kind -> [L, H] fp32
    blobs: Dict[Tuple[str, str], bytes]  # (chunk_id, resolution) -> bytes
    state_blob: Optional[bytes] = None  # SSM/RG-LRU snapshot
    layout: Optional[Tuple[int, int]] = None

    def sizes(self, resolution: str) -> Dict[str, int]:
        return {r.chunk_id: len(self.blobs[(r.chunk_id, resolution)])
                for r in self.refs}

    def total_bytes(self, resolution: str) -> int:
        n = sum(self.sizes(resolution).values())
        if self.state_blob:
            n += len(self.state_blob)
        return n

    @property
    def resolutions(self) -> Tuple[str, ...]:
        return tuple(sorted({res for (_, res) in self.blobs},
                            key=RESOLUTION_ORDER.index))


def layer_groups_of(n_attn_layers: int) -> List[Tuple[int, ...]]:
    return [tuple(range(i, min(i + 3, n_attn_layers)))
            for i in range(0, n_attn_layers, 3)]


def encode_prefix(kv_k: np.ndarray, kv_v: np.ndarray, *,
                  prefix: str,
                  layout: Optional[IntraLayout] = None,
                  resolutions: Sequence[str] = ("240p", "480p", "1080p"),
                  tokens_per_chunk: int = DEFAULT_TOKENS_PER_CHUNK,
                  options: CodecOptions = CodecOptions(),
                  search_sample: int = 512) -> KVManifest:
    """kv_k/kv_v [T, L, H, D] float -> manifest with multi-res encodings."""
    T, L, H, D = kv_k.shape
    groups = layer_groups_of(L)
    codec = KVCodec(H, D, layout, options)
    qs, scales = {}, {}
    for kind, kv in (("k", kv_k), ("v", kv_v)):
        qs[kind], scales[kind] = quantize(kv)
    if layout is None:
        sample = qs["k"][:min(search_sample, T), :min(3, L)]
        codec.search_layout(sample, resolutions[0])

    refs: List[ChunkRef] = []
    blobs: Dict[Tuple[str, str], bytes] = {}
    n_chunks = max(1, -(-T // tokens_per_chunk))
    for kind in ("k", "v"):
        for g, layers in enumerate(groups):
            for ci in range(n_chunks):
                t0 = ci * tokens_per_chunk
                t1 = min(T, t0 + tokens_per_chunk)
                ref = ChunkRef(kind, g, ci, t0, t1, layers)
                refs.append(ref)
                q = qs[kind][t0:t1][:, list(layers)]
                for res in resolutions:
                    blobs[(ref.chunk_id, res)] = codec.encode_chunk(q, res)
    return KVManifest(prefix=prefix, n_tokens=T, layer_groups=groups,
                      refs=refs, scales=scales, blobs=blobs,
                      layout=(codec.layout.hr, codec.layout.dr))


def decode_chunk_tokens(manifest: KVManifest, chunk_id: str,
                        resolution: str, H: int, D: int) -> np.ndarray:
    """Bulk-decode one chunk back to dequantized float KV [t, nl, H, D]."""
    lay = IntraLayout(H, D, *manifest.layout)
    codec = KVCodec(H, D, lay)
    ref = next(r for r in manifest.refs if r.chunk_id == chunk_id)
    q = codec.decode_chunk(manifest.blobs[(chunk_id, resolution)])
    sc = manifest.scales[ref.kind][list(ref.layers)]  # [nl, H]
    return (q.astype(np.float32) - 128) * sc[None, :, :, None]


# ---------------------------------------------------------------------------
# Recurrent-state snapshots (SSM / RG-LRU prefix reuse)
# ---------------------------------------------------------------------------

def encode_state_snapshot(states: Dict[str, np.ndarray],
                          lanes: int = 256) -> bytes:
    """Flatten, per-tensor absmax-quantize, left-predict, entropy-code."""
    import struct
    out = bytearray()
    out += struct.pack("<I", len(states))
    for name in sorted(states):
        x = np.asarray(states[name], np.float32)
        absmax = max(float(np.abs(x).max()), 1e-8)
        scale = absmax / 127.0
        q = (np.clip(np.rint(x / scale), -127, 127) + 128).astype(np.uint8)
        flat = q.reshape(-1)
        res = flat.copy()
        res[1:] = flat[1:] - flat[:-1]
        stream = entropy.encode(ZIGZAG[res], lanes)
        nb = name.encode()
        out += struct.pack("<H", len(nb)) + nb
        out += struct.pack("<f", scale)
        out += struct.pack("<B", x.ndim)
        out += struct.pack(f"<{x.ndim}I", *x.shape)
        out += struct.pack("<I", len(stream)) + stream
    return bytes(out)


def decode_state_snapshot(blob: bytes) -> Dict[str, np.ndarray]:
    import struct
    off = 0
    (n,) = struct.unpack_from("<I", blob, off)
    off += 4
    out = {}
    for _ in range(n):
        (ln,) = struct.unpack_from("<H", blob, off)
        off += 2
        name = blob[off:off + ln].decode()
        off += ln
        (scale,) = struct.unpack_from("<f", blob, off)
        off += 4
        (nd,) = struct.unpack_from("<B", blob, off)
        off += 1
        shape = struct.unpack_from(f"<{nd}I", blob, off)
        off += 4 * nd
        (sl,) = struct.unpack_from("<I", blob, off)
        off += 4
        z = entropy.decode(blob[off:off + sl])
        off += sl
        res = UNZIGZAG[z]
        flat = np.cumsum(res.astype(np.uint64)).astype(np.uint8)
        out[name] = (flat.reshape(shape).astype(np.float32) - 128) * scale
    return out
