"""Fetching-aware scheduler (paper §3.3.1, Fig. 15).

A dedicated ``waiting_for_KV`` queue lives outside the engine's own
waiting/running queues. Each scheduling iteration:
  - requests that need remote KV move to waiting_for_KV and their fetch is
    started in the background (the engine never blocks on them);
  - non-reuse requests follow the engine's normal FCFS admission;
  - when a fetch completes (or the layer-wise condition of Appx A.3 allows
    early admission), the request re-enters the admission flow.

``policy="fetch_agnostic"`` reproduces the baseline HOL-blocking behaviour
(fetching requests sit at the head of the single FCFS queue and block
everyone behind them) for the Fig. 9 / Fig. 19 comparisons.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, Dict, List, Optional


class ReqState(enum.Enum):
    WAITING = "waiting"
    WAITING_FOR_KV = "waiting_for_kv"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    max_new_tokens: int = 64
    reuse_tokens: int = 0  # prefix tokens whose KV is fetched remotely
    prefix: Optional[str] = None  # manifest key when reuse_tokens > 0
    # Shared-link arbitration weight: under the "fair" policy this fetch
    # receives weight/total_weight of the link; under "drr" it is served
    # proportionally more bytes per round (see network.SharedLink).
    weight: float = 1.0
    # multi-tenant identity: owning user + SLO tier.  With a
    # FairScheduler wired (fairness= on either environment) the tier is
    # mapped to `weight` at arrival and all served cost is charged to
    # `user`'s virtual counter (docs/fairness.md).  None = single-tenant.
    user: Optional[str] = None
    slo_tier: Optional[str] = None

    state: ReqState = ReqState.WAITING
    # storage-tier resolution (set when a StorageCluster serves fetches):
    # "full" | "partial" | "miss"; on a partial hit reuse_tokens is
    # reduced to the resident ancestor's coverage and the original ask is
    # preserved in requested_reuse_tokens (the tail is recomputed).
    storage_hit: Optional[str] = None
    storage_node: Optional[str] = None
    requested_reuse_tokens: Optional[int] = None
    # cataloged key that missed (delayed write-on-miss): the environment
    # calls StorageCluster.notify_recompute_done(storage_miss_key) when
    # this request's fallback prefill reaches its first token.
    storage_miss_key: Optional[str] = None
    # fetch progress
    fetch_dispatched: bool = False  # scheduler handed it to the controller
    fetch_started: Optional[float] = None
    fetch_done: Optional[float] = None
    layers_ready: int = 0
    early_admitted: bool = False
    # serving progress
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    tokens_out: int = 0
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def needs_fetch(self) -> bool:
        return self.reuse_tokens > 0

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if len(self.token_times) < 2:
            return None
        spans = [b - a for a, b in zip(self.token_times, self.token_times[1:])]
        return sum(spans) / len(spans)


class FetchingAwareScheduler:
    # ``fairness`` (optional) is a cluster.fairness.FairScheduler: it
    # stamps tier weights at arrival, holds queued fetches in a
    # per-user backlog drained in lagging-user order through
    # take_fetches(), and charges served cost on admission /
    # fetch-completion (docs/fairness.md).  None keeps plain FCFS.
    def __init__(self, policy: str = "kvfetcher",
                 max_running: int = 8, fairness=None):
        assert policy in ("kvfetcher", "fetch_agnostic")
        assert fairness is None or policy == "kvfetcher", \
            "fairness= needs the kvfetcher policy (fetch_agnostic IS " \
            "the HOL-blocking FCFS baseline)"
        self.policy = policy
        self.max_running = max_running
        self.fairness = fairness
        self.waiting: Deque[Request] = deque()
        self.waiting_for_kv: Deque[Request] = deque()
        self.running: List[Request] = []
        self.fetch_requests: List[Request] = []  # fetches to start

    # -- intake ----------------------------------------------------------
    def submit(self, req: Request, now: float) -> None:
        req.state = ReqState.WAITING
        if self.fairness is not None:
            self.fairness.on_arrival(req)
        self.waiting.append(req)

    # -- background-fetch notifications -----------------------------------
    def notify_fetch_done(self, req: Request, now: float) -> None:
        req.fetch_done = now
        if self.fairness is not None:
            # wall-clock fallback: no byte meter, charge 0 but free the
            # slot.  The virtual-clock controller charges real wire
            # bytes *before* notifying, making this call a no-op there.
            self.fairness.on_fetch_done(req, 0.0)
        if req.state is ReqState.WAITING_FOR_KV:
            self.waiting_for_kv.remove(req)
            req.state = ReqState.WAITING
            self.waiting.appendleft(req)  # ready: head of admission queue

    def notify_early_admissible(self, req: Request, now: float) -> None:
        """Layer-wise pipeline condition satisfied (Appx A.3)."""
        if req.state is ReqState.WAITING_FOR_KV:
            self.waiting_for_kv.remove(req)
            req.early_admitted = True
            req.state = ReqState.WAITING
            self.waiting.appendleft(req)

    def notify_fetch_miss(self, req: Request, now: float) -> None:
        """Nothing (more) to fetch — the request falls back to a full
        prefill: a storage-tier miss, or a WAN transport abort after
        ``max_attempts`` exhausted.  It re-enters admission immediately
        (there is no fetch to wait for); under ``fetch_agnostic`` it
        simply stops blocking the queue head since ``needs_fetch`` turns
        False.  A transport abort keeps the request's original storage
        resolution (the tier DID hit; the network failed), so
        ``storage_hit``/``requested_reuse_tokens`` are only stamped when
        still unset.

        Resolution of a storage miss is the *delayed write-on-miss*
        hook: the environment watches for this request's first token and
        then calls ``StorageCluster.notify_recompute_done`` with
        ``req.storage_miss_key`` — the recomputed KV exists only from
        that moment, so the storage tier must not re-admit earlier."""
        if self.fairness is not None:
            # free the dispatch slot without charging (nothing moved on
            # the wire; a transport abort charged its partial delivery
            # already and this call is then a no-op)
            self.fairness.on_fetch_miss(req)
        if req.requested_reuse_tokens is None:
            req.requested_reuse_tokens = req.reuse_tokens
        req.reuse_tokens = 0
        if req.storage_hit is None:
            req.storage_hit = "miss"
        if req.state is ReqState.WAITING_FOR_KV:
            self.waiting_for_kv.remove(req)
            req.state = ReqState.WAITING
            self.waiting.appendleft(req)

    def finish(self, req: Request, now: float) -> None:
        req.state = ReqState.FINISHED
        req.t_finished = now
        if req in self.running:
            self.running.remove(req)

    # -- scheduling iteration ---------------------------------------------
    def schedule(self, now: float) -> List[Request]:
        """One iteration: returns requests newly admitted to running.

        Side effect: fills ``self.fetch_requests`` with fetches the caller
        (fetch controller) must start in the background.
        """
        admitted: List[Request] = []
        if self.policy == "kvfetcher":
            # move fetching requests out of the engine's admission path
            still: Deque[Request] = deque()
            for req in self.waiting:
                if req.needs_fetch and not req.fetch_dispatched:
                    req.fetch_dispatched = True
                    req.state = ReqState.WAITING_FOR_KV
                    self.waiting_for_kv.append(req)
                    if self.fairness is not None:
                        self.fairness.enqueue(req)  # fair backlog
                    else:
                        self.fetch_requests.append(req)
                else:
                    still.append(req)
            self.waiting = still
            while self.waiting and len(self.running) < self.max_running:
                req = self.waiting.popleft()
                req.state = ReqState.RUNNING
                req.t_admitted = now
                if self.fairness is not None:
                    self.fairness.on_admit(req)
                self.running.append(req)
                admitted.append(req)
        else:  # fetch_agnostic: single FCFS queue, HOL blocking
            for req in self.waiting:
                if req.needs_fetch and not req.fetch_dispatched:
                    req.fetch_dispatched = True
                    self.fetch_requests.append(req)
            while self.waiting and len(self.running) < self.max_running:
                head = self.waiting[0]
                if head.needs_fetch and head.fetch_done is None:
                    break  # head blocks everyone behind it
                self.waiting.popleft()
                head.state = ReqState.RUNNING
                head.t_admitted = now
                self.running.append(head)
                admitted.append(head)
        return admitted

    def take_fetches(self) -> List[Request]:
        if self.fairness is not None:
            # drain the fair backlog into free dispatch slots in
            # lagging-user order (slots are released on fetch
            # completion / miss / abort, so an abusive flood queues
            # here instead of monopolizing the link)
            self.fetch_requests.extend(self.fairness.take())
        out, self.fetch_requests = self.fetch_requests, []
        return out
