"""Shared model components: norms, RoPE, inits, losses."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_hint


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: Optional[int] = None,
               dtype=jnp.float32):
    """Truncated-normal-ish init scaled by fan-in."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
            ).astype(dt)


def squared_relu(x: jax.Array) -> jax.Array:
    r = jax.nn.relu(x)
    return r * r


# ---------------------------------------------------------------------------
# RoPE (llama-style half rotation)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., s, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., s, 1, hd/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy. logits [..., V] fp32-cast; labels int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


__all__ = ["dense_init", "embed_init", "rms_norm", "squared_relu",
           "rope_freqs", "apply_rope", "cross_entropy", "shard_hint"]
