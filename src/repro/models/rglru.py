"""Griffin / RecurrentGemma recurrent block with RG-LRU [arXiv:2402.19427].

Block:  x -> (W_x -> causal conv1d -> RG-LRU) * gelu(W_g x) -> W_o
RG-LRU: r_t = sigmoid(W_a u_t);  i_t = sigmoid(W_i u_t)
        log a_t = -c * softplus(Lambda) * r_t          (c = 8)
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Full-sequence path uses an associative scan (O(log s) depth); decode is a
single recurrence step.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, shard_hint

_C = 8.0


def init_rglru(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, w), d, dtype),
        "w_gate": dense_init(ks[1], (d, w), d, dtype),
        "conv": dense_init(ks[2], (4, w), 4, dtype),
        "w_a": dense_init(ks[3], (w, w), w, dtype),
        "w_i": dense_init(ks[4], (w, w), w, dtype),
        "lam": jnp.full((w,), 0.65, jnp.float32),  # softplus^-1-ish init
        "w_out": dense_init(ks[5], (w, d), w, dtype),
    }


RGLRU_PARAM_AXES = {
    "w_x": ("embed", "rglru_width"),
    "w_gate": ("embed", "rglru_width"),
    "conv": ("conv_k", "rglru_width"),
    "w_a": ("embed", "rglru_width"),
    "w_i": ("embed", "rglru_width"),
    "lam": ("rglru_width",),
    "w_out": ("rglru_width", "embed"),
}


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    w = cfg.rglru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, 3, w), dtype)}


RGLRU_CACHE_AXES = {"h": ("batch", "rglru_width"),
                    "conv": ("batch", "conv_k", "rglru_width")}


def linear_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None
                ) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1; returns all h_t.

    Implemented with jax.lax.associative_scan over (a, b) pairs.
    """
    if h0 is not None:
        # fold h0 into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _gates(p: dict, u: jax.Array):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wk->bsk", u, p["w_a"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wk->bsk", u, p["w_i"])
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * u.astype(jnp.float32))
    return a, gated_in


def apply_rglru_full(p: dict, x: jax.Array, cfg: ModelConfig,
                     with_cache: bool) -> Tuple[jax.Array, Optional[dict]]:
    """x [b, s, d]; full-sequence recurrence via associative scan."""
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"])
    u = shard_hint(u, ("batch", "seq", "rglru_width"))
    # causal depthwise conv, width 4
    w = p["conv"].shape[0]
    prev = jnp.zeros((u.shape[0], w - 1, u.shape[-1]), u.dtype)
    full = jnp.concatenate([prev, u], axis=1)
    u = sum(full[:, i:i + x.shape[1]] * p["conv"][i] for i in range(w))
    conv_state = full[:, -(w - 1):] if with_cache else None

    a, gated_in = _gates(p, u)
    h = linear_scan(a, gated_in)  # [b, s, w] fp32
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    out = jnp.einsum("bsw,wd->bsd", (h.astype(x.dtype) * gate), p["w_out"])
    if with_cache:
        return out, {"h": h[:, -1], "conv": conv_state}
    return out, None


def apply_rglru_decode(p: dict, x: jax.Array, cfg: ModelConfig,
                       cache: dict) -> Tuple[jax.Array, dict]:
    """x [b, 1, d] single-step recurrence."""
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"])  # [b,1,w]
    hist = jnp.concatenate([cache["conv"], u], axis=1)  # [b, 4, w]
    u = jnp.einsum("bwk,wk->bk", hist, p["conv"])[:, None]  # [b,1,w]
    new_conv = hist[:, 1:]
    a, gated_in = _gates(p, u)  # [b,1,w]
    h = a[:, 0] * cache["h"] + gated_in[:, 0]  # [b, w]
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))[:, 0]
    out = jnp.einsum("bw,wd->bd", h.astype(x.dtype) * gate, p["w_out"])
    return out[:, None], {"h": h, "conv": new_conv}
