"""Model composition: embeddings -> scanned layer stack -> head.

Layers are stacked and scanned (``lax.scan``) so even 96-layer configs lower
to compact HLO. Heterogeneous (hybrid) stacks scan over the repeating
``layer_pattern`` cycle with any remainder layers unrolled; an optional
unstacked prefix handles e.g. DeepSeekMoE's dense first layer.

Entry points:
  init_params(cfg, key, dtype)
  forward_full(params, cfg, tokens/embeds, ...)        -> logits (train path)
  prefill(params, cfg, tokens/embeds)                  -> (logits, cache)
  decode_step(params, cfg, token, pos, cache)          -> (logits, cache)
  init_cache(cfg, batch, seq_len, dtype)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import embed_init, dense_init, rms_norm, shard_hint

MAX_LEARNED_POS = 32_768  # hubert prefill_32k upper bound


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> Tuple[int, int, Tuple[str, ...]]:
    """(n_prefix_layers, n_cycles, rest_kinds)."""
    kinds = cfg.layer_kinds()
    n_prefix = 1 if cfg.first_layer_dense else 0
    body = kinds[n_prefix:]
    cl = len(cfg.layer_pattern)
    n_cycles = len(body) // cl
    rest = body[n_cycles * cl:]
    return n_prefix, n_cycles, rest


def _attn_window(cfg: ModelConfig) -> int:
    return cfg.sliding_window or cfg.local_window


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, kind: str, key, dtype,
                dense_mlp: bool = False) -> Dict[str, Any]:
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), dtype)}
    if kind == "attn":
        p["attn"] = attn_mod.init_attention(cfg, k1, dtype)
    elif kind == "rglru":
        p["rec"] = rglru_mod.init_rglru(cfg, k1, dtype)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(cfg, k1, dtype)
        return p  # mamba2 blocks have no separate MLP
    else:
        raise ValueError(kind)
    p["ln2"] = jnp.zeros((d,), dtype)
    if cfg.num_experts and not dense_mlp:
        p["moe"] = moe_mod.init_moe(cfg, k2, dtype)
    else:
        ff = cfg.dense_d_ff if (dense_mlp and cfg.dense_d_ff) else (
            cfg.d_ff if cfg.d_ff else 4 * d)
        p["mlp"] = mlp_mod.init_mlp(cfg.mlp_kind, d, ff, k2, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    n_prefix, n_cycles, rest = layer_plan(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), cfg.d_model, dtype)
    if cfg.rope_theta <= 0:
        params["pos_embed"] = embed_init(
            keys[2], (MAX_LEARNED_POS, cfg.d_model), dtype)
    if cfg.is_encoder:
        params["mask_embed"] = embed_init(keys[3], (cfg.d_model,), dtype)

    pattern = cfg.layer_pattern
    params["prefix"] = tuple(
        _init_layer(cfg, "attn", k, dtype, dense_mlp=True)
        for k in jax.random.split(keys[4], n_prefix)) if n_prefix else ()

    if n_cycles:
        def init_cycle(k):
            ks = jax.random.split(k, len(pattern))
            return {f"l{j}": _init_layer(cfg, kind, ks[j], dtype)
                    for j, kind in enumerate(pattern)}
        cycle_keys = jax.random.split(keys[5], n_cycles)
        params["cycles"] = jax.vmap(init_cycle)(cycle_keys)
    else:
        params["cycles"] = None

    params["rest"] = tuple(
        _init_layer(cfg, kind, k, dtype)
        for kind, k in zip(rest, jax.random.split(keys[6], max(len(rest), 1)))
    ) if rest else ()
    return params


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      dtype) -> Optional[dict]:
    if kind == "attn":
        spec = attn_mod.cache_spec(cfg, seq_len, local=cfg.local_window > 0)
        return attn_mod.init_kv_cache(cfg, batch, spec, dtype)
    if kind == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if kind == "rglru":
        return rglru_mod.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.float32) -> Dict[str, Any]:
    n_prefix, n_cycles, rest = layer_plan(cfg)
    pattern = cfg.layer_pattern
    mk = functools.partial(_init_layer_cache, cfg, batch=batch,
                           seq_len=seq_len, dtype=dtype)
    cache: Dict[str, Any] = {
        "prefix": tuple(mk(kind="attn") for _ in range(n_prefix)),
        "rest": tuple(mk(kind=k) for k in rest),
    }
    if n_cycles:
        one = {f"l{j}": mk(kind=kind) for j, kind in enumerate(pattern)}
        cache["cycles"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_cycles,) + x.shape), one)
    else:
        cache["cycles"] = None
    return cache


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer(kind: str, p: dict, x, cfg: ModelConfig, *, mode: str,
                 cache: Optional[dict], pos, positions,
                 token_cache_updates: bool = False
                 ) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    window = _attn_window(cfg)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = None
    if kind == "attn":
        causal = not cfg.is_encoder
        if mode == "full":
            out = attn_mod.attention_full(p["attn"], h, cfg, positions,
                                          window=window, causal=causal)
        elif mode == "prefill":
            cap = cache["k"].shape[1]
            # ring writes only needed when the prompt overflows the window
            spec = attn_mod.CacheSpec(cap, windowed=cap < positions.shape[-1])
            out, new_cache = attn_mod.attention_prefill(
                p["attn"], h, cfg, positions, cache, spec, causal=causal)
        else:  # decode
            # windowed slot/validity math is a no-op while pos < capacity,
            # so it is safe to use ring semantics whenever a window exists
            spec = attn_mod.CacheSpec(cache["k"].shape[1],
                                      windowed=window > 0)
            if token_cache_updates:
                # scanned layers: return only the new token's K/V; the
                # caller writes the stacked cache once outside the scan
                out, new_cache = attn_mod.attention_decode_token(
                    p["attn"], h, cfg, pos, cache, spec)
            else:
                out, new_cache = attn_mod.attention_decode(
                    p["attn"], h, cfg, pos, cache, spec)
    elif kind == "rglru":
        if mode == "decode":
            out, new_cache = rglru_mod.apply_rglru_decode(p["rec"], h, cfg,
                                                          cache)
        else:
            out, new_cache = rglru_mod.apply_rglru_full(
                p["rec"], h, cfg, with_cache=(mode == "prefill"))
    elif kind == "ssm":
        if mode == "decode":
            out, new_cache = ssm_mod.apply_ssm_decode(p["ssm"], h, cfg, cache)
        else:
            out, new_cache = ssm_mod.apply_ssm_full(
                p["ssm"], h, cfg, with_cache=(mode == "prefill"))
        x = x + out
        x = shard_hint(x, ("batch", "seq", "embed_act"))
        return x, new_cache, aux
    else:
        raise ValueError(kind)

    x = x + out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        if mode == "decode":
            b = h2.shape[0]
            out2, aux = moe_mod.apply_moe(
                p["moe"], h2.reshape(1, b, -1), cfg)
            out2 = out2.reshape(b, 1, -1)
        else:
            out2, aux = moe_mod.apply_moe(p["moe"], h2, cfg)
    else:
        out2 = mlp_mod.apply_mlp(p["mlp"], h2, cfg.mlp_kind)
    x = x + out2
    x = shard_hint(x, ("batch", "seq", "embed_act"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack runner
# ---------------------------------------------------------------------------

def _run_stack(params, cfg: ModelConfig, x, *, mode: str,
               cache: Optional[dict], pos, positions, remat: bool = False):
    pattern = cfg.layer_pattern
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {"prefix": [], "rest": [], "cycles": None}

    # --- prefix (unrolled) ---
    for i, lp in enumerate(params["prefix"]):
        c = cache["prefix"][i] if cache is not None else None
        x, nc, aux = _apply_layer("attn", lp, x, cfg, mode=mode, cache=c,
                                  pos=pos, positions=positions)
        aux_total += aux
        new_cache["prefix"].append(nc)

    # --- scanned cycles ---
    if params["cycles"] is not None:
        with_cache = cache is not None
        token_updates = mode == "decode"

        def body(carry, xs):
            xc, auxc = carry
            if with_cache:
                cyc_p, cyc_c = xs
            else:
                cyc_p, cyc_c = xs, None
            ncs = {}
            for j, kind in enumerate(pattern):
                cj = cyc_c[f"l{j}"] if with_cache else None
                xc, nc, a = _apply_layer(kind, cyc_p[f"l{j}"], xc, cfg,
                                         mode=mode, cache=cj, pos=pos,
                                         positions=positions,
                                         token_cache_updates=token_updates)
                auxc = auxc + a
                ncs[f"l{j}"] = nc if nc is not None else 0
            return (xc, auxc), (ncs if with_cache else 0)

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        xs = ((params["cycles"], cache["cycles"]) if with_cache
              else params["cycles"])
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
        if with_cache and token_updates:
            # merge: write each attn layer's new token K/V into the
            # stacked cache with ONE dynamic-update-slice per tensor
            window = _attn_window(cfg)
            merged = {}
            for j, kind in enumerate(pattern):
                old = cache["cycles"][f"l{j}"]
                if kind == "attn":
                    cap = old["k"].shape[2]
                    slot = (pos % cap) if window > 0 else pos
                    k_tok = ys[f"l{j}"]["k_tok"]  # [nc, b, 1, K, hd]
                    v_tok = ys[f"l{j}"]["v_tok"]
                    merged[f"l{j}"] = {
                        "k": jax.lax.dynamic_update_slice(
                            old["k"], k_tok, (0, 0, slot, 0, 0)),
                        "v": jax.lax.dynamic_update_slice(
                            old["v"], v_tok, (0, 0, slot, 0, 0)),
                    }
                else:
                    merged[f"l{j}"] = ys[f"l{j}"]
            new_cache["cycles"] = merged
        elif with_cache:
            new_cache["cycles"] = ys

    # --- rest (unrolled) ---
    _, n_cycles, rest = layer_plan(cfg)
    for i, kind in enumerate(rest):
        lp = params["rest"][i]
        c = cache["rest"][i] if cache is not None else None
        x, nc, aux = _apply_layer(kind, lp, x, cfg, mode=mode, cache=c,
                                  pos=pos, positions=positions)
        aux_total += aux
        new_cache["rest"].append(nc)

    new_cache["prefix"] = tuple(new_cache["prefix"])
    new_cache["rest"] = tuple(new_cache["rest"])
    return x, (new_cache if cache is not None else None), aux_total


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, tokens: Optional[jax.Array],
                 embeds: Optional[jax.Array], positions: jax.Array,
                 mask_positions: Optional[jax.Array] = None) -> jax.Array:
    parts = []
    if embeds is not None:
        e = embeds
        if cfg.is_encoder and mask_positions is not None:
            e = jnp.where(mask_positions[..., None],
                          params["mask_embed"].astype(e.dtype), e)
        parts.append(e)
    if tokens is not None:
        parts.append(params["embed"][tokens])
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions]
    return shard_hint(x, ("batch", "seq", "embed_act"))


def lm_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard_hint(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward_full(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                 mask_positions=None, remat: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train path). Returns (logits, moe_aux)."""
    b = (tokens if tokens is not None else embeds).shape[0]
    s = (0 if tokens is None else tokens.shape[1]) + \
        (0 if embeds is None else embeds.shape[1])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_inputs(params, cfg, tokens, embeds, positions, mask_positions)
    x, _, aux = _run_stack(params, cfg, x, mode="full", cache=None, pos=None,
                           positions=positions, remat=remat)
    return lm_logits(params, cfg, x), aux


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            cache: Optional[dict] = None, dtype=jnp.float32
            ) -> Tuple[jax.Array, dict]:
    """Process the full prompt, fill the cache, return last-pos logits."""
    b = (tokens if tokens is not None else embeds).shape[0]
    s = (0 if tokens is None else tokens.shape[1]) + \
        (0 if embeds is None else embeds.shape[1])
    if cache is None:
        cache = init_cache(cfg, b, s, dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_inputs(params, cfg, tokens, embeds, positions)
    x, new_cache, _ = _run_stack(params, cfg, x, mode="prefill", cache=cache,
                                 pos=None, positions=positions)
    return lm_logits(params, cfg, x[:, -1:, :]), new_cache


def decode_step(params, cfg: ModelConfig, token: jax.Array, pos,
                cache: dict) -> Tuple[jax.Array, dict]:
    """One decode step. token [b] int32; pos scalar int32 (next index)."""
    b = token.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = params["embed"][token][:, None, :]
    if "pos_embed" in params:
        x = x + params["pos_embed"][positions]
    x, new_cache, _ = _run_stack(params, cfg, x, mode="decode", cache=cache,
                                 pos=pos, positions=positions)
    return lm_logits(params, cfg, x)[:, 0], new_cache
