"""Mamba2 (SSD — state-space duality) block [arXiv:2405.21060].

Pure-JAX chunked SSD for train/prefill (matmul-rich: maps onto the MXU),
O(1)-state single-token decode. A Pallas kernel version of the chunked scan
lives in repro.kernels.ssd_scan.

Block dataflow (norm handled by the caller):
  in_proj -> [z | xBC | dt]; causal depthwise conv + silu over xBC;
  split xBC -> x, B, C;  dt = softplus(dt + bias);
  h_t = exp(dt_t A) h_{t-1} + dt_t * B_t (x)  (outer product per head)
  y_t = C_t . h_t + D * x_t
  out = out_proj( rmsnorm(y * silu(z)) )
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm, shard_hint


def init_ssm(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    G, S, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    convdim = din + 2 * G * S
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], (d, 2 * din + 2 * G * S + nh), d, dtype),
        "conv": dense_init(ks[1], (cfg.ssm_conv, convdim), cfg.ssm_conv,
                           dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.zeros((din,), dtype),
        "w_out": dense_init(ks[2], (din, d), din, dtype),
    }


SSM_PARAM_AXES = {
    "w_in": ("embed", "ssm_inner"),
    "conv": ("conv_k", "ssm_inner"),
    "A_log": ("ssm_heads",),
    "D": ("ssm_heads",),
    "dt_bias": ("ssm_heads",),
    "norm_w": ("ssm_inner",),
    "w_out": ("ssm_inner", "embed"),
}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    nh, hd, S = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    convdim = cfg.d_inner + 2 * cfg.ssm_ngroups * S
    return {
        "state": jnp.zeros((batch, nh, hd, S), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, convdim), dtype),
    }


SSM_CACHE_AXES = {
    "state": ("batch", "ssm_heads", None, "ssm_state"),
    "conv": ("batch", "conv_k", "ssm_inner"),
}


def _split_in(p: dict, x, cfg: ModelConfig):
    din, G, S, nh = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                     cfg.ssm_nheads)
    proj = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z = proj[..., :din]
    xBC = proj[..., din:2 * din + 2 * G * S]
    dt = proj[..., 2 * din + 2 * G * S:]
    return z, xBC, dt


def _conv_full(p: dict, xBC, prev: Optional[jax.Array]):
    """Causal depthwise conv over seq. prev: [b, w-1, convdim] history."""
    w = p["conv"].shape[0]
    if prev is None:
        prev = jnp.zeros((xBC.shape[0], w - 1, xBC.shape[-1]), xBC.dtype)
    full = jnp.concatenate([prev, xBC], axis=1)
    out = sum(full[:, i:i + xBC.shape[1]] * p["conv"][i]
              for i in range(w))
    return jax.nn.silu(out), full[:, -(w - 1):]


def _segsum(a_log: jax.Array) -> jax.Array:
    """a_log [..., q] -> [..., q, q] lower-tri cumulative log-decay."""
    q = a_log.shape[-1]
    cs = jnp.cumsum(a_log, axis=-1)
    # decay from j+1..i inclusive = cs[i] - cs[j]; strictly lower + diag 0
    dif = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, dif, -jnp.inf)


def ssd_chunked(xh, a_log, Bm, Cm, init_state=None, chunk: int = 128):
    """Chunked SSD.

    xh     [b, s, nh, hd]   (already multiplied by dt)
    a_log  [b, s, nh]       log decay per step (dt * A, negative)
    Bm, Cm [b, s, G, S]     (G broadcast over heads)
    returns y [b, s, nh, hd], final_state [b, nh, hd, S]
    """
    b, s, nh, hd = xh.shape
    G, S = Bm.shape[2], Bm.shape[3]
    assert nh % G == 0
    q = min(chunk, s)
    hpg = nh // G
    orig_s = s
    if s % q:  # pad to a chunk multiple; a_log=0, x=0 leaves state intact
        pad = q - s % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    c = s // q

    # large intra-chunk intermediates ([b,c,nh,q,q]) follow the input
    # dtype (bf16 in the production configs) — decay math stays fp32
    cdtype = xh.dtype
    xc = xh.reshape(b, c, q, nh, hd)
    ac = a_log.reshape(b, c, q, nh).astype(jnp.float32)
    Bc = Bm.reshape(b, c, q, G, S).astype(cdtype)
    Cc = Cm.reshape(b, c, q, G, S).astype(cdtype)

    acs = jnp.cumsum(ac, axis=2)  # [b,c,q,nh]
    # intra-chunk (diagonal) term
    L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2))).astype(cdtype)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)  # [b,c,G,q,q]
    scores = jnp.repeat(scores, hpg, axis=2)  # [b,c,nh,q,q]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", L * scores, xc,
                        preferred_element_type=jnp.float32)

    # per-chunk end states: input at t decays by exp(sum_{t+1..end} a)
    decay_to_end = jnp.exp(acs[:, :, -1:, :] - acs).astype(cdtype)
    Bh = jnp.repeat(Bc, hpg, axis=3)  # [b,c,q,nh,S]
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                        Bh, decay_to_end, xc,
                        preferred_element_type=jnp.float32)
    y, final = _ssd_inter(y_diag, states, acs, Cc, xc, init_state, hpg)
    return y[:, :orig_s], final


def _ssd_inter(y_diag, states, acs, Cc, xc, init_state, hpg):
    b, c, q, nh = acs.shape
    hd = xc.shape[-1]
    S = Cc.shape[-1]
    chunk_decay = jnp.exp(acs[:, :, -1, :])  # [b,c,nh]

    def step(h, inp):
        st, dec = inp  # st [b,nh,hd,S], dec [b,nh]
        h_prev = h
        h = h * dec[..., None, None] + st
        return h, h_prev

    if init_state is None:
        init_state = jnp.zeros((b, nh, hd, S), jnp.float32)
    # scan over chunks
    states_t = states.transpose(1, 0, 2, 3, 4)  # [c,b,nh,hd,S]
    decay_t = chunk_decay.transpose(1, 0, 2)  # [c,b,nh]
    final, h_prevs = jax.lax.scan(step, init_state, (states_t, decay_t))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # [b,c,nh,hd,S]

    # inter-chunk contribution: y_off[t] = C_t . (decay(0..t) * h_chunk_start)
    in_decay = jnp.exp(acs)  # decay from chunk start to t inclusive
    Ch = jnp.repeat(Cc, hpg, axis=3) if Cc.shape[3] != nh else Cc
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch,
                       h_prevs.astype(Ch.dtype),
                       in_decay.astype(jnp.float32).astype(Ch.dtype),
                       preferred_element_type=jnp.float32)
    y = (y_diag.astype(jnp.float32) + y_off).reshape(b, c * q, nh, hd)
    return y, final


def apply_ssm_full(p: dict, x, cfg: ModelConfig,
                   with_cache: bool) -> Tuple[jax.Array, Optional[dict]]:
    """Train (with_cache=False) or prefill (True) over a full sequence."""
    b, s, _ = x.shape
    G, S, nh, hd = (cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads,
                    cfg.ssm_head_dim)
    z, xBC, dt = _split_in(p, x, cfg)
    xBC, conv_state = _conv_full(p, xBC, None)
    xin = xBC[..., :cfg.d_inner]
    Bm = xBC[..., cfg.d_inner:cfg.d_inner + G * S].reshape(b, s, G, S)
    Cm = xBC[..., cfg.d_inner + G * S:].reshape(b, s, G, S)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [b,s,nh]
    A = -jnp.exp(p["A_log"])
    a_log = dt * A  # [b, s, nh]
    xh = xin.reshape(b, s, nh, hd)
    xh = shard_hint(xh, ("batch", "seq", "ssm_heads", None))
    xdt = (xh.astype(jnp.float32) * dt[..., None]).astype(xh.dtype)
    y, final = ssd_chunked(xdt, a_log, Bm, Cm, chunk=64)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    if with_cache:
        return out, {"state": final, "conv": conv_state}
    return out, None


def apply_ssm_decode(p: dict, x, cfg: ModelConfig,
                     cache: dict) -> Tuple[jax.Array, dict]:
    """x [b, 1, d] -> (out [b, 1, d], new cache)."""
    b = x.shape[0]
    G, S, nh, hd = (cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads,
                    cfg.ssm_head_dim)
    z, xBC, dt = _split_in(p, x, cfg)
    # conv over [history | current]
    w = p["conv"].shape[0]
    hist = jnp.concatenate([cache["conv"], xBC], axis=1)  # [b, w, convdim]
    conv_out = jnp.einsum("bwk,wk->bk", hist, p["conv"])[:, None]
    xBC = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:]

    xin = xBC[..., :cfg.d_inner]
    Bm = xBC[..., cfg.d_inner:cfg.d_inner + G * S].reshape(b, G, S)
    Cm = xBC[..., cfg.d_inner + G * S:].reshape(b, G, S)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [b,nh]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # [b, nh]
    xh_raw = xin.reshape(b, nh, hd).astype(jnp.float32)
    xh = xh_raw * dt[..., None]
    hpg = nh // G
    Bh = jnp.repeat(Bm, hpg, axis=1)  # [b, nh, S]
    Ch = jnp.repeat(Cm, hpg, axis=1)
    new_state = (cache["state"] * a[..., None, None]
                 + xh[..., None] * Bh[:, :, None, :].astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32))
    y = y + xh_raw * p["D"][None, :, None]  # skip uses raw x (no dt)
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    return out, {"state": new_state, "conv": new_conv}
