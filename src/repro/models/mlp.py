"""Feed-forward blocks: SwiGLU, squared-ReLU (Nemotron), GELU (HuBERT)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, shard_hint, squared_relu


def init_mlp(kind: str, d_model: int, d_ff: int, key,
             dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    if kind == "swiglu":
        return {"wi": dense_init(k1, (d_model, 2, d_ff), d_model, dtype),
                "wo": dense_init(k2, (d_ff, d_model), d_ff, dtype)}
    return {"wi": dense_init(k1, (d_model, d_ff), d_model, dtype),
            "wo": dense_init(k2, (d_ff, d_model), d_ff, dtype)}


def mlp_param_axes(kind: str) -> dict:
    if kind == "swiglu":
        return {"wi": ("embed", None, "mlp"), "wo": ("mlp", "embed")}
    return {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}


def apply_mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jnp.einsum("bsd,dcf->bscf", x, p["wi"])
        h = shard_hint(h, ("batch", "seq", None, "mlp"))
        h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"])
        h = shard_hint(h, ("batch", "seq", "mlp"))
        h = squared_relu(h) if kind == "squared_relu" else jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])
