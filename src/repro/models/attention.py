"""Attention: MHA/GQA/MQA, causal / bidirectional / sliding-window masks,
full-sequence (train/prefill) and single-token (decode) paths, with a
blocked (flash-style, online-softmax) implementation for long sequences.

Shapes
------
x            [b, s, d_model]
q            [b, s, H, hd]
k, v         [b, s, K, hd]      (K = num_kv_heads)
cache k/v    [b, S, K, hd]      (S = capacity; ring buffer when windowed)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init, shard_hint

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), d, dtype),
        "wk": dense_init(ks[1], (d, K, hd), d, dtype),
        "wv": dense_init(ks[2], (d, K, hd), d, dtype),
        "wo": dense_init(ks[3], (H, hd, d), H * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((K, hd), dtype)
        p["bv"] = jnp.zeros((K, hd), dtype)
    return p


PARAM_AXES = {
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "bq": ("heads", "head_dim"),
    "bk": ("kv_heads", "head_dim"),
    "bv": ("kv_heads", "head_dim"),
}


# ---------------------------------------------------------------------------
# Masking helpers
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int) -> jax.Array:
    """[..., q, k] additive bias. window==0 -> unwindowed."""
    dif = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(dif.shape, bool)
    if causal:
        ok &= dif >= 0
    if window > 0:
        ok &= dif < window
    return jnp.where(ok, 0.0, NEG_INF)


# ---------------------------------------------------------------------------
# Core attention (full sequence)
# ---------------------------------------------------------------------------

def _attend_naive(q, k, v, q_pos, k_pos, *, causal, window):
    b, s, H, hd = q.shape
    K = k.shape[2]
    g = H // K
    qg = q.reshape(b, s, K, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    logits = logits + _mask_bias(q_pos, k_pos, causal=causal,
                                 window=window)[:, None, None]
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, s, H, hd)


def _attend_blocked(q, k, v, q_pos, k_pos, *, causal, window,
                    block_q: int = 512, block_k: int = 1024):
    """Flash-style online-softmax attention, O(block) memory.

    Scans q blocks (outer) x kv blocks (inner). Padding handled by
    position-mask (padded q rows produce garbage that is sliced away).
    """
    b, s, H, hd = q.shape
    K = k.shape[2]
    g = H // K
    sk = k.shape[1]

    nq = -(-s // block_q)
    nk = -(-sk // block_k)
    pq = nq * block_q - s
    pk = nk * block_k - sk

    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=-1)
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, ((0, 0), (0, pk)), constant_values=2**30)

    qp = qp.reshape(b, nq, block_q, K, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qpos = qpos.reshape(b, nq, block_q).transpose(1, 0, 2)
    kp = kp.reshape(b, nk, block_k, K, hd).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(b, nk, block_k, K, hd).transpose(1, 0, 2, 3, 4)
    kpos = kpos.reshape(b, nk, block_k).transpose(1, 0, 2)

    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def q_step(_, qb):
        qblk, qposb = qb  # [b, Bq, K, g, hd], [b, Bq]

        def kv_step(carry, kb):
            m, l, acc = carry
            kblk, vblk, kposb = kb
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            bias = _mask_bias(qposb, kposb, causal=causal, window=window)
            logits = logits + bias[:, None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk)
            return (m_new, l, acc), None

        m0 = jnp.full((b, K, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, K, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, K, g, block_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kp, vp, kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4)  # [b, Bq, K, g, hd]

    _, outs = jax.lax.scan(q_step, None, (qp, qpos))  # [nq, b, Bq, K, g, hd]
    outs = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, H, hd)
    return outs[:, :s].astype(q.dtype)


def _attend_blocked_windowed(q, k, v, q_pos, k_pos, *, window: int,
                             block_q: int = 512, block_k: int = 1024):
    """Sliding-window attention with BLOCK SKIPPING: each q block visits
    only the ~(window+block_q)/block_k KV blocks that can intersect its
    window, instead of all of them — an O(s*window) algorithm rather than
    O(s^2) with masking. Requires aligned q/k positions (prefill)."""
    b, s, H, hd = q.shape
    K = k.shape[2]
    g = H // K
    sk = k.shape[1]
    nq = -(-s // block_q)
    nk = -(-sk // block_k)
    pq = nq * block_q - s
    pk = nk * block_k - sk

    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=-1)
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, ((0, 0), (0, pk)), constant_values=2**30)

    qp = qp.reshape(b, nq, block_q, K, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qpos = qpos.reshape(b, nq, block_q).transpose(1, 0, 2)
    kpb = kp.reshape(b, nk, block_k, K, hd).transpose(1, 0, 2, 3, 4)
    vpb = vp.reshape(b, nk, block_k, K, hd).transpose(1, 0, 2, 3, 4)
    kposb = kpos.reshape(b, nk, block_k).transpose(1, 0, 2)

    n_inner = (window + block_q) // block_k + 2
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def q_step(_, inp):
        qi, qblk, qposb = inp

        def kv_step(carry, j):
            m, l, acc = carry
            blk = (qi * block_q - window) // block_k + j
            blk_c = jnp.clip(blk, 0, nk - 1)
            ok_blk = (blk >= 0) & (blk <= nk - 1)
            kblk = jax.lax.dynamic_index_in_dim(kpb, blk_c, 0, False)
            vblk = jax.lax.dynamic_index_in_dim(vpb, blk_c, 0, False)
            kpos_j = jax.lax.dynamic_index_in_dim(kposb, blk_c, 0, False)
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            bias = _mask_bias(qposb, kpos_j, causal=True, window=window)
            bias = jnp.where(ok_blk, bias, NEG_INF)
            logits = logits + bias[:, None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l2 = l * alpha + p.sum(axis=-1)
            acc2 = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk)
            return (m_new, l2, acc2), None

        m0 = jnp.full((b, K, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, K, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, K, g, block_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(n_inner))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4)

    _, outs = jax.lax.scan(q_step, None,
                           (jnp.arange(nq), qp, qpos))
    outs = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, H, hd)
    return outs[:, :s].astype(q.dtype)


def attend(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
           blocked_threshold: int = 2048):
    big = q.shape[1] * k.shape[1] > blocked_threshold ** 2
    if big and causal and window > 0 and q.shape[1] == k.shape[1]:
        # beyond-paper: O(s*window) block-skip SWA instead of O(s^2)+mask
        return _attend_blocked_windowed(q, k, v, q_pos, k_pos,
                                        window=window)
    if big:
        return _attend_blocked(q, k, v, q_pos, k_pos, causal=causal,
                               window=window)
    return _attend_naive(q, k, v, q_pos, k_pos, causal=causal, window=window)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheSpec:
    capacity: int  # slots (== seq for full attn, window for SWA/local)
    windowed: bool


def cache_spec(cfg: ModelConfig, seq_len: int, *, local: bool) -> CacheSpec:
    window = cfg.local_window if local else cfg.sliding_window
    if window and window < seq_len:
        return CacheSpec(window, True)
    return CacheSpec(seq_len, False)


def init_kv_cache(cfg: ModelConfig, batch: int, spec: CacheSpec,
                  dtype=jnp.float32) -> dict:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, spec.capacity, K, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


CACHE_AXES = {"k": ("batch", "cache_seq", "kv_heads", "head_dim"),
              "v": ("batch", "cache_seq", "kv_heads", "head_dim")}


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _project_qkv(p: dict, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_hint(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_hint(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard_hint(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def attention_full(p: dict, x, cfg: ModelConfig, positions, *,
                   window: int, causal: bool) -> jax.Array:
    """Train / no-cache forward over a full sequence."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = attend(q, k, v, positions, positions, causal=causal, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_prefill(p: dict, x, cfg: ModelConfig, positions, cache: dict,
                      spec: CacheSpec, *, causal: bool = True
                      ) -> Tuple[jax.Array, dict]:
    """Full-seq forward that also fills the KV cache (ring when windowed)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    window = spec.capacity if spec.windowed else 0
    out = attend(q, k, v, positions, positions, causal=causal, window=window)
    s = x.shape[1]
    if spec.windowed and s > spec.capacity:
        # only the trailing window lands in the ring buffer
        kt = k[:, -spec.capacity:]
        vt = v[:, -spec.capacity:]
        tpos = positions[:, -spec.capacity:]
        slots = tpos % spec.capacity
        # scatter rows into ring slots
        bidx = jnp.arange(kt.shape[0])[:, None]
        new_k = cache["k"].at[bidx, slots].set(kt)
        new_v = cache["v"].at[bidx, slots].set(vt)
    else:
        new_k = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    new_k = shard_hint(new_k, CACHE_AXES["k"])
    new_v = shard_hint(new_v, CACHE_AXES["v"])
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
            {"k": new_k, "v": new_v})


def attention_decode_token(p: dict, x, cfg: ModelConfig, pos, cache: dict,
                           spec: CacheSpec) -> Tuple[jax.Array, dict]:
    """Decode WITHOUT rewriting the cache: attends over the (stale) cache
    plus the new token's K/V computed on the fly, and returns the token
    K/V for the caller to write with one stacked dynamic-update-slice
    outside the layer scan. This keeps the scan's carried/stacked state to
    O(tokens) instead of O(cache), which otherwise costs whole-cache
    copies and hoisted dtype-converts per step.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    K, hd = cfg.num_kv_heads, cfg.head_dim
    g = cfg.num_heads // K
    qg = q.reshape(b, K, g, hd)
    ck, cv = cache["k"], cache["v"]
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, ck,
                        preferred_element_type=jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = logits * scale
    slot = (pos % spec.capacity) if spec.windowed else pos
    idx = jnp.arange(spec.capacity)
    valid = idx <= pos - 1
    if spec.windowed:
        valid = valid | (pos >= spec.capacity)
    valid = valid & (idx != slot)  # the new token replaces this slot
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    # pin the seq-sharded contraction: weights stay sharded like the cache
    # seq dim and the PV dot reduces to a tiny [b, K, g, hd] all-reduce —
    # otherwise GSPMD reshards (all-gathers) the whole V cache per layer
    logits = shard_hint(logits, ("batch", "kv_heads", None, "cache_seq"))
    logits_new = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(ck.dtype),
                            preferred_element_type=jnp.float32) * scale
    m = jnp.maximum(logits.max(-1, keepdims=True),
                    logits_new.max(-1, keepdims=True))
    p_cache = jnp.exp(logits - m)
    p_new = jnp.exp(logits_new - m)
    denom = p_cache.sum(-1, keepdims=True) + p_new.sum(-1, keepdims=True)
    w_cache = (p_cache / denom).astype(cv.dtype)
    w_cache = shard_hint(w_cache, ("batch", "kv_heads", None, "cache_seq"))
    w_new = (p_new / denom).astype(cv.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w_cache, cv)
    out = shard_hint(out, ("batch", None, None, None))
    out = out + w_new * v.reshape(b, K, 1, hd).astype(cv.dtype)
    out = out.reshape(b, 1, cfg.num_heads, hd)
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
            {"k_tok": k.astype(ck.dtype), "v_tok": v.astype(cv.dtype)})


def attention_decode(p: dict, x, cfg: ModelConfig, pos, cache: dict,
                     spec: CacheSpec) -> Tuple[jax.Array, dict]:
    """Single-token decode. x [b, 1, d]; pos scalar int (same for batch)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    slot = (pos % spec.capacity) if spec.windowed else pos
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    ck = shard_hint(ck, CACHE_AXES["k"])
    cv = shard_hint(cv, CACHE_AXES["v"])

    K, hd = cfg.num_kv_heads, cfg.head_dim
    g = cfg.num_heads // K
    qg = q.reshape(b, K, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, ck,
                        preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    # validity: slot i holds a token iff i <= pos (unwindowed) or always
    # once the ring is full (windowed); ring slots hold positions in
    # (pos-capacity, pos] by construction, all attendable under the window.
    idx = jnp.arange(spec.capacity)
    valid = idx <= pos  # before ring wraps, slots > pos are empty
    if spec.windowed:
        valid = valid | (pos >= spec.capacity)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, cv).reshape(b, 1, cfg.num_heads, hd)
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
            {"k": ck, "v": cv})
