"""Mixture-of-Experts layer: top-k routing with fixed expert capacity
(gather/scatter dispatch, no giant one-hot dispatch tensors), optional
shared experts (DeepSeekMoE), switch-style load-balance aux loss.

Dispatch strategy
-----------------
Tokens are processed in groups (the batch dim). Per group:
  1. router logits -> top-k experts + renormalized weights per token
  2. position-in-expert via cumsum over the flattened (token, choice)
     assignment list; tokens beyond capacity C are dropped (their weight
     mass is simply not added back -> standard capacity dropping)
  3. an [E, C] table of token ids is built by scatter, token vectors are
     gathered to [E, C, d], experts run as one batched einsum, and results
     are scatter-added back weighted by the routing weights.

Compute is E*C*ffn = k*capacity_factor overhead over ideal, matching
production dropping MoE implementations, and the expert dim shards over the
"model" mesh axis (all-to-all appears in the lowered HLO).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, shard_hint
from repro.models.mlp import apply_mlp, init_mlp


def init_moe(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, E), d, jnp.float32),
        "wi": (dense_init(ks[1], (E, d, 2, ff), d, dtype)
               if cfg.mlp_kind == "swiglu" else
               dense_init(ks[1], (E, d, ff), d, dtype)),
        "wo": dense_init(ks[2], (E, ff, d), ff, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(cfg.mlp_kind, d,
                               cfg.num_shared_experts * ff, ks[3], dtype)
    return p


def moe_param_axes(cfg: ModelConfig) -> dict:
    swiglu = cfg.mlp_kind == "swiglu"
    axes = {
        "router": ("embed", "experts"),
        "wi": (("experts", "embed", None, "mlp") if swiglu
               else ("experts", "embed", "mlp")),
        "wo": ("experts", "mlp", "embed"),
    }
    if cfg.num_shared_experts:
        from repro.models.mlp import mlp_param_axes
        axes["shared"] = mlp_param_axes(cfg.mlp_kind)
    return axes


def _expert_ffn(p: dict, xe: jax.Array, kind: str) -> jax.Array:
    """xe [G, E, C, d] -> [G, E, C, d], batched over groups and experts.

    The hidden dim shards over "mlp" (model axis) so each device computes
    its ff-slice locally from its group-shard of xe — no dispatched-
    activation all-gather. The wo contraction produces partial sums that
    GSPMD reduces once per layer.
    """
    if kind == "swiglu":
        h = jnp.einsum("gecd,edif->gecif", xe, p["wi"])
        h = shard_hint(h, ("batch", "experts", "expert_cap", None, "mlp"))
        h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    else:
        h = jnp.einsum("gecd,edf->gecf", xe, p["wi"])
        h = shard_hint(h, ("batch", "experts", "expert_cap", "mlp"))
        h = jax.nn.relu(h) ** 2 if kind == "squared_relu" else jax.nn.gelu(h)
    return jnp.einsum("gecf,efd->gecd", h, p["wo"])


def _route_tables(tope, topw, s: int, E: int, cap: int, dtype):
    """Per-group routing tables (integers only — cheap to build/replicate).

    tope/topw [s, k] -> (table [E, cap] token ids (s = pad),
                         wtab [E, cap] combine weights)."""
    k = tope.shape[-1]
    flat_e = tope.reshape(-1)  # [s*k], token-major
    tok_ids = jnp.repeat(jnp.arange(s), k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # position-in-expert
    myk = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    ok = myk < cap
    safe_e = jnp.where(ok, flat_e, 0)
    safe_p = jnp.where(ok, myk, cap)  # cap column = dropped sentinel
    table = jnp.full((E, cap + 1), s, jnp.int32)
    table = table.at[safe_e, safe_p].set(jnp.where(ok, tok_ids, s))
    wtab = jnp.zeros((E, cap + 1), dtype)
    wtab = wtab.at[safe_e, safe_p].set(
        jnp.where(ok, topw.reshape(-1), 0.0).astype(dtype))
    return table[:, :cap], wtab[:, :cap]


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              capacity_factor: float = 0.0
              ) -> Tuple[jax.Array, jax.Array]:
    """x [b, s, d] -> (out [b, s, d], aux_loss scalar)."""
    b, s, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    cf = capacity_factor or cfg.moe_capacity_factor
    cap = max(1, int(s * k * cf / E))

    # cast the fp32 router weight down rather than the (huge) activation up;
    # accumulate in fp32 via preferred_element_type
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [b, s, E]
    topw, tope = jax.lax.top_k(probs, k)  # [b, s, k]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (switch-style)
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(tope, E, dtype=jnp.float32), axis=(1, 2))  # [b, E]
    prob_frac = jnp.mean(probs, axis=1)  # [b, E]
    aux = E * jnp.mean(jnp.sum(dispatch_frac * prob_frac, axis=-1))

    # routing tables: vmapped int scatters (tiny); the token-vector
    # gathers are batched over the (data-sharded) group axis -> local
    tables, wtabs = jax.vmap(
        lambda te, tw: _route_tables(te, tw, s, E, cap, x.dtype)
    )(tope, topw)  # [b, E, cap] each
    # per-(token, choice) slot in the dispatched tensor, for the combine
    # gather below; dropped tokens point at the zero sentinel slot E*cap
    flat_e = tope.reshape(b, s * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [b, s*k, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot
    myk = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    slot = jnp.where(myk < cap, flat_e * cap + myk, E * cap)  # [b, s*k]

    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad[:, :, None, :],  # [b, s+1, 1, d]
        tables.reshape(b, E * cap)[:, :, None, None], axis=1
    ).reshape(b, E, cap, d)
    xe = shard_hint(xe, ("batch", "experts", "expert_cap", None))
    ye = _expert_ffn(p, xe, cfg.mlp_kind)
    ye = shard_hint(ye, ("batch", "experts", "expert_cap", None))

    # combine as a batched GATHER (not scatter-add): out[t] =
    # sum_k w_tk * ye[slot(t, k)] — identical math, but gathers partition
    # cleanly under GSPMD while scatter-adds force giant all-reduces.
    ye_flat = jnp.concatenate(
        [ye.reshape(b, E * cap, d),
         jnp.zeros((b, 1, d), ye.dtype)], axis=1)  # sentinel zero row
    picked = jnp.take_along_axis(
        ye_flat[:, :, None, :], slot[:, :, None, None], axis=1
    ).reshape(b, s, k, d)
    w = jnp.where(myk < cap, topw.reshape(b, s * k), 0.0).reshape(b, s, k)
    out = jnp.einsum("bskd,bsk->bsd", picked, w.astype(picked.dtype))
    out = shard_hint(out, ("batch", "seq", None))
    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, cfg.mlp_kind)
    return out, aux
