"""Logical-axis sharding rules (MaxText-style), divisibility-aware.

Model code annotates tensors with *logical* axis names via ``shard_hint``;
launchers activate a rule set mapping logical names to mesh axes. Outside an
active context (unit tests, CPU smoke runs) ``shard_hint`` is a no-op, so the
model zoo never depends on a mesh being present.

A rule maps a logical axis to a priority list of mesh axes (or axis tuples).
At resolution time we pick the first candidate whose total size evenly
divides the dimension — small smoke models never crash on a 256-chip mesh,
and dims like GQA's 8 KV heads fall back to replication on a 16-way model
axis instead of producing an invalid sharding.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCand = Union[str, Tuple[str, ...]]

# Default rule set. "fsdp" behaviour: weight dims marked "embed" shard over
# the data axes, giving ZeRO-3-style full parameter sharding.
DEFAULT_RULES: Dict[str, Sequence[AxisCand]] = {
    "batch": [("pod", "data"), "data"],
    "seq": [],  # unsharded by default; "cp" variant shards it (see below)
    "cache_seq": [],  # decode-time KV seq; context-parallel rule shards it
    "embed": [("pod", "data"), "data"],  # fsdp dim of weights
    "embed_act": [],  # activation hidden dim
    "heads": ["model"],
    "kv_heads": ["model"],
    "head_dim": [],
    "mlp": ["model"],
    "vocab": ["model"],
    "experts": ["model"],
    "expert_cap": [],
    "ssm_inner": ["model"],
    "ssm_heads": ["model"],
    "ssm_state": [],
    "conv_channels": ["model"],
    # d_model sharded over the model axis (sequence-parallel-style
    # reduce-scatter points, e.g. the MoE combine)
    "embed_model": ["model"],
    "rglru_width": ["model"],
    "conv_k": [],
    "frames": [],
    "layers": [],  # stacked-layer leading dim of scanned params
}

# Context-parallel overlay used for batch=1 long-context decode: KV cache
# sequence is sharded over the data axes (queries are replicated, partial
# attention is combined with a logsumexp reduction).
CONTEXT_PARALLEL_OVERLAY: Dict[str, Sequence[AxisCand]] = {
    "cache_seq": [("pod", "data"), "data"],
    "batch": [],
}


class _State(threading.local):
    def __init__(self) -> None:
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Sequence[AxisCand]] = {}


_STATE = _State()


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Optional[Dict[str, Sequence[AxisCand]]] = None,
             overlay: Optional[Dict[str, Sequence[AxisCand]]] = None):
    """Activate (mesh, rules) so shard_hint becomes a real constraint."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    if overlay:
        merged.update(overlay)
    prev = (_STATE.mesh, _STATE.rules)
    _STATE.mesh, _STATE.rules = mesh, merged
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def _axis_size(mesh: Mesh, cand: AxisCand) -> int:
    if isinstance(cand, str):
        return mesh.shape[cand]
    size = 1
    for a in cand:
        size *= mesh.shape[a]
    return size


def _try_candidate(mesh: Mesh, cand: Optional[AxisCand], dim: int,
                   taken: set) -> Optional[AxisCand]:
    if cand is None:
        return None
    axes = (cand,) if isinstance(cand, str) else tuple(cand)
    if any(a not in mesh.shape for a in axes):
        return None
    if any(a in taken for a in axes):
        return None
    if dim % _axis_size(mesh, cand) != 0:
        return None
    return cand


def logical_to_pspec(logical_axes: Sequence[Optional[str]],
                     shape: Sequence[int],
                     mesh: Optional[Mesh] = None) -> P:
    """Resolve logical axis names to a PartitionSpec for `shape`.

    Resolution is round-based: in round r every still-unresolved dim tries
    its r-th candidate. A rule may contain ``None`` entries to skip early
    rounds, i.e. to yield a mesh axis to higher-priority logical axes
    (e.g. ``cache_seq: [None, "model"]`` lets ``kv_heads`` claim "model"
    first and only claims it when kv_heads was indivisible).
    """
    mesh = mesh or _STATE.mesh
    assert mesh is not None
    taken: set = set()
    out: list = [None] * len(logical_axes)
    resolved = [name is None for name in logical_axes]
    max_rounds = max((len(_STATE.rules.get(n, ())) for n in logical_axes
                      if n is not None), default=0)
    for r in range(max_rounds):
        for i, (name, dim) in enumerate(zip(logical_axes, shape)):
            if resolved[i]:
                continue
            cands = _STATE.rules.get(name, ())
            if r >= len(cands):
                continue
            cand = _try_candidate(mesh, cands[r], dim, taken)
            if cand is not None:
                axes = (cand,) if isinstance(cand, str) else tuple(cand)
                taken.update(axes)
                out[i] = cand
                resolved[i] = True
    return P(*out)


def shard_hint(x: jax.Array, logical_axes: Sequence[Optional[str]]):
    """Apply with_sharding_constraint if a rule context is active."""
    if _STATE.mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard_hint: {len(logical_axes)} axes for rank-{x.ndim} array")
    spec = logical_to_pspec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE.mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]],
                   shape: Sequence[int],
                   mesh: Optional[Mesh] = None) -> NamedSharding:
    mesh = mesh or _STATE.mesh
    assert mesh is not None
    return NamedSharding(mesh, logical_to_pspec(logical_axes, shape, mesh))
