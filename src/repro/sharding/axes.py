"""Logical-axis annotation of whole pytrees (params, optimizer state,
KV caches, batches) by tree path — the bridge between the model zoo's
parameter structure and the mesh rules in repro.sharding.rules.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

from repro.configs.base import ModelConfig


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(f"[{k.idx}]")
        elif isinstance(k, GetAttrKey):
            out.append(k.name)
    return tuple(out)


def _param_leaf_axes(names: Tuple[str, ...], ndim: int) -> Tuple:
    """Logical axes for one parameter leaf, by its tree path."""
    name = names[-1]
    in_cycles = "cycles" in names
    in_moe = "moe" in names and "shared" not in names

    def wrap(axes):
        axes = tuple(axes)
        assert len(axes) + (1 if in_cycles else 0) == ndim, (names, ndim,
                                                             axes)
        return (("layers",) + axes) if in_cycles else axes

    if name == "embed":
        return ("vocab", "embed")
    if name == "pos_embed":
        return (None, "embed")
    if name == "lm_head":
        return ("embed", "vocab")
    if name in ("final_norm", "mask_embed"):
        return (None,)
    if name in ("ln1", "ln2", "norm_w", "lam", "A_log", "D", "dt_bias"):
        return wrap((None,) * (ndim - (1 if in_cycles else 0)))
    if name == "wq":
        return wrap(("embed", "heads", None))
    if name in ("wk", "wv"):
        return wrap(("embed", "kv_heads", None))
    if name == "bq":
        return wrap(("heads", None))
    if name in ("bk", "bv"):
        return wrap(("kv_heads", None))
    if name == "wo" and "attn" in names:
        return wrap(("heads", None, "embed"))
    if name == "router":
        return wrap(("embed", "experts"))
    if name == "wi":
        body = ndim - (1 if in_cycles else 0)
        if in_moe:
            return wrap(("experts", "embed", None, "mlp") if body == 4
                        else ("experts", "embed", "mlp"))
        return wrap(("embed", None, "mlp") if body == 3
                    else ("embed", "mlp"))
    if name == "wo":  # mlp / moe (attn handled above)
        if in_moe:
            return wrap(("experts", "mlp", "embed"))
        return wrap(("mlp", "embed"))
    if name == "w_in":
        return wrap(("embed", "ssm_inner"))
    if name == "conv":
        kind = "ssm_inner" if "ssm" in names else "rglru_width"
        return wrap((None, kind))
    if name == "w_out":
        kind = "ssm_inner" if "ssm" in names else "rglru_width"
        return wrap((kind, "embed"))
    if name in ("w_x", "w_gate"):
        return wrap(("embed", "rglru_width"))
    if name in ("w_a", "w_i"):
        return wrap((None, "rglru_width"))
    raise ValueError(f"no axis rule for param {names}")


def param_axes(params_shapes) -> Any:
    """Tree of logical-axes tuples matching a params(-shaped) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _param_leaf_axes(_path_names(p), len(x.shape)),
        params_shapes)


def _cache_leaf_axes(names: Tuple[str, ...], ndim: int) -> Tuple:
    name = names[-1]
    in_cycles = "cycles" in names

    def wrap(axes):
        axes = tuple(axes)
        assert len(axes) + (1 if in_cycles else 0) == ndim, (names, ndim)
        return (("layers",) + axes) if in_cycles else axes

    if name in ("k", "v"):
        return wrap(("batch", "cache_seq", "kv_heads", None))
    if name == "state":
        return wrap(("batch", "ssm_heads", None, "ssm_state"))
    if name == "conv":
        # ssm conv [b, w-1, convdim] / rglru conv [b, w-1, w]: the channel
        # dim shards over "model" either way (logical "conv_channels")
        return wrap(("batch", None, "conv_channels"))
    if name == "h":
        return wrap(("batch", "rglru_width"))
    raise ValueError(f"no axis rule for cache leaf {names}")


def cache_axes(cache_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, x: _cache_leaf_axes(_path_names(p), len(x.shape)),
        cache_shapes)


def batch_axes(batch_shapes) -> Any:
    def leaf(path, x):
        name = _path_names(path)[-1]
        if name in ("patch_embeds", "frame_embeds"):
            return ("batch", None, None)
        return ("batch",) + (None,) * (len(x.shape) - 1)
    return jax.tree_util.tree_map_with_path(leaf, batch_shapes)
