"""Nemotron-4-340B — dense GQA with squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8, head_dim=192) d_ff=73728 vocab=256000.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    source="arXiv:2402.16819",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    mlp_kind="squared_relu",
))
