"""H2O-Danube-3-4B — llama+mistral mix with SWA [arXiv:2401.16818].

24L d_model=3840 32H (GQA kv=8, head_dim=120) d_ff=10240 vocab=32000.
Sliding-window attention enables the long_500k decode shape (the decode KV
working set is window-sized).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    source="arXiv:2401.16818",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    mlp_kind="swiglu",
))
