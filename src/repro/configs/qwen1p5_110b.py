"""Qwen1.5-110B — dense GQA with QKV bias [hf:Qwen/Qwen1.5-0.5B family].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    mlp_kind="swiglu",
    qkv_bias=True,
))
