from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    PAPER_ARCHS,
    InputShape,
    ModelConfig,
    get_config,
    list_configs,
    reduce_config,
    register,
)
