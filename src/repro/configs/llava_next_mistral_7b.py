"""LLaVA-NeXT (v1.6) Mistral-7B backbone — VLM
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

Language backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
AnyRes tiling: the vision tower + projector are stubbed per assignment;
``input_specs`` provides up to 2880 (5x576) patch embeddings prepended to
the text tokens.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    mlp_kind="swiglu",
    frontend="vision",
    num_patch_tokens=2880,  # anyres: base 576 + 4 tiles x 576
))
