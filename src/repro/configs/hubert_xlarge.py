"""HuBERT-XLarge — audio encoder backbone [arXiv:2106.07447].

48L d_model=1280 16H (MHA: kv=16) d_ff=5120 vocab=504 (k-means unit
codebook). Encoder-only (bidirectional attention, no decode path). The
conv/mel frontend is stubbed per assignment: ``input_specs`` provides frame
embeddings of shape [batch, frames, d_model].
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    source="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    is_encoder=True,
    mlp_kind="gelu",
    rope_theta=0.0,  # learned/absolute positions in w2v2 family -> none here
    frontend="audio",
))
