"""RecurrentGemma-9B — Griffin hybrid: RG-LRU + local attention 1:2
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1, head_dim=256) d_ff=12288 vocab=256000.
Pattern: (rglru, rglru, attn) cycled; local attention window 2048.
Sub-quadratic -> long_500k runs.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    source="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp_kind="swiglu",
    layer_pattern=("rglru", "rglru", "attn"),
    local_window=2048,
    rglru_width=4096,
))
