"""Mixtral-8x22B — MoE 8 experts top-2, SWA per assignment [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) expert d_ff=16384 vocab=32768.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    mlp_kind="swiglu",
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
))
