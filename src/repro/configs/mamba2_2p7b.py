"""Mamba2-2.7B — attention-free SSM with SSD [arXiv:2405.21060].

64L d_model=2560, expand=2 (d_inner=5120), head_dim=64 (80 SSM heads),
state=128, vocab=50280. Sub-quadratic: decode holds O(heads*headdim*state)
per layer, so long_500k runs.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    layer_pattern=("ssm",),
))
