"""DeepSeekMoE-16B — fine-grained MoE [arXiv:2401.06066].

28L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=102400.
64 routed experts top-6 + 2 shared experts; layer 0 uses a dense MLP
(d_ff=10944), faithful to the release.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    source="arXiv:2401.06066",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    mlp_kind="swiglu",
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    first_layer_dense=True,
    dense_d_ff=10944,
))
