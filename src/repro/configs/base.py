"""Model/config system: ModelConfig dataclass, registry, smoke reduction.

Every assigned architecture registers a ``ModelConfig`` here via its own
module in ``repro.configs``; the registry is the single source of truth for
``--arch <id>`` selection in launchers, benchmarks and tests.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned, fixed)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description, sufficient to build params + step fns."""

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str  # citation (arXiv id / hf model card)

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention behaviour
    is_encoder: bool = False  # bidirectional, no decode path
    sliding_window: int = 0  # 0 = full attention
    qkv_bias: bool = False
    rope_theta: float = 1.0e6

    # mlp behaviour
    mlp_kind: str = "swiglu"  # swiglu | squared_relu | gelu

    # MoE
    num_experts: int = 0  # routed experts (0 = dense MLP)
    num_shared_experts: int = 0
    experts_per_token: int = 0
    first_layer_dense: bool = False  # deepseek-moe: layer 0 is dense
    dense_d_ff: int = 0  # d_ff of that dense layer (0 -> d_ff)
    moe_capacity_factor: float = 1.25  # expert capacity = s*k*cf/E

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1

    # hybrid layer pattern, cycled over num_layers. entries: attn|rglru|ssm
    layer_pattern: Tuple[str, ...] = ("attn",)
    # local attention window for hybrid local-attn layers (recurrentgemma)
    local_window: int = 0
    rglru_width: int = 0  # 0 -> d_model

    # modality frontend (stubbed; input_specs provides embeddings)
    frontend: str = "none"  # none | audio | vision
    num_patch_tokens: int = 0  # vision: patches prepended to text

    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind, pattern cycled to num_layers."""
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def sub_quadratic(self) -> bool:
        """True if decode at 500k context holds O(window/state) memory."""
        kinds = set(self.layer_kinds())
        if kinds <= {"ssm", "rglru"}:
            return True
        if "attn" in kinds:
            # all attention layers must be windowed
            window = self.sliding_window or self.local_window
            return window > 0
        return True

    def shape_supported(self, shape: InputShape) -> Tuple[bool, str]:
        """(supported, reason-if-not) for an (arch, input-shape) pair."""
        if shape.kind == "decode" and self.is_encoder:
            return False, "encoder-only: no autoregressive decode"
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, "full attention: no sub-quadratic 500k decode path"
        return True, ""

    # approx parameter count (for roofline MODEL_FLOPS)
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.layer_kinds():
            if kind == "attn":
                q = d * self.num_heads * self.head_dim
                kv = 2 * d * self.num_kv_heads * self.head_dim
                o = self.num_heads * self.head_dim * d
                total += q + kv + o
            elif kind == "rglru":
                w = self.rglru_width or d
                total += 2 * d * w + w * d + 3 * w * w + 2 * w  # branches+gates
            elif kind == "ssm":
                din = self.d_inner
                proj_in = d * (2 * din + 2 * self.ssm_ngroups * self.ssm_state
                               + self.ssm_nheads)
                total += proj_in + din * d + self.ssm_conv * (
                    din + 2 * self.ssm_ngroups * self.ssm_state)
            # mlp
            if kind in ("attn", "rglru"):
                mult = 3 if self.mlp_kind == "swiglu" else 2
                if self.num_experts:
                    n_e = (self.experts_per_token + self.num_shared_experts
                           if active_only else
                           self.num_experts + self.num_shared_experts)
                    total += n_e * mult * d * self.d_ff
                    total += d * self.num_experts  # router
                else:
                    total += mult * d * self.d_ff
        return total

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Raw KV cache bytes/token (the quantity the codec compresses)."""
        per_layer = 2 * self.num_kv_heads * self.head_dim * dtype_bytes
        n_attn = sum(1 for k in self.layer_kinds() if k == "attn")
        return per_layer * n_attn


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _validate(cfg)
    _REGISTRY[cfg.name] = cfg
    return cfg


def _validate(cfg: ModelConfig) -> None:
    kinds = set(cfg.layer_kinds())
    if "attn" in kinds:
        assert cfg.num_heads > 0 and cfg.head_dim > 0, cfg.name
        assert cfg.num_heads % max(cfg.num_kv_heads, 1) == 0, cfg.name
    if "ssm" in kinds:
        assert cfg.ssm_state > 0 and cfg.d_inner % cfg.ssm_head_dim == 0
    if cfg.num_experts:
        assert cfg.experts_per_token > 0
    assert cfg.vocab_size > 0 and cfg.num_layers > 0 and cfg.d_model > 0


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


ASSIGNED_ARCHS = (
    "hubert-xlarge",
    "nemotron-4-340b",
    "h2o-danube-3-4b",
    "llava-next-mistral-7b",
    "deepseek-moe-16b",
    "yi-9b",
    "mamba2-2.7b",
    "mixtral-8x22b",
    "recurrentgemma-9b",
    "qwen1.5-110b",
)

PAPER_ARCHS = ("lwm-7b", "yi-34b", "llama3-70b")

_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import every sibling module so registration side-effects run
    from repro.configs import (  # noqa: F401
        hubert_xlarge, nemotron_4_340b, h2o_danube_3_4b,
        llava_next_mistral_7b, deepseek_moe_16b, yi_9b, mamba2_2p7b,
        mixtral_8x22b, recurrentgemma_9b, qwen1p5_110b, paper_models,
    )


# ---------------------------------------------------------------------------
# Smoke reduction — same family, tiny dims (2 layers, d_model<=512, <=4 exp)
# ---------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig, *, d_model: int = 256,
                  num_layers: int = 2, vocab: int = 512) -> ModelConfig:
    """Reduced variant of the same architecture family for CPU smoke tests."""
    changes: Dict[str, object] = dict(
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=min(d_model, 512),
        vocab_size=min(cfg.vocab_size, vocab),
    )
    if cfg.num_heads:
        heads = max(4, min(8, cfg.num_heads))
        kv = max(1, heads // max(cfg.q_per_kv, 1))
        # keep the GQA ratio when possible
        while heads % kv:
            kv -= 1
        changes.update(num_heads=heads, num_kv_heads=kv,
                       head_dim=changes["d_model"] // heads)  # type: ignore
    if cfg.d_ff:
        changes["d_ff"] = 2 * int(changes["d_model"])  # type: ignore
    if cfg.dense_d_ff:
        changes["dense_d_ff"] = 2 * int(changes["d_model"])  # type: ignore
    if cfg.num_experts:
        # capacity_factor = E makes capacity >= s*k: no token dropping, so
        # smoke tests can check prefill/decode against the full forward.
        changes.update(num_experts=4,
                       experts_per_token=min(2, cfg.experts_per_token),
                       num_shared_experts=min(1, cfg.num_shared_experts),
                       moe_capacity_factor=4.0)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=32)
    if cfg.rglru_width:
        changes["rglru_width"] = int(changes["d_model"])  # type: ignore
    if cfg.sliding_window:
        changes["sliding_window"] = 64
    if cfg.local_window:
        changes["local_window"] = 64
    if cfg.num_patch_tokens:
        changes["num_patch_tokens"] = 16
    # hybrid pattern: keep every distinct layer kind represented
    if len(cfg.layer_pattern) > 1 and num_layers < len(cfg.layer_pattern):
        uniq = tuple(dict.fromkeys(cfg.layer_pattern))
        changes["layer_pattern"] = uniq[:num_layers]
    return dataclasses.replace(cfg, **changes)  # type: ignore[arg-type]
