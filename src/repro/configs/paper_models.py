"""The paper's own evaluation models (codec/layout experiments replicate on
reduced variants of these): LWM-7B [hf:LargeWorldModel/LWM-Text-Chat-1M],
Yi-34B [hf:01-ai/Yi-34B], Llama3-70B [hf:meta-llama/Llama-3.3-70B-Instruct].
"""
from repro.configs.base import ModelConfig, register

LWM_7B = register(ModelConfig(
    name="lwm-7b",
    arch_type="dense",
    source="hf:LargeWorldModel/LWM-Text-Chat-1M",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,  # llama-2-7b base: MHA
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    mlp_kind="swiglu",
))

YI_34B = register(ModelConfig(
    name="yi-34b",
    arch_type="dense",
    source="hf:01-ai/Yi-34B",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    mlp_kind="swiglu",
))

LLAMA3_70B = register(ModelConfig(
    name="llama3-70b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.3-70B-Instruct",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    mlp_kind="swiglu",
))
