"""Render the roofline table from dryrun_results/*.json.

    PYTHONPATH=src python -m repro.roofline.report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.configs.base import ASSIGNED_ARCHS, INPUT_SHAPES

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def load(results_dir: str = DEFAULT_DIR) -> List[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def table(records: List[dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | step | compute | memory | collective | "
            "dominant | useful/HLO | bytes/dev | status |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    order = {a: i for i, a in enumerate(ASSIGNED_ARCHS)}
    shape_order = {s: i for i, s in enumerate(INPUT_SHAPES)}
    recs = [r for r in records if r["mesh"] == mesh]
    recs.sort(key=lambda r: (order.get(r["arch"], 99),
                             shape_order.get(r["shape"], 9)))
    for r in recs:
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                        f"| — | — | SKIP: {r.get('reason', r.get('error', ''))[:40]} |")
            continue
        rf = r["roofline"]
        mem = r.get("memory", {})
        dev_bytes = (mem.get("argument_size_in_bytes", 0)
                     + mem.get("temp_size_in_bytes", 0)
                     - mem.get("alias_size_in_bytes", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['description'].split()[0]} "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} "
            f"| {rf['dominant'].replace('_s', '')} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {dev_bytes / 1e9:.1f}GB | ok |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--dir", default=DEFAULT_DIR)
    args = ap.parse_args()
    print(table(load(args.dir), args.mesh))


if __name__ == "__main__":
    main()
