"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh):
  compute    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are parsed from the compiled HLO text (GSPMD has already partitioned it,
so operand shapes are per-device) by summing operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from repro.configs.base import InputShape, ModelConfig

# TPU v5e hardware constants (per brief)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# e.g. "bf16[16,4096,1152]{2,1,0}" possibly inside a tuple
_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|f16|f32|f64|c64)"
                       r"\[([\d,]*)\]")
_OP_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")

# ops whose "result" is free (views / control-flow wrappers / loop-carry
# parameters — a body's parameter is the carried state, not HBM traffic)
_FREE_OPS = {"get-tuple-element", "tuple", "bitcast", "while",
             "conditional", "call", "after-all", "constant", "parameter"}
_RESULT_OP_RE = re.compile(r"=\s*(?:\([^=]*?\)|\S+)\s+([\w\-]+)(?:\.|\()")


def hlo_bytes_split(hlo_text: str) -> Dict[str, float]:
    """Approximate HBM traffic from the partitioned HLO text: sum of
    result-shape bytes of every real op (x2 for read+write), split into
    while-body vs outside contributions. Unlike cost_analysis this lets
    the roofline weight loop bodies by their trip counts and is immune to
    the CPU backend's unfused byte over-count."""
    lines = hlo_text.splitlines()
    body_names = set()
    for line in lines:
        if " while(" in line or " while." in line:
            for m in _BODY_RE.finditer(line):
                body_names.add(m.group(1))
    in_loop = outside = 0.0
    current = None
    for line in lines:
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            current = m.group(1) if m else None
            continue
        if "=" not in line:
            continue
        ls = line.strip()
        eq = ls.index("=")
        rhs = ls[eq + 1:].lstrip()
        # op name = first token after the result shape(s)
        op_m = re.match(r"(?:\([^)]*\)|[\w\[\],{}:#*]+)\s+([\w\-]+)", rhs)
        op = op_m.group(1) if op_m else ""
        if op in _FREE_OPS or op == "":
            continue
        # result shapes sit before the op's '(' args
        paren = rhs.find("(")
        seg = rhs[:paren] if paren > 0 else rhs
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(seg))
        if current in body_names:
            in_loop += nbytes
        else:
            outside += nbytes
    return {"bytes_in_loop": 2.0 * in_loop, "bytes_outside": 2.0 * outside}


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum per-collective result-shape bytes over the partitioned module,
    split into loop-body vs outside-loop contributions.

    HLO line format: ``%name = TYPE[dims]{layout} op-name(...)`` — the
    result shape(s) sit between '=' and the op name and are the
    per-device payload proxy for the transfer. Collectives inside while
    bodies execute once per trip; those outside execute once per step —
    the roofline multiplies only the in-loop share by scan_trips.
    """
    lines = hlo_text.splitlines()
    body_names = set()
    for line in lines:
        if " while(" in line or " while." in line:
            for m in _BODY_RE.finditer(line):
                body_names.add(m.group(1))
    out: Dict[str, float] = {op: 0.0 for op in _COLL_OPS}
    count: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    in_loop_total = 0.0
    outside_total = 0.0
    current = None
    for line in lines:
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            current = m.group(1) if m else None
            continue
        if "=" not in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        if f"{op}-done(" in line:
            continue  # start/done pairs: count the start only
        eq = line.index("=")
        seg = line[eq + 1:m.start()]
        nbytes = float(sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(seg)))
        out[op] += nbytes
        count[op] += 1
        if current in body_names:
            in_loop_total += nbytes
        else:
            outside_total += nbytes
    out["total"] = sum(out[o] for o in _COLL_OPS)
    out["in_loop"] = in_loop_total
    out["outside"] = outside_total
    out["counts"] = count  # type: ignore[assignment]
    return out


def analytic_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Architecture-aware per-step FLOPs floor (all devices).

    param matmuls + attention (window-aware: the block-skip SWA path makes
    O(s*W) the true cost) + SSD state-expansion. Train counts fwd+bwd+
    remat-recompute (8x fwd-param units); inference counts 2x.
    """
    train = shape.kind == "train"
    b, s = shape.global_batch, shape.seq_len
    tokens = b * (s if shape.kind != "decode" else 1)
    mult = 8.0 if train else 2.0  # 2(fwd)+4(bwd)+2(remat) vs 2(fwd)
    total = mult * cfg.param_count(active_only=True) * tokens
    io_mult = mult / 2.0
    for kind in cfg.layer_kinds():
        if kind == "attn":
            window = cfg.sliding_window or cfg.local_window
            if shape.kind == "decode":
                ctx = min(s, window) if window else s
                per_tok = 4.0 * ctx * cfg.num_heads * cfg.head_dim
            else:
                ctx_avg = min(window, s) if window else s / 2.0
                per_tok = 4.0 * ctx_avg * cfg.num_heads * cfg.head_dim
            total += io_mult * per_tok * tokens
        elif kind == "ssm":
            q = 64 if shape.kind != "decode" else 1
            nh, hd, S = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
            G = cfg.ssm_ngroups
            per_tok = (2.0 * q * nh * hd + 2.0 * q * G * S
                       + 6.0 * nh * hd * S / max(q, 1))
            total += io_mult * per_tok * tokens
    return total


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D for inference."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def roofline_report(cfg: ModelConfig, shape: InputShape,
                    cost: Optional[dict], coll: Dict[str, float],
                    n_devices: int, scan_trips: int = 1,
                    bytes_split: Optional[Dict[str, float]] = None) -> dict:
    """Roofline terms per device.

    XLA's cost_analysis counts while-loop (scan) bodies ONCE (verified
    empirically), so raw HLO numbers are multiplied by ``scan_trips``
    (= layer-scan cycles x grad-accum microbatches). The small non-scanned
    remainder (embedding, logits, optimizer) gets over-multiplied by the
    same factor — an acceptable upper-bound bias documented in
    EXPERIMENTS.md, cross-checked against analytic MODEL_FLOPS.
    """
    raw_flops = float(cost.get("flops", 0.0)) if cost else 0.0
    raw_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    flops = raw_flops * scan_trips
    if bytes_split is not None:
        nbytes = (bytes_split["bytes_in_loop"] * scan_trips
                  + bytes_split["bytes_outside"])
    else:
        nbytes = raw_bytes * scan_trips
    if "in_loop" in coll:
        coll_total = (coll["in_loop"] * scan_trips + coll["outside"])
    else:
        coll_total = coll.get("total", 0.0) * scan_trips
    # analytic compute floor: HLO flops undercount NESTED loop bodies
    # (e.g. the blocked-attention inner KV scan), so the compute term is
    # the max of the corrected-HLO and architecture-analytic estimates
    af = analytic_flops(cfg, shape) / n_devices
    t_compute = max(flops, af) / PEAK_FLOPS
    t_memory = nbytes / HBM_BW
    t_coll = coll_total / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return {
        **terms,
        "dominant": dominant,
        "analytic_flops_per_device": af,
        "scan_trips": scan_trips,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_devices,
        "useful_flops_ratio": (mf / n_devices) / flops if flops else 0.0,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": nbytes,
        "hlo_flops_raw": raw_flops,
        "collective_bytes": coll_total,
    }
