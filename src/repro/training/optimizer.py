"""Pure-JAX AdamW with decoupled weight decay + LR schedules."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip /
                                jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.count + 1
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)
        m = jax.tree.map(lambda mm, g: self.b1 * mm + (1 - self.b1) * g,
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g,
                         state.v, grads)
        lr = self.lr(count)

        def upd(p, mm, vv):
            mhat = mm / b1c
            vhat = vv / b2c
            du = mhat / (jnp.sqrt(vhat) + self.eps)
            du = du + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * du).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(count, m, v)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in leaves))


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(warmup, 1)
        frac = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(c < warmup, warm, cos)
    return lr


def constant_schedule(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)
