"""Pytree checkpointing: npz payload + JSON tree structure."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(path + ".npz", **arrays)
    with open(path + ".tree.json", "w") as f:
        json.dump({"treedef": str(treedef), "n": len(leaves)}, f)


def restore(path: str, like) -> Any:
    """Restore into the structure of `like` (shape/dtype verified)."""
    data = np.load(path + ".npz")
    leaves, treedef = _flatten(like)
    out = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert arr.shape == tuple(ref.shape), (i, arr.shape, ref.shape)
        out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, out)


def exists(path: str) -> bool:
    return os.path.exists(path + ".npz")
