"""Train / eval step factories over the model zoo (all architectures).

``make_train_step`` builds a jit-able (state, batch) -> (state, metrics)
function with remat'd scanned layers, optional gradient accumulation
(bounds activation memory for the 100B+ configs), MoE aux loss, and the
per-arch loss heads (causal LM / VLM text-only / HuBERT masked units).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.common import cross_entropy
from repro.training.optimizer import AdamW, AdamWState, global_norm


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jax.Array


def init_state(cfg: ModelConfig, optimizer: AdamW, key,
               dtype=jnp.float32) -> TrainState:
    params = tf.init_params(cfg, key, dtype)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32))


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            aux_coef: float = 0.01, remat: bool = True):
    tokens = batch.get("tokens")
    embeds = batch.get("patch_embeds", batch.get("frame_embeds"))
    mask_positions = batch.get("mask")
    logits, moe_aux = tf.forward_full(params, cfg, tokens=tokens,
                                      embeds=embeds,
                                      mask_positions=mask_positions,
                                      remat=remat)
    labels = batch["labels"]
    if cfg.is_encoder:
        # HuBERT-style masked-unit prediction: loss on masked frames only
        loss = cross_entropy(logits, labels, mask=mask_positions)
    elif cfg.frontend == "vision":
        # loss over text positions only (patches are prefix)
        np_ = cfg.num_patch_tokens
        text_logits = logits[:, np_:, :]
        loss = cross_entropy(text_logits[:, :-1], labels[:, 1:])
    else:
        loss = cross_entropy(logits[:, :-1], labels[:, 1:])
    total = loss + aux_coef * moe_aux
    return total, {"loss": loss, "moe_aux": moe_aux}


def make_train_step(cfg: ModelConfig, optimizer: AdamW, *,
                    accum_steps: int = 1, remat: bool = True,
                    donate: bool = True):
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, remat=remat), has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if accum_steps == 1:
            (_, metrics), grads = grad_fn(state.params, batch=batch)
        else:
            def micro(carry, mb):
                acc = carry
                (_, m), g = grad_fn(state.params, batch=mb)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(
                                       lambda x: x.astype(jnp.float32), g))
                return acc, m
            split = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, ms = jax.lax.scan(micro, zeros, split)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        new_params, new_opt = optimizer.update(grads, state.opt,
                                               state.params)
        metrics = dict(metrics)
        metrics["grad_norm"] = global_norm(grads)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
