"""Training driver: config in, loss curve out. CPU-smoke friendly."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, batches
from repro.training import checkpoint
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.steps import init_state, make_train_step


def train(cfg: ModelConfig, *, steps: int = 20, batch_size: int = 4,
          seq_len: int = 64, lr: float = 3e-4, accum_steps: int = 1,
          seed: int = 0, ckpt_path: Optional[str] = None,
          log_every: int = 5) -> List[Dict[str, float]]:
    optimizer = AdamW(lr=cosine_schedule(lr, warmup=max(steps // 10, 1),
                                         total=steps))
    state = init_state(cfg, optimizer, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(cfg, optimizer,
                                      accum_steps=accum_steps))
    data = batches(cfg, DataConfig(batch_size=batch_size, seq_len=seq_len,
                                   seed=seed))
    history: List[Dict[str, float]] = []
    # training progress logging is operator-facing wall time, not
    # replayed state — the loss curve itself is seed-deterministic
    t0 = time.time()  # repro-lint: allow(no-wall-clock)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = step_fn(state, batch)
        rec = {k: float(v) for k, v in metrics.items()}
        rec["step"] = i
        history.append(rec)
        if log_every and i % log_every == 0:
            print(f"step {i:4d} loss {rec['loss']:.4f} "
                  f"gnorm {rec['grad_norm']:.3f} "
                  # repro-lint: allow(no-wall-clock) -- progress print
                  f"({time.time() - t0:.1f}s)")
    if ckpt_path:
        checkpoint.save(ckpt_path, state.params)
    return history
