"""Training launcher.

CPU smoke (runs real compute on a reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
        --steps 20
Production shape (requires a real TPU mesh; on CPU use dryrun.py):
    python -m repro.launch.train --arch nemotron-4-340b --shape train_4k
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import INPUT_SHAPES, get_config, reduce_config
from repro.training.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k",
                    choices=list(INPUT_SHAPES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
        hist = train(cfg, steps=args.steps, batch_size=args.batch,
                     seq_len=args.seq, lr=args.lr,
                     accum_steps=args.accum, ckpt_path=args.ckpt)
        print(f"final loss {hist[-1]['loss']:.4f}")
        return

    shape = INPUT_SHAPES[args.shape]
    n_dev = len(jax.devices())
    need = 256
    if n_dev < need:
        raise SystemExit(
            f"production training of {cfg.name} at {shape.name} needs a "
            f">=256-chip mesh ({n_dev} devices visible). Use --smoke for "
            "local runs or `python -m repro.launch.dryrun` to verify the "
            "distributed lowering.")
    # on a real pod: reuse the dry-run recipe with concrete arrays
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_dryrun
    from repro.sharding import rules
    mesh = make_production_mesh()
    with rules.activate(mesh):
        recipe = build_dryrun(cfg, shape, mesh)
        print(f"lowered {recipe.description}; materialize inputs and call "
              "recipe.fn to train")


if __name__ == "__main__":
    main()
