"""Serving launcher: live engine (real compute) or cluster simulation.

    PYTHONPATH=src python -m repro.launch.serve --arch lwm-7b --live
    PYTHONPATH=src python -m repro.launch.serve --arch yi-34b \
        --simulate --gbps 16 --context 100000 --method kvfetcher
"""
from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lwm-7b")
    ap.add_argument("--live", action="store_true")
    ap.add_argument("--simulate", action="store_true")
    ap.add_argument("--method", default="kvfetcher",
                    choices=["kvfetcher", "cachegen", "llm265", "raw",
                             "lmcache_raw", "full_prefill"])
    ap.add_argument("--gbps", type=float, default=16.0)
    ap.add_argument("--context", type=int, default=100_000)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--chip", default="h20",
                    choices=["h20", "a100", "l20", "tpu-v5e"])
    args = ap.parse_args()

    if args.live or not args.simulate:
        import runpy
        import sys
        sys.argv = ["serve_reuse.py"]
        runpy.run_path("examples/serve_reuse.py", run_name="__main__")
        return

    from repro.configs import get_config
    from repro.core.adaptive import TABLES
    from repro.cluster.network import BandwidthTrace
    from repro.cluster import simulator as sim
    from repro.data.workload import fixed_context_trace
    from repro.serving.metrics import summarize

    spec = {
        "kvfetcher": sim.kvfetcher_spec(
            {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}),
        "cachegen": sim.cachegen_spec(3.5),
        "llm265": sim.llm265_spec(5.0),
        "raw": sim.raw_spec(),
        "lmcache_raw": sim.lmcache_raw_spec(),
        "full_prefill": sim.full_prefill_spec(),
    }[args.method]
    table = TABLES["h20" if args.chip == "tpu-v5e" else args.chip]
    s = sim.ServingSimulator(
        get_config(args.arch), spec, chip=args.chip
        if args.chip != "tpu-v5e" else "h20", n_chips=2,
        bandwidth=BandwidthTrace.constant(args.gbps), table=table)
    res = s.run(fixed_context_trace(args.context,
                                    n_requests=args.requests, gap=60.0),
                max_new_tokens=16)
    reqs = res.fetching() or res.requests
    print(f"method={args.method} ctx={args.context} bw={args.gbps}Gbps")
    for k, v in summarize(reqs).items():
        print(f"  {k}: {v:.3f}")


if __name__ == "__main__":
    main()
