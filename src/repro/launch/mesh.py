"""Production mesh construction (dry-run target: TPU v5e pods).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests)."""
    import jax
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
