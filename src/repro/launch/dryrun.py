import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, record memory/cost analysis + collective bytes.

The two lines above MUST stay the first statements in this file — jax
locks the device count on first init, and only the dry-run is allowed to
see 512 placeholder devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
Results land in dryrun_results/<arch>.<shape>.<mesh>.json.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_dryrun, decode_overlay  # noqa: E402
from repro.roofline.analysis import (  # noqa: E402
    collective_bytes_from_hlo, hlo_bytes_split, roofline_report,
)
from repro.sharding import rules  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def run_one(arch: str, shape_name: str, mesh_kind: str,
            out_dir: str = RESULTS_DIR, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = cfg.shape_supported(shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": None}
    if not ok:
        rec.update(status="skipped", reason=why)
        _save(rec, out_dir)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    overlay = decode_overlay(cfg, shape, mesh)
    # lower/compile wall timings are the dry-run's *measurement output*
    # (reported in the result record), not replayed state
    t0 = time.time()  # repro-lint: allow(no-wall-clock)
    try:
        with rules.activate(mesh, overlay=overlay):
            recipe = build_dryrun(cfg, shape, mesh)
            lowered = recipe.fn.lower(*recipe.args)
            t_lower = time.time() - t0  # repro-lint: allow(no-wall-clock)
            compiled = lowered.compile()
            # repro-lint: allow(no-wall-clock) -- measured compile time
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo_text = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo_text)
            bsplit = hlo_bytes_split(hlo_text)
        n_dev = mesh.devices.size
        mem_rec = {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
        } if mem is not None else {}
        rec.update(
            status="ok",
            description=recipe.description,
            n_devices=int(n_dev),
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops=float(cost.get("flops", 0.0)) if cost else 0.0,
            bytes_accessed=float(cost.get("bytes accessed", 0.0))
            if cost else 0.0,
            memory=mem_rec,
            collectives=coll,
            roofline=roofline_report(cfg, shape, cost, coll, n_dev,
                                     scan_trips=recipe.scan_trips,
                                     bytes_split=bsplit),
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    # repro-lint: allow(no-wall-clock) -- reported wall_s measurement
    rec["wall_s"] = round(time.time() - t0, 2)
    _save(rec, out_dir)
    if verbose:
        state = rec["status"]
        extra = (f" compile={rec.get('compile_s')}s "
                 f"flops={rec.get('flops', 0):.3e}"
                 if state == "ok" else rec.get("reason",
                                               rec.get("error", "")))
        print(f"[{state:>7}] {arch} x {shape_name} x {mesh_kind} {extra}",
              flush=True)
    return rec


def _save(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}.{rec['shape']}.{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = (["single", "multipod"] if args.mesh == "both"
              else [args.mesh])
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = (list(INPUT_SHAPES) if (args.all or not args.shape)
              else [args.shape])
    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_one(arch, shape, mesh_kind, args.out)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
