"""Abstract input specs + shardings for every (arch x input shape) pair.

``build_dryrun`` returns a jit-able step function together with
ShapeDtypeStruct stand-ins for all its inputs (weak-type-correct, no
device allocation) and NamedShardings resolved through the logical-axis
rule engine — the complete recipe ``dryrun.py`` lowers and compiles.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as tf
from repro.sharding import axes as ax
from repro.sharding import rules
from repro.training.optimizer import AdamW, constant_schedule
from repro.training import steps as steps_mod


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder:
        return {
            "frame_embeds": _sds((B, S, cfg.d_model), dtype),
            "labels": _sds((B, S), jnp.int32),
            "mask": _sds((B, S), jnp.bool_),
        }
    if cfg.frontend == "vision":
        n_text = S - cfg.num_patch_tokens
        return {
            "tokens": _sds((B, n_text), jnp.int32),
            "labels": _sds((B, n_text), jnp.int32),
            "patch_embeds": _sds((B, cfg.num_patch_tokens, cfg.d_model),
                                 dtype),
        }
    return {"tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32)}


def prefill_arg_specs(cfg: ModelConfig, shape: InputShape,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder:
        return {"frame_embeds": _sds((B, S, cfg.d_model), dtype)}
    if cfg.frontend == "vision":
        return {"tokens": _sds((B, S - cfg.num_patch_tokens), jnp.int32),
                "patch_embeds": _sds((B, cfg.num_patch_tokens, cfg.d_model),
                                     dtype)}
    return {"tokens": _sds((B, S), jnp.int32)}


def _shardings_from_axes(axes_tree, shapes_tree, mesh):
    return jax.tree.map(
        lambda a, s: rules.named_sharding(a, s.shape, mesh),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0 and all(
            isinstance(e, (str, type(None))) for e in x))


def decode_overlay(cfg: ModelConfig, shape: InputShape, mesh) -> dict:
    """Context/sequence-parallel overlays for decode shapes."""
    overlay: dict = {}
    model = mesh.shape.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if shape.kind != "decode":
        return overlay
    if cfg.num_kv_heads and cfg.num_kv_heads % model != 0:
        # KV heads can't shard over the model axis -> shard cache seq
        overlay["cache_seq"] = [None, "model"]
    if shape.global_batch == 1:
        # batch-1 long-context: context parallelism over the data axes
        cand = overlay.get("cache_seq", [None])[:1]
        overlay["cache_seq"] = cand + [data_axes, "model"] \
            if cand != [None] else [data_axes, "model"]
        overlay["batch"] = []
    return overlay


@dataclasses.dataclass
class DryrunRecipe:
    fn: Any  # jitted function
    args: Tuple  # ShapeDtypeStruct pytrees
    description: str
    scan_trips: int = 1  # layer-scan cycles x grad-accum microbatches


def default_accum(cfg: ModelConfig, shape: InputShape, mesh) -> int:
    data_ways = 1
    for a in ("pod", "data"):
        data_ways *= mesh.shape.get(a, 1)
    local_batch = max(shape.global_batch // data_ways, 1)
    if cfg.d_model >= 12288:
        want = 16
    elif cfg.d_model >= 6144:
        want = 8
    elif cfg.d_model >= 3840:
        want = 4
    else:
        want = 1
    return max(1, min(want, local_batch))


def build_dryrun(cfg: ModelConfig, shape: InputShape, mesh, *,
                 dtype=jnp.bfloat16,
                 accum: Optional[int] = None,
                 remat: bool = True) -> DryrunRecipe:
    """Recipe for one (arch, input-shape, mesh) combination."""
    key = jax.random.PRNGKey(0)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        accum = accum or default_accum(cfg, shape, mesh)
        opt = AdamW(lr=constant_schedule(3e-4))
        state_shapes = jax.eval_shape(
            lambda k: steps_mod.init_state(cfg, opt, k, dtype), key)
        p_axes = ax.param_axes(state_shapes.params)
        state_sh = steps_mod.TrainState(
            params=_shardings_from_axes(p_axes, state_shapes.params, mesh),
            opt=type(state_shapes.opt)(
                count=rules.named_sharding((), (), mesh),
                m=_shardings_from_axes(p_axes, state_shapes.opt.m, mesh),
                v=_shardings_from_axes(p_axes, state_shapes.opt.v, mesh)),
            step=rules.named_sharding((), (), mesh))
        batch_shapes = train_batch_specs(cfg, shape, dtype)
        b_axes = ax.batch_axes(batch_shapes)
        batch_sh = _shardings_from_axes(b_axes, batch_shapes, mesh)
        fn = steps_mod.make_train_step(cfg, opt, accum_steps=accum,
                                       remat=remat)
        jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        _, n_cycles, _ = tf.layer_plan(cfg)
        return DryrunRecipe(jitted, (state_shapes, batch_shapes),
                            f"train_step accum={accum}",
                            scan_trips=max(n_cycles, 1) * accum)

    params_shapes = jax.eval_shape(
        lambda k: tf.init_params(cfg, k, dtype), key)
    p_axes = ax.param_axes(params_shapes)
    params_sh = _shardings_from_axes(p_axes, params_shapes, mesh)

    if shape.kind == "prefill":
        args = prefill_arg_specs(cfg, shape, dtype)
        a_axes = ax.batch_axes(args)
        args_sh = _shardings_from_axes(a_axes, args, mesh)

        if cfg.is_encoder:
            def fn(params, frame_embeds):
                logits, _ = tf.forward_full(params, cfg,
                                            embeds=frame_embeds)
                return logits
        elif cfg.frontend == "vision":
            def fn(params, tokens, patch_embeds):
                logits, cache = tf.prefill(params, cfg, tokens=tokens,
                                           embeds=patch_embeds, dtype=dtype)
                return logits, cache
        else:
            def fn(params, tokens):
                logits, cache = tf.prefill(params, cfg, tokens=tokens,
                                           dtype=dtype)
                return logits, cache
        order = [k for k in ("frame_embeds", "tokens", "patch_embeds")
                 if k in args]  # matches each fn's positional signature
        if cfg.is_encoder:
            out_sh = None
        else:
            # constrain the returned cache's sharding (else XLA replicates
            # the stacked KV output on every device)
            cache_shapes = jax.eval_shape(
                lambda: tf.init_cache(cfg, B, S, dtype))
            cache_sh = _shardings_from_axes(ax.cache_axes(cache_shapes),
                                            cache_shapes, mesh)
            out_sh = (None, cache_sh)
        jitted = jax.jit(fn, in_shardings=(params_sh,) +
                         tuple(args_sh[k] for k in order),
                         out_shardings=out_sh)
        ordered = tuple(args[k] for k in order)
        _, n_cycles, _ = tf.layer_plan(cfg)
        return DryrunRecipe(jitted, (params_shapes,) + ordered,
                            "prefill_step", scan_trips=max(n_cycles, 1))

    # decode
    cache_shapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, B, S, dtype))
    c_axes = ax.cache_axes(cache_shapes)
    cache_sh = _shardings_from_axes(c_axes, cache_shapes, mesh)
    token_spec = _sds((B,), jnp.int32)
    pos_spec = _sds((), jnp.int32)
    token_sh = rules.named_sharding(("batch",), (B,), mesh)
    scalar_sh = rules.named_sharding((), (), mesh)

    def fn(params, token, pos, cache):
        return tf.decode_step(params, cfg, token, pos, cache)

    jitted = jax.jit(fn, in_shardings=(params_sh, token_sh, scalar_sh,
                                       cache_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(3,))
    _, n_cycles, _ = tf.layer_plan(cfg)
    return DryrunRecipe(jitted,
                        (params_shapes, token_spec, pos_spec, cache_shapes),
                        "serve_step (1 new token, cached context)",
                        scan_trips=max(n_cycles, 1))
