"""Pallas TPU kernel: GQA decode attention over a paged KV cache
(flash-decoding style online softmax, one page per grid step).

TPU mapping: block tables are scalar-prefetch operands so each grid step's
K/V BlockSpec index_map aims DMA at the right physical page — HBM->VMEM
traffic is exactly one (page_size, K, hd) tile per step. The online-softmax
running state (m, l, acc) lives in VMEM scratch and persists across the
sequential page-axis grid iterations of the same batch row. MXU work is the
[H, hd] x [hd, ps] logits matmul and the [H, ps] x [ps, hd] value matmul;
head_dim and page_size should be multiples of the 128-lane tiling for full
MXU utilization (all production configs here satisfy that).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, out_ref,
            m_scr, l_scr, acc_scr, *, page_size: int, pages_per_seq: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [H, hd]
    k = k_ref[0].astype(jnp.float32)  # [ps, K, hd]
    v = v_ref[0].astype(jnp.float32)
    H, hd = q.shape
    ps, K, _ = k.shape
    g = H // K

    qg = q.reshape(K, g, hd)
    logits = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)  # [K, g, ps]
    logits = logits / jnp.sqrt(jnp.float32(hd))
    pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
    logits = jnp.where(pos < lens_ref[b], logits, NEG_INF)
    logits = logits.reshape(H, ps)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)  # [H, ps]
    l_new = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.reshape(K, g, ps), v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32).reshape(H, hd)
    acc_new = acc_scr[...] * alpha + pv
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == pages_per_seq - 1)
    def _finish():
        out_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)
                      ).astype(out_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, block_tables, context_lens,
                           *, interpret: bool = True):
    B, H, hd = q.shape
    P, ps, K, _ = k_pages.shape
    bps = block_tables.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (block_tables flat, context_lens)
        grid=(B, bps),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, t, ln: (b, 0, 0)),
            pl.BlockSpec((1, ps, K, hd),
                         lambda b, j, t, ln: (t[b, j], 0, 0, 0)),
            pl.BlockSpec((1, ps, K, hd),
                         lambda b, j, t, ln: (t[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, t, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, page_size=ps, pages_per_seq=bps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )
    return fn(block_tables.astype(jnp.int32), context_lens.astype(jnp.int32),
              q, k_pages, v_pages)
