"""Pure-jnp oracle for paged GQA decode attention."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def _softmax(x):
    m = x.max(axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / e.sum(axis=-1, keepdims=True)


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens):
    """q [B, H, hd]; k/v_pages [P, ps, K, hd]; block_tables [B, bps];
    context_lens [B] -> out [B, H, hd]."""
    B, H, hd = q.shape
    P, ps, K, _ = k_pages.shape
    bps = block_tables.shape[1]
    g = H // K
    # gather each sequence's pages -> [B, bps*ps, K, hd]
    k = k_pages[block_tables].reshape(B, bps * ps, K, hd)
    v = v_pages[block_tables].reshape(B, bps * ps, K, hd)
    qg = q.reshape(B, K, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    pos = jnp.arange(bps * ps)[None]
    logits = jnp.where((pos < context_lens[:, None])[:, None, None],
                       logits, NEG_INF)
    w = _softmax(logits)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
