"""Jitted public wrapper for paged GQA decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import (
    paged_attention_pallas,
)
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    use_kernel: bool = True, interpret: bool = True):
    """Decode-time attention of one query token per sequence over a paged
    KV cache.

    q            [B, H, hd]
    k/v_pages    [P, page_size, K, hd]
    block_tables [B, pages_per_seq] int32 (physical page per logical page)
    context_lens [B] int32
    """
    if use_kernel:
        return paged_attention_pallas(q, k_pages, v_pages, block_tables,
                                      context_lens, interpret=interpret)
    return paged_attention_ref(q, k_pages, v_pages, block_tables,
                               context_lens)
