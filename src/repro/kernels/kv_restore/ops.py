"""Jitted public wrapper for the fused KV restoration op."""
from __future__ import annotations

import functools

import jax

from repro.kernels.kv_restore.kv_restore import kv_restore_pallas
from repro.kernels.kv_restore.ref import kv_restore_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def kv_restore(pages, q_tokens, scales, slots, *, use_kernel: bool = True,
               interpret: bool = True):
    """Dequantize decoded uint8 KV tokens and scatter them into paged rows.

    pages    [R, H, D] float  (paged KV memory rows)
    q_tokens [n, H, D] uint8  (one decoded frame's tokens, one layer/kind)
    scales   [H] float32      (per-head dequant scales)
    slots    [n] int32        (destination rows; -1 drops the token)
    """
    if use_kernel:
        return kv_restore_pallas(pages, q_tokens, scales, slots,
                                 interpret=interpret)
    return kv_restore_ref(pages, q_tokens, scales, slots)
