"""Pallas TPU kernel: fused dequantize + scatter of decoded KV tokens into
paged KV memory (the ``Sparse_frame_KV_transfer`` operator, §3.3.2/§4).

Design for TPU: the destination row of each token block is data-dependent
(slot mapping), so the slot array is a *scalar-prefetch* operand — the
output BlockSpec's index_map reads it to aim each grid step's (1, H, D)
VMEM tile at the right page row. The dequant (uint8 -> (x-128)*scale) runs
on the VPU over the tile; the MXU is untouched, and VMEM footprint is a
single token tile per step — this is why restoration memory stays in the
tens-of-MB range (Fig. 24) instead of chunk-sized buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QOFF = 128.0


def _kernel(safe_ref, orig_ref, q_ref, scale_ref, pages_in_ref,
            pages_out_ref):
    i = pl.program_id(0)
    q = q_ref[...]  # [1, H, D] uint8
    deq = (q.astype(jnp.float32) - QOFF) * scale_ref[...][None, :, None]
    # dropped tokens (original slot < 0) keep the old page row
    keep = orig_ref[i] >= 0
    old = pages_in_ref[...]
    pages_out_ref[...] = jnp.where(keep, deq.astype(old.dtype), old)


def kv_restore_pallas(pages, q_tokens, scales, slots, *,
                      interpret: bool = True):
    """pages [R, H, D]; q_tokens [n, H, D] u8; scales [H]; slots [n] i32."""
    n, H, D = q_tokens.shape
    slots = slots.astype(jnp.int32)
    safe = jnp.where(slots >= 0, slots, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (clamped slots for index_map, originals)
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda i, safe, orig: (i, 0, 0)),
            pl.BlockSpec((H,), lambda i, safe, orig: (0,)),
            pl.BlockSpec((1, H, D), lambda i, safe, orig: (safe[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D),
                               lambda i, safe, orig: (safe[i], 0, 0)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pages.shape, pages.dtype),
        input_output_aliases={4: 0},  # pages operand aliases the output
        interpret=interpret,
    )
    return fn(safe, slots, q_tokens, scales, pages)
