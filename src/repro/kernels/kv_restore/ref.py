"""Pure-jnp oracle for the fused KV restoration op.

restore = dequantize(uint8 tokens) -> scatter into paged KV memory rows.
This is the device-side half of frame-wise restoration (§3.3.2): the
paper's ``Sparse_frame_KV_transfer`` writes each decoded frame's tokens
straight into the engine's paged memory.
"""
from __future__ import annotations

import jax.numpy as jnp

QOFF = 128


def kv_restore_ref(pages, q_tokens, scales, slots):
    """pages [R, H, D] float; q_tokens [n, H, D] uint8; scales [H] f32;
    slots [n] int32 (row index into pages; -1 = drop).

    Returns updated pages.
    """
    deq = (q_tokens.astype(jnp.float32) - QOFF) * scales[None, :, None]
    deq = deq.astype(pages.dtype)
    ok = slots >= 0
    safe = jnp.where(ok, slots, 0)
    deq = jnp.where(ok[:, None, None], deq,
                    pages[safe])  # dropped rows rewrite their old value
    return pages.at[safe].set(deq)
