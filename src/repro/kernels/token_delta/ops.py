"""Jitted public wrappers for the token-delta transform."""
from __future__ import annotations

import functools

import jax

from repro.kernels.token_delta.ref import (
    token_delta_decode_frame_ref, token_delta_encode_ref,
)
from repro.kernels.token_delta.token_delta import (
    token_delta_decode_frame_pallas, token_delta_encode_pallas,
)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def token_delta_encode(video, *, use_kernel: bool = True,
                       interpret: bool = True):
    if use_kernel:
        return token_delta_encode_pallas(video, interpret=interpret)
    return token_delta_encode_ref(video)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def token_delta_decode_frame(prev_frame, zres, *, use_kernel: bool = True,
                             interpret: bool = True):
    if use_kernel:
        return token_delta_decode_frame_pallas(prev_frame, zres,
                                               interpret=interpret)
    return token_delta_decode_frame_ref(prev_frame, zres)
