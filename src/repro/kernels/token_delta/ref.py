"""Pure-jnp oracle for the token-delta (inter-frame) transform."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.prediction import UNZIGZAG, ZIGZAG

_ZIG = jnp.asarray(ZIGZAG)
_UNZIG = jnp.asarray(UNZIGZAG)


def token_delta_encode_ref(video):
    """video [F, H, W] uint8 -> zigzagged temporal residuals (frame 0 raw)."""
    prev = jnp.concatenate(
        [jnp.zeros_like(video[:1]), video[:-1]], axis=0)
    res = video - prev  # uint8 wraparound
    return _ZIG[res]


def token_delta_decode_frame_ref(prev_frame, zres):
    """prev [H, W] u8 (zeros for frame 0), zres [H, W] u8 -> frame u8."""
    return prev_frame + _UNZIG[zres]
