"""Pallas TPU kernel: inter-frame (token-delta) predictive transform.

Encode side of the KV codec's hot loop: residual = frame_f - frame_{f-1}
(mod 256) followed by the zigzag sign-interleave, tiled (block_h, block_w)
over each frame so a grid step touches exactly two VMEM tiles (current +
reference). Pure VPU element-wise work; tiles are chosen 8x128-aligned.

The decode-side inverse is per-frame (frame-wise restoration consumes one
frame at a time), so it is exposed as a (prev, residual) -> frame kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _zigzag(r):
    r32 = r.astype(jnp.int32)
    z = jnp.where(r32 < 128, 2 * r32, 2 * (256 - r32) - 1)
    return z.astype(jnp.uint8)


def _unzigzag(z):
    z32 = z.astype(jnp.int32)
    r = jnp.where(z32 % 2 == 0, z32 // 2, 256 - (z32 + 1) // 2)
    return r.astype(jnp.uint8)


def _encode_kernel(cur_ref, prev_ref, out_ref):
    f = pl.program_id(0)
    cur = cur_ref[...]
    prev = jnp.where(f > 0, prev_ref[...], jnp.zeros_like(cur))
    out_ref[...] = _zigzag(cur - prev)


def token_delta_encode_pallas(video, *, block=(8, 128),
                              interpret: bool = True):
    """video [F, H, W] uint8 -> zigzag residuals [F, H, W] uint8."""
    F, H, W = video.shape
    bh = min(block[0], H)
    bw = min(block[1], W)
    grid = (F, -(-H // bh), -(-W // bw))
    fn = pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bh, bw), lambda f, i, j: (f, i, j)),
            # reference frame: previous f (clamped at 0; masked in-kernel)
            pl.BlockSpec((1, bh, bw),
                         lambda f, i, j: (jnp.maximum(f - 1, 0), i, j)),
        ],
        out_specs=pl.BlockSpec((1, bh, bw), lambda f, i, j: (f, i, j)),
        out_shape=jax.ShapeDtypeStruct(video.shape, jnp.uint8),
        interpret=interpret,
    )
    return fn(video, video)


def _decode_kernel(prev_ref, zres_ref, out_ref):
    out_ref[...] = prev_ref[...] + _unzigzag(zres_ref[...])


def token_delta_decode_frame_pallas(prev_frame, zres, *, block=(8, 128),
                                    interpret: bool = True):
    """prev [H, W] u8, zres [H, W] u8 -> reconstructed frame u8."""
    H, W = zres.shape
    bh = min(block[0], H)
    bw = min(block[1], W)
    grid = (-(-H // bh), -(-W // bw))
    fn = pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
                  pl.BlockSpec((bh, bw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(zres.shape, jnp.uint8),
        interpret=interpret,
    )
    return fn(prev_frame, zres)
