"""Pallas TPU kernel: Mamba2 chunked SSD scan (state-space duality).

Grid (batch, heads, chunks); the chunk axis is innermost so each (b, h)
pair walks its chunks sequentially with the running [hd, S] state in VMEM
scratch — the inter-chunk recurrence never touches HBM. Per chunk the work
is three MXU matmuls (C.B^T scores, (L*scores).X intra-chunk, decayed-state
outer products) on [Q, S]/[Q, hd] tiles; Q=128 aligns the matmul dims with
the MXU and keeps the VMEM working set to a few tiles:
  Q*(hd + 2S + Q) + hd*S floats  ~= 0.3 MB at Q=128, hd=64, S=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr,
            *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)  # [Q, hd]
    a = a_ref[0, :, 0].astype(jnp.float32)  # [Q]
    Bm = b_ref[0, :, 0].astype(jnp.float32)  # [Q, S]
    Cm = c_ref[0, :, 0].astype(jnp.float32)  # [Q, S]
    Q = x.shape[0]

    acs = jnp.cumsum(a)  # [Q]
    # intra-chunk decay matrix L[i, j] = exp(acs[i] - acs[j]) for i >= j
    dif = acs[:, None] - acs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(dif), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(L * scores, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state = state_scr[...]  # [hd, S]
    # inter-chunk contribution: y_off = (C * exp(acs)) @ state^T
    Cd = Cm * jnp.exp(acs)[:, None]
    y_off = jax.lax.dot_general(Cd, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: state' = exp(acs[-1]) * state + X^T @ (decay_to_end * B)
    decay_to_end = jnp.exp(acs[-1] - acs)  # [Q]
    Bd = Bm * decay_to_end[:, None]
    upd = jax.lax.dot_general(x, Bd, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    new_state = jnp.exp(acs[-1]) * state + upd
    state_scr[...] = new_state
    st_ref[0, 0] = new_state.astype(st_ref.dtype)


def ssd_scan_pallas(xdt, a_log, Bm, Cm, *, chunk: int = 128,
                    interpret: bool = True):
    """Shapes as ssd_scan_ref; s must be a multiple of `chunk` (the ops
    wrapper pads). G must divide nh (B/C broadcast per head group)."""
    b, s, nh, hd = xdt.shape
    G, S = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, s)
    assert s % Q == 0
    nc = s // Q
    hpg = nh // G

    grid = (b, nh, nc)
    fn = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, Q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, Q, 1, S),
                         lambda bi, hi, ci: (bi, ci, hi // hpg, 0)),
            pl.BlockSpec((1, Q, 1, S),
                         lambda bi, hi, ci: (bi, ci, hi // hpg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, hd, S), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, hd, S), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, S), jnp.float32)],
        interpret=interpret,
    )
    y, st = fn(xdt, a_log, Bm, Cm)
    return y, st
