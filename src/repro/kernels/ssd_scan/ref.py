"""Oracle for the chunked SSD scan kernel: the pure-jnp implementation in
repro.models.ssm (itself validated against step-by-step decode)."""
from __future__ import annotations

from repro.models.ssm import ssd_chunked


def ssd_scan_ref(xdt, a_log, Bm, Cm, chunk: int = 128):
    """xdt [b,s,nh,hd] (dt-folded); a_log [b,s,nh]; Bm/Cm [b,s,G,S].
    Returns (y [b,s,nh,hd] f32, final_state [b,nh,hd,S] f32)."""
    return ssd_chunked(xdt, a_log, Bm, Cm, chunk=chunk)
