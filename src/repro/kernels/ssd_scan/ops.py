"""Jitted public wrapper for the chunked SSD scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


@functools.partial(jax.jit,
                   static_argnames=("chunk", "use_kernel", "interpret"))
def ssd_scan(xdt, a_log, Bm, Cm, *, chunk: int = 128,
             use_kernel: bool = True, interpret: bool = True):
    """Mamba2 chunked SSD scan.

    xdt [b,s,nh,hd] (x pre-multiplied by dt), a_log [b,s,nh] (dt*A),
    Bm/Cm [b,s,G,S]. Returns (y [b,s,nh,hd] f32, final_state [b,nh,hd,S]).
    """
    if not use_kernel:
        return ssd_scan_ref(xdt, a_log, Bm, Cm, chunk=chunk)
    b, s = xdt.shape[:2]
    Q = min(chunk, s)
    pad = (-s) % Q
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, st = ssd_scan_pallas(xdt, a_log, Bm, Cm, chunk=Q,
                            interpret=interpret)
    return y[:, :s], st
