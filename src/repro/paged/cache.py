"""Paged KV cache (vLLM-style) for dense-attention models.

Device state: k_pages / v_pages [L, P, page_size, K, hd]; host state: the
allocator + per-sequence block tables. Writes happen through
  - ``write_prefill``: bulk scatter of freshly computed K/V, and
  - ``restore_tokens``: the frame-wise fused dequant+scatter kernel
    (repro.kernels.kv_restore), i.e. the paper's Sparse_frame_KV_transfer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.kv_restore.ops import kv_restore
from repro.paged.allocator import PageAllocator


@dataclasses.dataclass
class SeqInfo:
    seq_id: int
    block_table: List[int]
    context_len: int = 0


class PagedKVCache:
    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int = 16,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        L = cfg.num_layers
        K, hd = cfg.num_kv_heads, cfg.head_dim
        shape = (L, n_pages, page_size, K, hd)
        self.k_pages = jnp.zeros(shape, dtype)
        self.v_pages = jnp.zeros(shape, dtype)
        self.alloc = PageAllocator(n_pages)
        self.seqs: Dict[int, SeqInfo] = {}

    # -- sequence lifecycle ------------------------------------------------
    def add_seq(self, seq_id: int, n_tokens: int) -> SeqInfo:
        n = -(-n_tokens // self.page_size)
        pages = self.alloc.allocate(seq_id, n)
        info = SeqInfo(seq_id, pages, 0)
        self.seqs[seq_id] = info
        return info

    def ensure_capacity(self, seq_id: int, n_tokens: int) -> None:
        info = self.seqs[seq_id]
        need = -(-n_tokens // self.page_size)
        if need > len(info.block_table):
            info.block_table.extend(
                self.alloc.extend(seq_id, need - len(info.block_table)))

    def free_seq(self, seq_id: int) -> None:
        self.alloc.release(seq_id)
        self.seqs.pop(seq_id, None)

    # -- slot math -----------------------------------------------------------
    def slots_for(self, seq_id: int, positions: np.ndarray) -> np.ndarray:
        """Logical token positions -> physical page rows (flat)."""
        info = self.seqs[seq_id]
        bt = np.asarray(info.block_table)
        return bt[positions // self.page_size] * self.page_size + \
            positions % self.page_size

    def block_table_array(self, seq_ids: List[int],
                          max_pages: Optional[int] = None) -> np.ndarray:
        mp = max_pages or max(len(self.seqs[s].block_table)
                              for s in seq_ids)
        out = np.zeros((len(seq_ids), mp), np.int32)
        for i, s in enumerate(seq_ids):
            bt = self.seqs[s].block_table
            out[i, :len(bt)] = bt
        return out

    # -- device writes -------------------------------------------------------
    def write_prefill(self, layer: int, seq_id: int, k: jax.Array,
                      v: jax.Array, start_pos: int = 0) -> None:
        """k/v [s, K, hd] computed by a prefill pass."""
        s = k.shape[0]
        positions = np.arange(start_pos, start_pos + s)
        slots = jnp.asarray(self.slots_for(seq_id, positions), jnp.int32)
        ps = self.page_size
        L, P = self.k_pages.shape[:2]
        flat_k = self.k_pages[layer].reshape(P * ps, *self.k_pages.shape[3:])
        flat_v = self.v_pages[layer].reshape(P * ps, *self.v_pages.shape[3:])
        flat_k = flat_k.at[slots].set(k.astype(flat_k.dtype))
        flat_v = flat_v.at[slots].set(v.astype(flat_v.dtype))
        self.k_pages = self.k_pages.at[layer].set(
            flat_k.reshape(self.k_pages.shape[1:]))
        self.v_pages = self.v_pages.at[layer].set(
            flat_v.reshape(self.v_pages.shape[1:]))

    def write_decode_token(self, layer: int, seq_id: int, pos: int,
                           k: jax.Array, v: jax.Array) -> None:
        self.write_prefill(layer, seq_id, k[None], v[None], start_pos=pos)

    def restore_tokens(self, layer: int, kind: str, seq_id: int,
                       token_ids: np.ndarray, q_tokens: jax.Array,
                       scales: jax.Array) -> None:
        """Frame-wise restoration: decoded uint8 tokens -> page rows.

        q_tokens [n, K, hd] uint8 (one layer, one frame); scales [K].
        """
        slots = jnp.asarray(self.slots_for(seq_id, np.asarray(token_ids)),
                            jnp.int32)
        ps = self.page_size
        P = self.n_pages
        pages = self.k_pages if kind == "k" else self.v_pages
        flat = pages[layer].reshape(P * ps, *pages.shape[3:])
        flat = kv_restore(flat, q_tokens, scales, slots)
        updated = pages.at[layer].set(flat.reshape(pages.shape[1:]))
        if kind == "k":
            self.k_pages = updated
        else:
            self.v_pages = updated

    def gpu_bytes(self) -> int:
        return self.k_pages.nbytes + self.v_pages.nbytes
