"""Free-list page allocator (host-side bookkeeping for the paged cache)."""
from __future__ import annotations

from typing import Dict, List


class PageAllocator:
    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.owned: Dict[int, List[int]] = {}  # seq id -> pages

    @property
    def n_free(self) -> int:
        return len(self.free)

    def allocate(self, seq_id: int, n: int) -> List[int]:
        if n > len(self.free):
            raise MemoryError(
                f"paged cache OOM: want {n} pages, {len(self.free)} free")
        pages = [self.free.pop() for _ in range(n)]
        self.owned.setdefault(seq_id, []).extend(pages)
        return pages

    def extend(self, seq_id: int, n: int) -> List[int]:
        return self.allocate(seq_id, n)

    def release(self, seq_id: int) -> None:
        for p in self.owned.pop(seq_id, []):
            self.free.append(p)
