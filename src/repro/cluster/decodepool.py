"""Decode pool: N decoder instances with profiled latency lookup tables
(NVDEC chips on GPUs; host-CPU rANS workers in the TPU adaptation)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.adaptive import DecodeTable


@dataclasses.dataclass
class DecodeStats:
    jobs: int = 0
    busy_time: float = 0.0
    first_start: float = float("inf")
    last_end: float = 0.0

    def utilization(self, n_decoders: int) -> float:
        span = max(self.last_end - min(self.first_start, self.last_end),
                   1e-9)
        return self.busy_time / (span * n_decoders)


class DecodePool:
    def __init__(self, table: DecodeTable,
                 n_decoders: Optional[int] = None):
        self.table = table
        self.n = n_decoders or table.n_decoders
        self.busy_until = [0.0] * self.n
        self.active_resolution: Optional[str] = None
        self.stats = DecodeStats()

    def load_at(self, t: float) -> int:
        return sum(1 for b in self.busy_until if b > t)

    def decode(self, resolution: str, t_ready: float,
               size_scale: float = 1.0) -> Tuple[float, float]:
        """Schedule one chunk decode; returns (t_start, t_done).

        size_scale scales the table latency for chunks smaller/larger than
        the profile's reference chunk.
        """
        i = int(np.argmin(self.busy_until))
        t_start = max(t_ready, self.busy_until[i])
        conc = self.load_at(t_start) + 1
        lat = self.table.decode_latency(resolution, conc) * size_scale
        if (self.active_resolution is not None
                and resolution != self.active_resolution):
            lat += self.table.penalty[resolution]
        self.active_resolution = resolution
        t_done = t_start + lat
        self.busy_until[i] = t_done
        st = self.stats
        st.jobs += 1
        st.busy_time += lat
        st.first_start = min(st.first_start, t_start)
        st.last_end = max(st.last_end, t_done)
        return t_start, t_done
