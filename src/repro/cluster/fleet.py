"""Fleet-scale serving: a prefix-affinity router over N serving nodes.

The per-GPU pipeline (docs/fetch_pipeline.md) scales out here to the
ROADMAP north star's first fleet slice: **N serving nodes**, each with
its own `SharedLink`, decode pool, and `FetchController` plan stream,
fronted by a :class:`FleetRouter` that places every request by policy:

  * ``affinity`` — consistent-hash / longest-prefix-locality: a request
    whose prefix (or any trie ancestor of it) was routed before goes to
    the same serving node, where the node-local KV working set
    (:class:`_LocalKV`), host-staged prefetch, and link warmth already
    live, turning remote fetches into local hits (the LMCache
    cache-aware-routing idiom, PAPERS.md).  New prefixes land on a
    vnode consistent-hash ring; a load-pressure escape hatch spills a
    hot key to the least-loaded node when its sticky target runs too
    far above the fair share.
  * ``least_loaded`` — minimum cumulative assigned requests (the
    classic load balancer baseline: great spread, zero locality).
  * ``random`` — seeded hash of the rid (the null baseline).

The shared tiers stay shared: ONE `StorageCluster` serves every node's
fetches over its own node links, ONE `PrefetchManager` speculates for
the whole fleet (its mispredict budget splits per node — see
``PrefetchManager(n_nodes=)``), and ONE `FairScheduler` keeps per-user
virtual counters global, with the fleet draining its backlog centrally
so a lagging user on node 3 still beats an abusive flood bound for
node 0.

Determinism contract (docs/fleet.md): every placement appends
``("place", rid, node_id, reason)`` to :attr:`FleetRouter.events`, and
all router/local-KV state advances only on the request sequence (never
on clocks), so :class:`FleetSimulator` (analytic) and
:class:`LiveFleet` (virtual-clock real engines) replay byte-identical
placement, fairness, and storage logs for the same trace
(``tests/test_fleet.py``).  Storage-node churn is therefore scripted by
*dispatch index* (``churn_at_dispatch``), not wall time — per-engine
clocks drift across environments, dispatch counts cannot.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.cluster.simulator import MethodSpec, ServingSimulator, SimResult  # noqa: F401
from repro.core.scheduler import Request

FLEET_POLICIES = ("affinity", "least_loaded", "random")


class FleetRouter:
    """Deterministic request placer over ``n_nodes`` serving nodes.

    All load state is the cumulative per-node assignment count — a pure
    function of the placement sequence, so both environments replay the
    identical decision stream.  ``parent_of`` (optional) maps a prefix
    key to its trie parent (usually the storage catalog), letting the
    affinity policy route every extension of one session chain to the
    chain root's node.
    """

    def __init__(self, n_nodes: int, *, policy: str = "affinity",
                 vnodes: int = 64, spill_factor: float = 2.0,
                 spill_slack: int = 4,
                 parent_of: Optional[Callable[[str],
                                              Optional[str]]] = None):
        assert policy in FLEET_POLICIES, \
            f"unknown policy {policy!r} (have {FLEET_POLICIES})"
        assert n_nodes >= 1
        self.n_nodes = n_nodes
        self.policy = policy
        self.parent_of = parent_of
        self.spill_factor = float(spill_factor)
        self.spill_slack = int(spill_slack)
        #: cumulative requests assigned per node (the only load signal)
        self.assigned = [0] * n_nodes
        #: affinity-root key -> node index (updated on spill)
        self.sticky: Dict[str, int] = {}
        #: deterministic placement log: ("place", rid, node_id, reason)
        self.events: List[Tuple[str, int, str, str]] = []
        # consistent-hash ring: vnodes points per node, sha256 like the
        # storage tier's ring so placements survive future node churn
        self._ring = sorted((self._point(f"s{k}#{v}"), k)
                            for k in range(n_nodes) for v in range(vnodes))

    @staticmethod
    def _point(s: str) -> int:
        return int.from_bytes(
            hashlib.sha256(s.encode()).digest()[:8], "big")

    def _ring_node(self, key: str) -> int:
        pts = [p for p, _ in self._ring]
        i = bisect.bisect_right(pts, self._point(key)) % len(self._ring)
        return self._ring[i][1]

    def _least_loaded(self) -> int:
        return min(range(self.n_nodes),
                   key=lambda k: (self.assigned[k], k))

    def _affinity_key(self, req: Request) -> Optional[str]:
        """Root of the request's prefix chain: walk trie parents so the
        whole session chain shares one sticky entry (longest-prefix
        locality — an extension lands where its ancestors' KV lives)."""
        if req.prefix is None or req.reuse_tokens <= 0:
            return None
        key = req.prefix
        if self.parent_of is not None:
            seen = {key}
            while True:
                parent = self.parent_of(key)
                if parent is None or parent in seen:
                    break
                seen.add(parent)
                key = parent
        return key

    def _overloaded(self, k: int) -> bool:
        fair = (sum(self.assigned) + 1) / self.n_nodes
        return self.assigned[k] + 1 > (self.spill_factor * fair
                                       + self.spill_slack)

    def place(self, req: Request) -> int:
        """Pick the serving node for ``req`` and log the decision."""
        if self.policy == "random":
            k = self._point(f"rid:{req.rid}") % self.n_nodes
            reason = "random"
        elif self.policy == "least_loaded":
            k = self._least_loaded()
            reason = "least_loaded"
        else:  # affinity
            key = self._affinity_key(req)
            if key is None:
                # nothing to be sticky to: fall back to load balancing
                k = self._least_loaded()
                reason = "least_loaded"
            else:
                k = self.sticky.get(key)
                reason = "sticky"
                if k is None:
                    k = self._ring_node(key)
                    reason = "hash"
                if self._overloaded(k):
                    # escape hatch: the sticky target runs too hot —
                    # spill this chain to the least-loaded node and
                    # re-stick there (locality follows the spill)
                    k = self._least_loaded()
                    reason = "spill"
                self.sticky[key] = k
        self.assigned[k] += 1
        self.events.append(("place", req.rid, f"s{k}", reason))
        return k


class _LocalKV:
    """Token-capacity LRU model of one serving node's resident prefix
    KV (paged cache + node-local reuse).  Entries are inserted at
    *dispatch* time — not completion — so residency is a pure function
    of the placement/dispatch sequence and replays identically in both
    environments."""

    def __init__(self, capacity_tokens: int):
        self.capacity = int(capacity_tokens)
        self._entries: "OrderedDict[str, int]" = OrderedDict()

    @property
    def resident_tokens(self) -> int:
        return sum(self._entries.values())

    def hit(self, key: str, need_tokens: int) -> bool:
        n = self._entries.get(key)
        if n is None or n < need_tokens:
            return False
        self._entries.move_to_end(key)
        return True

    def put(self, key: str, n_tokens: int) -> None:
        if n_tokens > self.capacity:
            return
        self._entries[key] = max(self._entries.get(key, 0), n_tokens)
        self._entries.move_to_end(key)
        while self.resident_tokens > self.capacity:
            self._entries.popitem(last=False)  # evict LRU


@dataclasses.dataclass
class FleetResult:
    requests: List[Request]
    #: rid -> serving node index
    placements: Dict[int, int]
    #: the router's ("place", rid, node_id, reason) log
    router_events: List[Tuple[str, int, str, str]]
    fairness_events: List[Tuple[str, int, str, int]]
    sim_time: float
    #: requests dispatched per node (fetch dispatches, incl. local hits)
    dispatches_by_node: Dict[int, int]

    def fetching(self) -> List[Request]:
        return [r for r in self.requests if r.needs_fetch
                or r.requested_reuse_tokens]

    @property
    def local_hits(self) -> int:
        return sum(1 for r in self.requests if r.storage_hit == "local")


class _FleetMixin:
    """Placement / local-KV / dispatch-churn logic shared verbatim by
    the analytic and live fleet harnesses — written once so the two
    environments cannot drift (the no-second-pipeline rule)."""

    def _init_fleet(self, n_nodes: int, *, policy: str, router, storage,
                    local_kv_tokens: Optional[int],
                    churn_at_dispatch) -> None:
        self.n_nodes = n_nodes
        self.storage = storage
        parent_of = None
        if storage is not None:
            parent_of = lambda k: (  # noqa: E731
                storage.catalog[k].parent if k in storage.catalog
                else None)
        self.router = router if router is not None else FleetRouter(
            n_nodes, policy=policy, parent_of=parent_of)
        self.local: Optional[List[_LocalKV]] = None
        if local_kv_tokens:
            self.local = [_LocalKV(local_kv_tokens)
                          for _ in range(n_nodes)]
        self.placement: Dict[int, int] = {}
        self.dispatched = 0
        self.dispatches_by_node: Dict[int, int] = {}
        # storage churn keyed by GLOBAL dispatch index (deterministic
        # across environments, unlike per-engine clocks):
        # [(dispatch_idx, "fail" | "recover", node_id)]
        self._churn_dispatch = sorted(churn_at_dispatch or [])
        assert not self._churn_dispatch or storage is not None, \
            "churn_at_dispatch needs a storage cluster"

    def _local_hit(self, k: int, req: Request) -> bool:
        """Node-local residency check at dispatch: serve from the
        serving node's own KV working set iff the exact prefix is
        resident there AND the catalog still knows it (the live engine
        restores from the cataloged manifest)."""
        if self.local is None or not req.needs_fetch:
            return False
        if req.prefix is None or self.storage is None \
                or req.prefix not in self.storage.catalog:
            return False
        return self.local[k].hit(req.prefix, req.reuse_tokens)

    def _note_local(self, k: int, req: Request) -> None:
        """A full remote hit just dispatched to node ``k``: its prefix
        becomes node-local from now on (dispatch-time insertion)."""
        if self.local is not None and req.storage_hit == "full" \
                and req.prefix is not None:
            self.local[k].put(req.prefix, req.reuse_tokens)

    def _churn_tick(self, now: float) -> None:
        """Apply storage churn scheduled for the current dispatch
        index (called once immediately before every dispatch)."""
        while self._churn_dispatch \
                and self._churn_dispatch[0][0] <= self.dispatched:
            _, kind, nid = self._churn_dispatch.pop(0)
            if kind == "fail":
                self.storage.fail_node(nid, now)
            else:
                self.storage.recover_node(nid, now)

    def _count_dispatch(self, k: int) -> None:
        self.dispatched += 1
        self.dispatches_by_node[k] = self.dispatches_by_node.get(k, 0) + 1


class FleetSimulator(_FleetMixin):
    """N `ServingSimulator` nodes behind one `FleetRouter`, on one
    unified virtual clock.

    Each node keeps its own link, decode pool, scheduler, and
    `FetchController` (built by its `ServingSimulator`); this class
    only adds what single-node runs don't have: placement, the shared
    storage/prefetch/fairness wiring, central fair dispatch, and
    per-node engine stepping (a node busy with a prefill chunk does not
    block its siblings' pipeline events).
    """

    def __init__(self, cfg, method: MethodSpec, *, n_nodes: int,
                 bandwidth, policy: str = "affinity",
                 # per-node ServingSimulator knobs: the analytic cost
                 # model (chip/n_chips/.../mfu) is simulator-only, and
                 # the link/table shaping reaches LiveFleet engines
                 # through its engine_kw= pass-through instead
                 # repro-lint: allow(cross-env-parity)
                 chip: str = "h20", n_chips: int = 2,
                 # repro-lint: allow(cross-env-parity)
                 loss=None, link_policy=None, link_ramp=None,
                 storage=None, prefetch=None, fairness=None,
                 # repro-lint: allow(cross-env-parity) -- engine_kw
                 table=None,
                 router: Optional[FleetRouter] = None,
                 local_kv_tokens: Optional[int] = None,
                 # clock-scripted churn is sim-only; LiveFleet scripts
                 # the shared churn_at_dispatch= (dispatch-indexed) or
                 # calls engine fail_node()/recover_node() imperatively
                 # repro-lint: allow(cross-env-parity)
                 fail_at: Optional[List[Tuple[float, str]]] = None,
                 # repro-lint: allow(cross-env-parity)
                 recover_at: Optional[List[Tuple[float, str]]] = None,
                 churn_at_dispatch: Optional[
                     List[Tuple[int, str, str]]] = None,
                 # repro-lint: allow(cross-env-parity) -- analytic knobs
                 chunk_tokens: int = 10_000, prefill_chunk: int = 2048,
                 # repro-lint: allow(cross-env-parity) -- engine_kw/mfu
                 max_running: int = 8, mfu: float = 0.45):
        self.cfg = cfg
        self.method = method
        self.fairness = fairness
        self.prefetch = prefetch
        # per-node bundles: own link/pool/scheduler/controller each;
        # storage and prefetch are attached AFTER construction so the
        # shared tier is wired once (heal + speculation events pump on
        # node 0's controller, whose queue the fleet loop always drains)
        self.nodes = [ServingSimulator(
            cfg, method, chip=chip, n_chips=n_chips, bandwidth=bandwidth,
            loss=loss, link_policy=link_policy, link_ramp=link_ramp,
            storage=None, table=table, fairness=fairness,
            chunk_tokens=chunk_tokens, prefill_chunk=prefill_chunk,
            max_running=max_running, mfu=mfu) for _ in range(n_nodes)]
        for nd in self.nodes:
            nd.storage = storage
            nd.prefetch = prefetch
            nd.ctrl.prefetcher = prefetch
            if storage is not None:
                nd.ctrl.rtt_sink = storage.observe_rtt
                nd.ctrl.res_sink = storage.note_resolution_use
        if storage is not None:
            storage.bind(self.nodes[0].ctrl.push_event)
        if prefetch is not None:
            assert storage is not None, "prefetch= needs a storage cluster"
            prefetch.bind(self.nodes[0].ctrl.push_event)
            if prefetch.n_nodes == 1:
                prefetch.n_nodes = n_nodes  # split the budget per node
        self._init_fleet(n_nodes, policy=policy, router=router,
                         storage=storage, local_kv_tokens=local_kv_tokens,
                         churn_at_dispatch=churn_at_dispatch)
        assert not (fail_at or recover_at) or storage is not None, \
            "fail_at/recover_at need a storage cluster"
        self._churn: List[Tuple[float, str, str]] = sorted(
            [(t, "fail", nid) for t, nid in (fail_at or [])]
            + [(t, "recover", nid) for t, nid in (recover_at or [])])

    def _admit(self, nd: ServingSimulator,
               admitted: List[Request]) -> None:
        for req in admitted:
            if req.needs_fetch and self.method.reuse:
                # reused prefix KV is restored: prefill the suffix only
                nd.prefill_remaining[req.rid] = max(
                    req.prompt_len - req.reuse_tokens, 0)
                nd.context_done[req.rid] = req.reuse_tokens

    def run(self, requests: List[Request], max_new_tokens: int = 32,
            horizon: float = 200_000.0) -> FleetResult:
        arrivals = sorted(requests, key=lambda r: r.arrival)
        ai = 0
        now = 0.0
        busy = [0.0] * self.n_nodes
        pending: List[Optional[Tuple[List[Request], List[Request]]]] = \
            [None] * self.n_nodes
        stall = 0
        while now < horizon:
            progressed = False
            while self._churn and self._churn[0][0] <= now:
                t, kind, nid = self._churn.pop(0)
                if kind == "fail":
                    self.storage.fail_node(nid, t)
                else:
                    self.storage.recover_node(nid, t)
                progressed = True
            # route + submit arrivals due by `now`
            while ai < len(arrivals) and arrivals[ai].arrival <= now:
                r = arrivals[ai]
                ai += 1
                if not self.method.reuse:
                    r.reuse_tokens = 0
                k = self.router.place(r)
                self.placement[r.rid] = k
                nd = self.nodes[k]
                nd.prefill_remaining[r.rid] = r.prompt_len
                nd.context_done[r.rid] = 0
                nd.sched.submit(r, r.arrival)
                progressed = True
            for nd in self.nodes:
                nd.ctrl.pump(now)
            for nd in self.nodes:
                self._admit(nd, nd.sched.schedule(now))
            # central fetch dispatch: with fairness the ONE global
            # backlog is drained here (a per-node take_fetches() would
            # steal other nodes' requests); each ready fetch goes to
            # its placed node's controller
            if self.fairness is not None:
                ready = self.fairness.take()
            else:
                ready = [r for nd in self.nodes
                         for r in nd.sched.take_fetches()]
            # insertion-ordered dict, not a set: the drain below feeds
            # admission (which appends fairness/serve events), so its
            # order must never depend on per-process hashing; sorted()
            # keeps the historical node-index drain order
            reschedule: Dict[int, None] = {}
            for req in ready:
                k = self.placement[req.rid]
                self._churn_tick(now)
                nd = self.nodes[k]
                if self._local_hit(k, req):
                    # the prefix already lives on this serving node:
                    # no wire transfer, the fetch completes instantly
                    # (a 0-byte "fetched" in the fairness log)
                    req.storage_hit = "local"
                    req.storage_node = f"s{k}"
                    nd.sched.notify_fetch_done(req, now)
                    reschedule[k] = None
                else:
                    if nd._dispatch_fetch(req, now):
                        reschedule[k] = None  # miss: re-run admission
                    else:
                        self._note_local(k, req)
                    if self.prefetch is not None:
                        self.prefetch.note_node(req.prefix, f"s{k}")
                self._count_dispatch(k)
                progressed = True
            if self.prefetch is not None:
                self.prefetch.tick(now)
            for k in sorted(reschedule):
                self._admit(self.nodes[k],
                            self.nodes[k].sched.schedule(now))
            # start engine steps on idle nodes
            for k, nd in enumerate(self.nodes):
                if pending[k] is not None or busy[k] > now:
                    continue
                prefills = [r for r in nd.sched.running
                            if nd.prefill_remaining[r.rid] > 0]
                decodes = [r for r in nd.sched.running
                           if nd.prefill_remaining[r.rid] == 0
                           and r.tokens_out < max_new_tokens]
                step = 0.0
                if prefills:
                    head = prefills[0]
                    chunk = min(nd.prefill_chunk,
                                max(nd.prefill_remaining[head.rid], 1))
                    step += nd.cost.prefill_time(
                        chunk, ctx=nd.context_done[head.rid])
                    nd.prefill_remaining[head.rid] -= chunk
                    nd.context_done[head.rid] += chunk
                    if nd.prefill_remaining[head.rid] <= 0:
                        nd.prefill_remaining[head.rid] = 0
                if decodes:
                    ctx = float(np.mean([r.prompt_len + r.tokens_out
                                         for r in decodes]))
                    step += nd.cost.decode_step_time(len(decodes), ctx)
                if step > 0.0:
                    if any(f.gpu_decomp_until > now
                           for f in nd.ctrl.active.values()):
                        step *= (self.method.prefill_slowdown if prefills
                                 else self.method.decode_slowdown)
                    busy[k] = now + step
                    pending[k] = (prefills, decodes)
                    progressed = True
            # advance the unified clock to the next instant anything
            # happens anywhere in the fleet
            nxt = [busy[k] for k in range(self.n_nodes)
                   if pending[k] is not None]
            for nd in self.nodes:
                t = nd.ctrl.next_event_time()
                if t is not None:
                    nxt.append(t)
            if ai < len(arrivals):
                nxt.append(arrivals[ai].arrival)
            if self._churn:
                nxt.append(self._churn[0][0])
            if not nxt:
                break
            new_now = max(now, min(nxt))
            stall = stall + 1 if (new_now == now and not progressed) else 0
            if stall > 1000:
                break  # safety valve: nothing can make progress
            now = new_now
            # finalize engine steps that completed by `now`
            for k, nd in enumerate(self.nodes):
                if pending[k] is None or busy[k] > now:
                    continue
                prefills, decodes = pending[k]
                pending[k] = None
                tnow = busy[k]
                for req in prefills:
                    if nd.prefill_remaining[req.rid] == 0 \
                            and req.t_first_token is None:
                        req.t_first_token = tnow
                        req.tokens_out = 1
                        req.token_times.append(tnow)
                        if (req.storage_hit == "miss" and self.storage
                                and req.storage_miss_key):
                            self.storage.notify_recompute_done(
                                req.storage_miss_key, tnow)
                for req in decodes:
                    if req.t_first_token is None:
                        req.t_first_token = tnow
                    req.tokens_out += 1
                    req.token_times.append(tnow)
                    if req.tokens_out >= max_new_tokens:
                        nd.sched.finish(req, tnow)
        return FleetResult(
            requests=arrivals, placements=dict(self.placement),
            router_events=list(self.router.events),
            fairness_events=(list(self.fairness.events)
                             if self.fairness is not None else []),
            sim_time=now,
            dispatches_by_node=dict(self.dispatches_by_node))


class LiveFleet(_FleetMixin):
    """N virtual-clock `LiveEngine` nodes behind one `FleetRouter`: the
    replay twin of :class:`FleetSimulator` for the cross-environment
    determinism tests (real model, real codec, real paged memory on
    every node; the network and placement are the shared models).

    Engines run ``fetch_mode="sync"`` with ``external_dispatch=True``:
    the fleet drains the ONE fair backlog centrally and hands each
    ready fetch to its placed engine, mirroring the simulator's loop
    phase order (pump/serve per node in index order, then central
    dispatch).
    """

    def __init__(self, params, cfg, cluster, *, n_nodes: int, bandwidth,
                 policy: str = "affinity",
                 router: Optional[FleetRouter] = None,
                 fairness=None, prefetch=None,
                 local_kv_tokens: Optional[int] = None,
                 churn_at_dispatch: Optional[
                     List[Tuple[int, str, str]]] = None,
                 engine_kw: Optional[dict] = None):
        from repro.serving.engine import LiveEngine  # lazy: needs jax

        self.fairness = fairness
        self.prefetch = prefetch
        kw = dict(engine_kw or {})
        kw.setdefault("fetch_mode", "sync")
        assert kw["fetch_mode"] == "sync", \
            "LiveFleet replays the serialized baseline (sync engines)"
        self.engines = [LiveEngine(params, cfg, cluster,
                                   bandwidth=bandwidth, fairness=fairness,
                                   prefetch=prefetch,
                                   external_dispatch=True, **kw)
                        for _ in range(n_nodes)]
        # every engine ctor re-bound the shared cluster to its own
        # event queue; pin it to node 0's like the simulator does
        if self.engines[0].ctrl is not None:
            cluster.bind(self.engines[0].ctrl.push_event)
            if prefetch is not None:
                prefetch.bind(self.engines[0].ctrl.push_event)
        if prefetch is not None and prefetch.n_nodes == 1:
            prefetch.n_nodes = n_nodes
        self._init_fleet(n_nodes, policy=policy, router=router,
                         storage=cluster, local_kv_tokens=local_kv_tokens,
                         churn_at_dispatch=churn_at_dispatch)
        self._next_rid = 0

    def submit(self, tokens, prefix_key: Optional[str] = None,
               reuse_tokens: int = 0, max_new_tokens: int = 8,
               user: Optional[str] = None,
               slo_tier: Optional[str] = None) -> Request:
        """Route one request and submit it to its serving node.  Rids
        are fleet-global (engines receive them explicitly), so the
        placement/fairness logs line up with the simulator's."""
        rid = self._next_rid
        self._next_rid += 1
        probe = Request(rid=rid, arrival=0.0, prompt_len=len(tokens),
                        reuse_tokens=reuse_tokens, prefix=prefix_key,
                        max_new_tokens=max_new_tokens, user=user,
                        slo_tier=slo_tier)
        k = self.router.place(probe)
        self.placement[rid] = k
        return self.engines[k].submit(
            tokens, reuse_prefix=prefix_key, reuse_tokens=reuse_tokens,
            max_new_tokens=max_new_tokens, user=user, slo_tier=slo_tier,
            rid=rid)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            work = False
            for eng in self.engines:  # index order, like the simulator
                work = eng.step() or work
            if self.fairness is not None:
                ready = self.fairness.take()
            else:
                ready = [r for eng in self.engines
                         for r in eng.sched.take_fetches()]
            for req in ready:
                k = self.placement[req.rid]
                eng = self.engines[k]
                self._churn_tick(eng.now())
                if self._local_hit(k, req):
                    req.storage_hit = "local"
                    req.storage_node = f"s{k}"
                    eng.local_restore(req)
                    eng.sched.schedule(eng.now())
                else:
                    eng.dispatch_fetch(req)
                    self._note_local(k, req)
                    if self.prefetch is not None:
                        self.prefetch.note_node(req.prefix, f"s{k}")
                self._count_dispatch(k)
            if not work and not ready:
                break

    @property
    def finished(self) -> List[Request]:
        return [r for eng in self.engines for r in eng.finished]
