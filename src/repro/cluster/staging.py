"""Speculative prefix prefetch + host-memory staging tier (ISSUE 6).

The fetch pipeline so far is purely *reactive*: every fetch pays the
WAN transfer on the TTFT critical path, even for a prefix the workload
was guaranteed to ask for.  This module moves the WAN off that path for
predicted traffic, following sglang's ``PrefetchManager`` tick/commit
loop (SNIPPETS.md #1) and the KV-offloading host<->GPU bandwidth
analysis (PAPERS.md):

  * :class:`HostStagingTier` — a capacity-bounded host-DRAM cache
    between the remote :class:`~repro.cluster.storage.StorageCluster`
    and GPU paged memory.  It reuses :class:`StorageNode`'s byte-
    accurate admission/eviction, and its ``link`` is a PCIe-like
    host->GPU :class:`~repro.cluster.network.BandwidthTrace`
    (:data:`PCIE_H2D_GBPS`) — a staged hit still pays the h2d copy,
    just not the WAN.
  * :class:`PrefetchManager` — the predictor + speculation driver.
    The predictor runs over the prefix trie: every demand lookup heats
    the resolved key (popularity) and, more strongly, its cataloged
    children (*session continuation*: a session that just reused P
    tends to come back asking for P extended).  :meth:`tick` — called
    once per environment scheduling loop — turns heat above
    ``heat_threshold`` into speculative transfers; completions
    *commit* into the staging tier.

Link-weight contract
--------------------
Speculative transfers join the source node's `SharedLink` at
:data:`PREFETCH_WEIGHT` (mirroring ``network.HEAL_WEIGHT``) under a
**negative flow id**, so speculation never starves demand fetches.  Two
further protections: :meth:`PrefetchManager.request_prefetch` defers
while the source link carries any demand flow, and
:meth:`PrefetchManager.demand_started` (hooked from
``FetchController.start``) cancels in-flight speculation the moment a
demand fetch needs the same link.

Budget semantics
----------------
``mispredict_budget_bytes`` is a hard cap on *wasted* speculative
bytes: bytes already on the wire when a speculation is cancelled, plus
the stored bytes of staged entries evicted without ever serving a host
hit.  An entry that serves a hit is *earned* and its later eviction is
free.  Once ``wasted_bytes`` reaches the budget, new speculation is
declined (``budget_reject`` events) — prediction quality bounds cost.

Like the storage cluster, the manager keeps a deterministic
:attr:`PrefetchManager.events` log of ``(kind, key)`` tuples —
``prefetch_start`` / ``prefetch_done`` / ``prefetch_cancel`` /
``stage_evict`` / ``stage_reject`` / ``host_hit`` / ``budget_reject``
— a pure function of the access sequence with ``transport="sync"``, so
the analytic simulator and the live engine replay identical sequences
for a prefetch-then-hit trace (``tests/test_prefetch.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .network import HEAL_WEIGHT, BandwidthTrace
from .storage import StorageCluster, StorageNode, StoredPrefix

#: speculative transfers join the WAN link at the heal weight — the
#: same "background traffic never starves demand" contract heals use
PREFETCH_WEIGHT = HEAL_WEIGHT

#: host->GPU staging bandwidth (Gbps): ~16 GB/s, a PCIe gen4 x16 lane
#: at realistic efficiency (KV-offloading bottleneck analysis)
PCIE_H2D_GBPS = 128.0

#: base for speculative flow ids: negative (never collides with a rid)
#: and far below the heal-flow range (heals count down from -1)
_PREFETCH_FLOW_BASE = -1_000_000


class HostStagingTier:
    """Capacity-bounded host-DRAM staging cache in front of GPU memory.

    Internally one :class:`StorageNode` (same byte accounting, same
    deterministic eviction policies) whose ``link`` models the
    host->GPU copy path: a `BandwidthTrace` at :data:`PCIE_H2D_GBPS`
    by default.  Fetches resolved here ride that link through the
    ordinary ``FetchController`` machinery — no second pipeline.
    """

    def __init__(self, capacity_bytes: Optional[float], *,
                 h2d=None, policy: str = "lru"):
        self.node = StorageNode(
            "host", capacity_bytes, policy=policy,
            link=(h2d if h2d is not None
                  else BandwidthTrace.constant(PCIE_H2D_GBPS)))

    @property
    def link(self):
        return self.node.link

    @property
    def used_bytes(self) -> int:
        return self.node.used_bytes

    def contains(self, key: str) -> bool:
        return self.node.contains(key)

    def __repr__(self) -> str:
        return f"HostStagingTier({self.node!r})"


@dataclass
class _Speculation:
    """One in-flight speculative transfer (cancellable)."""
    key: str
    flow: int
    link: object
    handle: object
    nbytes: float
    source_id: str
    t_start: float


class PrefetchManager:
    """Predictor + speculation driver over a :class:`StorageCluster`.

    ``transport="link"`` streams each speculation over the source
    node's `SharedLink` (needs :meth:`bind`-ing to a virtual event
    queue); ``"sync"`` commits instantly — clock-free, for wall-clock
    engines and cross-environment replay tests, exactly like the
    cluster's ``heal="sync"``.
    """

    def __init__(self, cluster: StorageCluster, staging: HostStagingTier,
                 *, weight: float = PREFETCH_WEIGHT,
                 mispredict_budget_bytes: Optional[float] = None,
                 transport: str = "link", max_inflight: int = 2,
                 heat_threshold: float = 2.0,
                 continuation_boost: float = 2.0,
                 # user-level budget shares: with a
                 # repro.cluster.fairness.FairScheduler attached, waste
                 # is attributed to the prefix's demanding user and each
                 # user may only burn budget * prefetch_share(user) —
                 # one tenant's mispredictions cannot exhaust the
                 # shared budget (docs/fairness.md)
                 fairness=None,
                 # fleet mode (docs/fleet.md): with N serving nodes the
                 # mispredict budget additionally splits per node —
                 # each node may burn at most budget / n_nodes, so one
                 # node's cold working set cannot exhaust speculation
                 # for the whole fleet.  Harnesses attribute keys to
                 # nodes via note_node() at dispatch time.
                 n_nodes: int = 1):
        assert transport in ("link", "sync"), transport
        self.cluster = cluster
        self.staging = staging
        self.weight = weight
        self.budget = (float("inf") if mispredict_budget_bytes is None
                       else float(mispredict_budget_bytes))
        self.transport = transport
        self.max_inflight = max_inflight
        self.heat_threshold = heat_threshold
        self.continuation_boost = continuation_boost
        self.fairness = fairness
        self.heat: Dict[str, float] = {}
        self.events: List[Tuple[str, str]] = []
        self.wasted_bytes = 0.0
        self.wasted_by_user: Dict[str, float] = {}
        self.n_nodes = max(1, int(n_nodes))
        self.wasted_by_node: Dict[str, float] = {}
        self._node_of_prefix: Dict[str, str] = {}
        self.prefetches_started = 0
        self.prefetches_committed = 0
        self.prefetches_cancelled = 0
        self.host_hits = 0
        # staged keys that earned a host hit; insertion-ordered dict,
        # not a set, so any drain replays in hit order (repro-lint
        # ordered-iteration)
        self._earned: Dict[str, None] = {}
        self._inflight: Dict[str, _Speculation] = {}
        self._flow = _PREFETCH_FLOW_BASE
        self._push = None

    def __repr__(self) -> str:
        return (f"PrefetchManager({len(self.staging.node.residents)} "
                f"staged, {len(self._inflight)} in flight, "
                f"{self.wasted_bytes / 1e6:.1f} MB wasted)")

    def bind(self, push) -> None:
        """Wire the environment's virtual event queue (the fetch
        controller's ``push_event``) so ``transport="link"``
        speculations can schedule completions; also binds the staging
        tier's h2d link for host-resolved demand fetches."""
        self._push = push
        if self.staging.link is not None:
            self.staging.link.bind(push)

    # -- predictor ----------------------------------------------------------
    def _children(self, key: str) -> List[str]:
        return [e.key for e in self.cluster.catalog.values()
                if e.parent == key]

    def observe(self, key: Optional[str], now: float) -> None:
        """Fold one demand lookup into the heat map: the resolved key
        gains popularity heat, its cataloged children gain the (larger)
        session-continuation heat.  Environments call this on every
        demand resolution — host hit, remote hit, or miss alike."""
        if key is None:
            return
        self.heat[key] = self.heat.get(key, 0.0) + 1.0
        for child in self._children(key):
            self.heat[child] = (self.heat.get(child, 0.0)
                                + self.continuation_boost)

    def predictions(self) -> List[str]:
        """Cataloged keys hot enough to warm, hottest first (catalog
        insertion order breaks ties — deterministic)."""
        cand = [k for k in self.cluster.catalog
                if self.heat.get(k, 0.0) >= self.heat_threshold
                and not self.staging.contains(k)
                and k not in self._inflight]
        cand.sort(key=lambda k: -self.heat[k])
        return cand

    # -- host-first resolution ----------------------------------------------
    def host_lookup(self, key: str, requested_tokens: int,
                    now: float) -> Optional[StoredPrefix]:
        """Resolve a demand fetch host-first: a staged entry covering
        the full ask serves from host DRAM (and is marked *earned*);
        anything less falls back to the remote/miss paths."""
        e = self.staging.node.get(key, now)
        if e is None or e.n_tokens < requested_tokens:
            return None
        self._earned[key] = None
        self.host_hits += 1
        self.events.append(("host_hit", key))
        return e

    def host_lookup_tokens(self, token_ids,
                           now: float) -> Optional[StoredPrefix]:
        """Token-id variant (live-engine path): a staged entry whose
        token ids equal the requested reuse region serves host-first."""
        token_ids = np.asarray(token_ids)
        for key in list(self.staging.node.residents):
            e = self.cluster.catalog.get(key)
            if e is None or e.token_ids is None:
                continue
            if e.n_tokens == len(token_ids) \
                    and np.array_equal(e.token_ids, token_ids):
                return self.host_lookup(key, len(token_ids), now)
        return None

    # -- tick / commit loop (sglang PrefetchManager idiom) -------------------
    def tick(self, now: float) -> None:
        """Once per scheduling loop: turn predictions into speculative
        transfers, bounded by ``max_inflight``.  ``transport="link"``
        completions commit asynchronously from the event queue."""
        for key in self.predictions():
            if len(self._inflight) >= self.max_inflight:
                return
            self.request_prefetch(key, now)

    def request_prefetch(self, key: str, now: float) -> bool:
        """Validate and start one speculation (the sglang shape:
        already-staged / already-busy / nothing-to-fetch-from all
        decline safely; so does an exhausted mispredict budget)."""
        if self.staging.contains(key) or key in self._inflight:
            return False
        entry = self.cluster.catalog.get(key)
        if entry is None:
            return False
        if self._over_budget(key):
            self.events.append(("budget_reject", key))
            return False
        holders = self.cluster._resident_nodes(key, now)
        if not holders:
            return False  # not resident remotely: nothing to warm from
        source = self.cluster._pick_heal_source(holders)
        if self.transport == "sync" or source.link is None:
            self.prefetches_started += 1
            self.events.append(("prefetch_start", key))
            self._commit(key, entry, now)
            return True
        if source.link.demand_flows():
            return False  # demand traffic holds the link: defer
        assert self._push is not None, \
            "transport='link' needs bind() — pass the manager to a " \
            "simulator/virtual-clock engine, or use transport='sync'"
        self._flow -= 1
        flow = self._flow
        source.link.bind(self._push)
        source.link.open_flow(flow, weight=self.weight, t=now)
        self.prefetches_started += 1
        self.events.append(("prefetch_start", key))

        def done(t: float, key=key, entry=entry, link=source.link,
                 flow=flow) -> None:
            link.close_flow(flow)
            self._inflight.pop(key, None)
            self._commit(key, entry, t)

        handle = source.link.submit(flow, entry.stored_bytes, now, done)
        self._inflight[key] = _Speculation(
            key, flow, source.link, handle, float(entry.stored_bytes),
            source.node_id, now)
        return True

    def _commit(self, key: str, entry: StoredPrefix, now: float) -> None:
        ok, evicted = self.staging.node.put(entry, now)
        for k in evicted:
            self.events.append(("stage_evict", k))
            self._charge_waste(k)
        if ok:
            self.prefetches_committed += 1
            self.events.append(("prefetch_done", key))
        else:
            self.events.append(("stage_reject", key))

    def note_node(self, key: Optional[str], node_id: str) -> None:
        """Attribute ``key`` to the serving node that last demanded it.
        Fleet harnesses call this at dispatch time, so the per-node
        budget split is a pure function of the placement sequence
        (cross-environment deterministic, like every other log)."""
        if key is not None:
            self._node_of_prefix[key] = node_id

    def _over_budget(self, key: str) -> bool:
        """Budget check for one more speculation on ``key``: global cap
        without fairness; with a FairScheduler, the cap is the key's
        demanding user's share of the budget (an unattributed key —
        never demanded — falls back to the global check).  In fleet
        mode (``n_nodes > 1``) the demanding *node*'s even share
        ``budget / n_nodes`` is checked as well — whichever cap trips
        first declines the speculation."""
        if self.n_nodes > 1:
            node = self._node_of_prefix.get(key)
            if node is not None and self.wasted_by_node.get(node, 0.0) \
                    >= self.budget / self.n_nodes:
                return True
        if self.fairness is not None:
            user = self.fairness.prefix_user(key)
            if user is not None:
                cap = self.budget * self.fairness.prefetch_share(user)
                return self.wasted_by_user.get(user, 0.0) >= cap
        return self.wasted_bytes >= self.budget

    def _account_waste(self, key: str, nbytes: float) -> None:
        self.wasted_bytes += nbytes
        if self.fairness is not None:
            user = self.fairness.prefix_user(key)
            if user is not None:
                self.wasted_by_user[user] = \
                    self.wasted_by_user.get(user, 0.0) + nbytes
        node = self._node_of_prefix.get(key)
        if node is not None:
            self.wasted_by_node[node] = \
                self.wasted_by_node.get(node, 0.0) + nbytes

    def _charge_waste(self, key: str) -> None:
        """A staged entry left the tier: free if it earned a host hit,
        otherwise its stored bytes count against the budget."""
        if key in self._earned:
            self._earned.pop(key, None)
            return
        e = self.cluster.catalog.get(key)
        if e is not None:
            self._account_waste(key, float(e.stored_bytes))

    # -- demand pressure ------------------------------------------------------
    def demand_started(self, req, link, now: float) -> None:
        """Hooked from ``FetchController.start``: a demand fetch just
        opened on ``link``, so in-flight speculation riding the same
        link is cancelled — bytes already on the wire are charged to
        the mispredict budget.  Speculation on other links, and demand
        fetches resolved from the host tier, cancel nothing."""
        if link is self.staging.link:
            return
        for key, spec in list(self._inflight.items()):
            if spec.link is not link:
                continue
            link.cancel(spec.handle, now)
            link.close_flow(spec.flow)
            sent = spec.nbytes - max(
                getattr(spec.handle, "left", spec.nbytes), 0.0)
            self._account_waste(key, sent)
            self.prefetches_cancelled += 1
            self.events.append(("prefetch_cancel", key))
            del self._inflight[key]
