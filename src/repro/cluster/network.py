"""Network model: bandwidth traces, chunk loss, and shared-link arbitration.

Three layers, composed bottom-up into the WAN model the fetch pipeline
runs against (ROADMAP "WAN scenarios"; LMCache / KV-offloading analyses
show loss and contention, not raw bandwidth, dominate tail TTFT):

  * :class:`BandwidthTrace` — piecewise-constant link capacity over time.
    Transmission times integrate the trace exactly, so adaptive-resolution
    decisions see realistic partial-chunk bandwidth shifts (paper Fig. 17).
  * :class:`LossModel` — per-chunk-attempt drop decisions: independent
    Bernoulli, bursty Gilbert-Elliott (per-flow or *shared* cross-flow
    correlated), or a scripted drop set for tests.  Decisions are keyed
    on ``(flow, chunk, attempt)`` so a seeded model produces the *same*
    drop schedule in the analytic simulator and the virtual-clock live
    engine regardless of event interleaving.
  * :class:`SharedLink` — splits one trace across concurrent fetch flows
    (``fair`` weighted fluid sharing or ``drr`` deficit-round-robin chunk
    interleaving), replacing the old model where every in-flight fetch
    silently got the full trace bandwidth.  With ``ramp="slowstart"`` a
    joining flow's share multiplicatively grows toward its fair share
    instead of converging instantly (congestion-window-shaped ramp).

:class:`RttEstimator` (Jacobson/Karels SRTT/RTTVAR over chunk service
times) lives here too: the fetch controller uses it to derive the
per-flow adaptive retransmit timeout ``rto = srtt + 4*rttvar``.

Units
-----
Internally everything is **bytes/sec** and **seconds**.  All public
constructors take link rates in **Gbps** (``GBPS`` converts: 1 Gbps ==
1e9/8 bytes/sec); ``repr`` shows Gbps so printed traces are readable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

#: bytes/sec per Gbps (all internal rates are bytes/sec).
GBPS = 1e9 / 8.0

#: Default arbitration weight for storage-tier *heal* (re-replication)
#: flows on a SharedLink.  Heal traffic shares the same links live
#: fetches ride (`StorageCluster` with ``heal="link"``); joining at
#: half weight keeps recovery from doubling the tail TTFT of requests
#: in flight while the ring re-converges — under ``fair`` a heal flow
#: gets weight/total_weight of the trace, under ``drr`` proportionally
#: fewer bytes per round (see `SharedLink`).
HEAL_WEIGHT = 0.5


@dataclasses.dataclass(repr=False)
class BandwidthTrace:
    """Piecewise-constant link capacity.

    ``times`` holds segment start times in **seconds** (``times[0] == 0``);
    ``bps`` holds the capacity of each segment in **bytes/sec** (note: not
    bits — use :data:`GBPS` or the constructors, which take Gbps).
    """

    times: np.ndarray  # [n] segment start times (s), times[0] == 0
    bps: np.ndarray  # [n] capacity in each segment (bytes/sec)

    @staticmethod
    def constant(gbps: float) -> "BandwidthTrace":
        """Flat trace at ``gbps`` gigabits/sec (stored as bytes/sec)."""
        return BandwidthTrace(np.array([0.0]), np.array([gbps * GBPS]))

    @staticmethod
    def steps(segs: Sequence[Tuple[float, float]]) -> "BandwidthTrace":
        """``segs``: [(t_start_seconds, gbps), ...], t_start ascending
        from 0.  Rates are gigabits/sec at this constructor boundary."""
        t = np.array([s[0] for s in segs], np.float64)
        b = np.array([s[1] * GBPS for s in segs], np.float64)
        assert t[0] == 0.0
        return BandwidthTrace(t, b)

    @staticmethod
    def jittered(rng: np.random.Generator, base_gbps: float,
                 duration: float, seg_len: float = 2.0,
                 rel_std: float = 0.35,
                 floor_frac: float = 0.25) -> "BandwidthTrace":
        """Random-walk-free jitter: one i.i.d. normal multiplier per
        ``seg_len``-second segment.

        ``base_gbps`` is gigabits/sec; each segment's rate is
        ``base_gbps * m`` with ``m ~ N(1, rel_std)`` clipped to
        ``[floor_frac, 2.5]`` — so the realized *mean* rate can sit
        slightly above ``base_gbps`` when ``rel_std`` is large (the clip
        is asymmetric).  The trace covers ``[0, duration]`` and holds the
        last segment's rate forever after.
        """
        n = max(2, int(duration / seg_len) + 1)
        mult = np.clip(rng.normal(1.0, rel_std, n), floor_frac, 2.5)
        return BandwidthTrace(np.arange(n) * seg_len,
                              base_gbps * GBPS * mult)

    def __repr__(self) -> str:  # Gbps, not raw bytes/sec
        g = self.bps / GBPS
        if len(g) == 1:
            return f"BandwidthTrace({g[0]:g} Gbps)"
        return (f"BandwidthTrace({len(g)} segs, "
                f"{g[0]:g}->{g[-1]:g} Gbps, mean {g.mean():.3g} Gbps)")

    def bw_at(self, t: float) -> float:
        """Capacity at time ``t`` (seconds) in **bytes/sec**."""
        i = int(np.searchsorted(self.times, t, side="right") - 1)
        return float(self.bps[max(i, 0)])

    def next_change(self, t: float) -> float:
        """First segment boundary strictly after ``t`` (inf if none)."""
        i = int(np.searchsorted(self.times, t, side="right"))
        return float(self.times[i]) if i < len(self.times) else float("inf")

    def transmit(self, nbytes: float, t0: float) -> float:
        """Finish time (seconds) of an ``nbytes``-byte transfer starting
        at ``t0``, integrating the trace exactly."""
        remaining = float(nbytes)
        t = t0
        i = int(np.searchsorted(self.times, t0, side="right") - 1)
        i = max(i, 0)
        while True:
            bw = float(self.bps[i])
            seg_end = (float(self.times[i + 1])
                       if i + 1 < len(self.times) else np.inf)
            dt = remaining / bw
            if t + dt <= seg_end:
                return t + dt
            remaining -= (seg_end - t) * bw
            t = seg_end
            i += 1


# ---------------------------------------------------------------------------
# RTT estimation (Jacobson/Karels)
# ---------------------------------------------------------------------------


class RttEstimator:
    """Jacobson/Karels smoothed-RTT estimator over chunk service times.

    The fetch controller feeds it the service time (submit -> wire
    completion) of every *first-attempt* chunk delivery — retransmitted
    chunks are skipped per Karn's algorithm, since their samples are
    ambiguous — and reads back the retransmit timeout

        rto = srtt + max(K * rttvar, floor)

    clamped to the caller's ``[min_rto, max_rto]``.  The ``floor`` term
    plays the role of TCP's clock granularity ``G``: once service times
    stabilize, ``rttvar`` decays geometrically toward zero and without a
    floor the deadline would converge onto the completion time itself,
    turning float jitter into spurious retransmissions.
    """

    ALPHA = 1.0 / 8.0  # srtt gain
    BETA = 1.0 / 4.0  # rttvar gain
    K = 4.0  # variance multiplier in the RTO

    def __init__(self) -> None:
        self.srtt: Optional[float] = None
        self.rttvar: float = 0.0

    def observe(self, sample: float) -> None:
        if sample <= 0:
            return
        if self.srtt is None:
            self.srtt = sample
            self.rttvar = sample / 2.0
            return
        self.rttvar = ((1.0 - self.BETA) * self.rttvar
                       + self.BETA * abs(self.srtt - sample))
        self.srtt = (1.0 - self.ALPHA) * self.srtt + self.ALPHA * sample

    def rto(self, min_rto: float, max_rto: float) -> Optional[float]:
        """Current retransmit timeout, or None before the first sample
        (the caller seeds the pre-sample deadline from its bandwidth
        estimate instead)."""
        if self.srtt is None:
            return None
        raw = self.srtt + max(self.K * self.rttvar, min_rto)
        return min(max(raw, min_rto), max_rto)


# ---------------------------------------------------------------------------
# Chunk loss
# ---------------------------------------------------------------------------


class LossModel:
    """Per-chunk-attempt drop decisions for the WAN scenarios.

    Every transmission attempt of every chunk asks :meth:`dropped` once.
    Draws are keyed on ``(seed, flow, chunk_seq, attempt)`` — *not* on
    global call order — so the same seeded model replays the identical
    drop schedule in the analytic simulator and the virtual-clock live
    engine even though their event interleavings differ.  The decided
    schedule is recorded in :attr:`drops` as ``(flow, chunk_seq,
    attempt)`` triples.

    Modes
    -----
    ``bernoulli``        i.i.d. loss with probability ``p`` per attempt.
    ``gilbert_elliott``  two-state burst-loss chain (good/bad states with
                         per-state loss rates); the chain advances once
                         per attempt *per flow*, so burst structure is
                         deterministic given the per-flow attempt order
                         (which the controller serializes).
    ``ge_shared``        cross-flow **correlated** bursts: one shared
                         good/bad chain advanced per ``slot`` seconds of
                         virtual time (the link's physical state), so
                         concurrent flows see the same bursts.  The state
                         of slot ``n`` is a pure function of ``(seed,
                         n)``-seeded draws and the per-attempt loss draw
                         stays keyed on ``(flow, chunk, attempt)`` —
                         environments whose wire timings agree (same
                         bytes over the same link) replay the identical
                         schedule regardless of decode/restore timing.
    ``scripted``         an explicit drop set, for tests and docs.
    """

    def __init__(self, mode: str, seed: int = 0, *, p: float = 0.0,
                 good_to_bad: float = 0.05, bad_to_good: float = 0.25,
                 p_good: float = 0.001, p_bad: float = 0.5,
                 slot: float = 0.05,
                 script: Optional[Set[Tuple[int, int, int]]] = None):
        assert mode in ("bernoulli", "gilbert_elliott", "ge_shared",
                        "scripted")
        self.mode = mode
        self.seed = seed
        self.p = p
        self.good_to_bad = good_to_bad
        self.bad_to_good = bad_to_good
        self.p_good = p_good
        self.p_bad = p_bad
        self.slot = slot  # ge_shared: seconds per link-state step
        self.script = script or set()
        self.drops: List[Tuple[int, int, int]] = []  # decided drop schedule
        self.drop_slots: List[int] = []  # ge_shared: slot of each drop
        self.attempts = 0
        self._ge_state: Dict[int, bool] = {}  # flow -> in bad state?
        self._ge_step: Dict[int, int] = {}  # flow -> chain step counter
        self._shared: List[bool] = [False]  # slot idx -> in bad state?
        # one sequential stream drives the shared chain's transitions
        # (slot n's state depends only on (seed, draws 1..n), so every
        # instance replays the same states without a per-slot Generator)
        self._shared_rng = np.random.default_rng((seed, 0x6E57))

    # -- constructors -------------------------------------------------------
    @staticmethod
    def bernoulli(p: float, seed: int = 0) -> "LossModel":
        """Independent per-attempt loss with probability ``p``."""
        return LossModel("bernoulli", seed, p=p)

    @staticmethod
    def gilbert_elliott(seed: int = 0, *, good_to_bad: float = 0.05,
                        bad_to_good: float = 0.25, p_good: float = 0.001,
                        p_bad: float = 0.5) -> "LossModel":
        """Bursty loss: a per-flow good/bad Markov chain advanced once per
        attempt; losses are drawn at ``p_good``/``p_bad`` by state."""
        return LossModel("gilbert_elliott", seed, good_to_bad=good_to_bad,
                         bad_to_good=bad_to_good, p_good=p_good,
                         p_bad=p_bad)

    @staticmethod
    def scripted(drops: Set[Tuple[int, int, int]]) -> "LossModel":
        """Drop exactly the given ``(flow, chunk_seq, attempt)`` triples."""
        return LossModel("scripted", script=set(drops))

    @staticmethod
    def correlated(seed: int = 0, *, slot: float = 0.05,
                   good_to_bad: float = 0.05, bad_to_good: float = 0.25,
                   p_good: float = 0.001,
                   p_bad: float = 0.5) -> "LossModel":
        """Cross-flow correlated bursts: one **shared** Gilbert-Elliott
        link state sampled once per ``slot`` seconds of virtual time, so
        concurrent flows see bad periods together (a congested or fading
        WAN segment drops everyone's chunks at once, not one flow's)."""
        return LossModel("ge_shared", seed, slot=slot,
                         good_to_bad=good_to_bad, bad_to_good=bad_to_good,
                         p_good=p_good, p_bad=p_bad)

    # -- queries ------------------------------------------------------------
    def _draw(self, flow: int, seq: int, attempt: int) -> float:
        rng = np.random.default_rng(
            (self.seed, int(flow), int(seq), int(attempt)))
        return float(rng.random())

    def _shared_bad(self, slot_idx: int) -> bool:
        """State of the shared chain at time slot ``slot_idx``: a pure
        function of the seed and the slot (transition draws come from one
        sequential seeded stream, advanced — and memoized — front-to-
        back, so query order never changes the states)."""
        while len(self._shared) <= slot_idx:
            u = float(self._shared_rng.random())
            bad = self._shared[-1]
            bad = (u >= self.bad_to_good) if bad else \
                (u < self.good_to_bad)
            self._shared.append(bad)
        return self._shared[slot_idx]

    def dropped(self, flow: int, seq: int, attempt: int,
                now: float = 0.0) -> bool:
        """Decide (and record) whether this transmission attempt is lost.
        ``now`` is the attempt's delivery instant on the virtual clock —
        only the ``ge_shared`` mode reads it (to index the shared link
        state); the other modes stay keyed purely on the triple."""
        self.attempts += 1
        if self.mode == "scripted":
            lost = (flow, seq, attempt) in self.script
        elif self.mode == "bernoulli":
            lost = self._draw(flow, seq, attempt) < self.p
        elif self.mode == "ge_shared":
            slot_idx = max(int(now / self.slot), 0)
            bad = self._shared_bad(slot_idx)
            lost = self._draw(flow, seq, attempt) < \
                (self.p_bad if bad else self.p_good)
            if lost:
                self.drop_slots.append(slot_idx)
        else:  # gilbert_elliott: advance this flow's chain one step
            step = self._ge_step.get(flow, 0)
            self._ge_step[flow] = step + 1
            rng = np.random.default_rng((self.seed, int(flow), step))
            u_state, u_loss = rng.random(2)
            bad = self._ge_state.get(flow, False)
            bad = (u_state >= self.bad_to_good) if bad else \
                (u_state < self.good_to_bad)
            self._ge_state[flow] = bad
            lost = u_loss < (self.p_bad if bad else self.p_good)
        if lost:
            self.drops.append((flow, seq, attempt))
        return lost

    def mean_loss_rate(self) -> float:
        """Stationary per-attempt loss probability (for bulk-transfer
        baselines that model loss as a goodput haircut)."""
        if self.mode == "bernoulli":
            return self.p
        if self.mode in ("gilbert_elliott", "ge_shared"):
            denom = self.good_to_bad + self.bad_to_good
            frac_bad = self.good_to_bad / denom if denom else 0.0
            return frac_bad * self.p_bad + (1 - frac_bad) * self.p_good
        return 0.0


# ---------------------------------------------------------------------------
# Shared-link arbitration
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Xfer:
    flow: int
    nbytes: float
    left: float
    t_ready: float
    cb: Callable[[float], None]  # called with the finish time
    cancelled: bool = False  # abandoned duplicate: cb never fires


class SharedLink:
    """Splits one :class:`BandwidthTrace` across concurrent fetch flows.

    The fetch controller binds its event queue via :meth:`bind` and then
    submits chunk transfers with :meth:`submit`; the link schedules each
    transfer's completion event itself (re-timing in-flight transfers as
    flows join and leave), so both hook environments see the identical
    contention model.

    Policies
    --------
    ``fair``  weighted fluid (processor-sharing) model: at any instant
              every active flow receives ``weight / total_active_weight``
              of the trace capacity, split evenly over that flow's
              in-flight transfers (a flow retransmitting while its next
              chunk streams does not get a double share).
    ``drr``   deficit round robin at chunk granularity: the wire carries
              one chunk at a time at full trace rate; queued chunks are
              served in round-robin order with per-flow deficit counters,
              so a weight-2 flow gets ~2x the bytes of a weight-1 flow
              while both are backlogged.

    Ramp
    ----
    ``ramp="instant"`` (default) reproduces the classic fluid model: a
    joining flow snaps straight to its fair share.  ``ramp="slowstart"``
    shapes the join like a congestion window: the flow starts at
    ``ramp_init`` of its fair share and doubles every ``ramp_interval``
    seconds (in-flight transfers are re-timed at each ramp epoch) until
    it reaches the full share.  Capacity a ramping flow leaves unclaimed
    goes to fully-ramped flows; if every flow is still ramping the link
    runs underutilized — exactly the slow-start underutilization real
    transports pay.  Under ``drr`` the ramp factor scales the flow's
    deficit quantum instead.

    A single-flow ``fair`` link degenerates to the bare trace, so wrapping
    a dedicated link in :class:`SharedLink` changes nothing — which is why
    :func:`make_link` always wraps.
    """

    #: DRR service quantum added per round-robin visit (bytes).
    DRR_QUANTUM = 4e6

    def __init__(self, trace: BandwidthTrace, policy: str = "fair",
                 loss: Optional[LossModel] = None, ramp: str = "instant",
                 ramp_init: float = 0.125, ramp_interval: float = 0.5):
        assert policy in ("fair", "drr"), policy
        assert ramp in ("instant", "slowstart"), ramp
        # a zero initial share would stall fair-share math (and DRR's
        # quantum accumulation) forever
        assert 0.0 < ramp_init <= 1.0, ramp_init
        self.trace = trace
        self.policy = policy
        self.loss = loss
        self.ramp = ramp
        self.ramp_init = ramp_init
        self.ramp_interval = ramp_interval
        self._ramp: Dict[int, float] = {}  # flow -> share factor (<= 1)
        # per-open generation token: flow ids are reused (retransmit /
        # heal / prefetch flows close and reopen under the same id), and
        # a ramp epoch scheduled by a previous open must not advance the
        # ramp of a later one
        self._ramp_gen: Dict[int, int] = {}
        self._push: Optional[Callable[[float, Callable], None]] = None
        self._weights: Dict[int, float] = {}
        # share-change observers (ABR down-switching, ISSUE 7): called
        # with (t, reason) whenever the per-flow share structure moves —
        # a flow joins/leaves or a slow-start ramp epoch fires — so the
        # fetch controller can re-evaluate remaining chunks' resolution
        # at the collapse instant instead of a chunk boundary later
        self._share_listeners: List[Callable[[float, str], None]] = []
        # fair-mode state: fluid frontier + in-flight transfers
        self._xfers: List[_Xfer] = []
        self._t = 0.0
        self._epoch = 0
        # drr-mode state
        self._queue: List[_Xfer] = []
        self._order: List[int] = []  # round-robin flow order
        self._rr = 0
        self._deficit: Dict[int, float] = {}
        self._serving: Optional[_Xfer] = None
        self._busy_until = 0.0

    def __repr__(self) -> str:
        return (f"SharedLink({self.policy}, {len(self._weights)} flows, "
                f"{self.trace!r})")

    # -- controller wiring --------------------------------------------------
    def bind(self, push: Callable[[float, Callable], None]) -> None:
        """Receive the controller's event-queue ``push(t, fn)`` handle."""
        self._push = push

    def on_share_change(self,
                        fn: Callable[[float, str], None]) -> None:
        """Subscribe to share-structure changes.  ``fn(t, reason)`` fires
        synchronously when a flow joins (``"flow_join"``), leaves with a
        known time (``"flow_leave"``), or a slow-start ramp epoch
        re-shares the link (``"ramp_epoch"``).  Deterministic: driven
        only by open/close/ramp events on the virtual clock."""
        if fn not in self._share_listeners:
            self._share_listeners.append(fn)

    def _notify_share(self, t: Optional[float], reason: str) -> None:
        if t is None:
            return  # no virtual-clock timestamp: nothing to re-time
        for fn in list(self._share_listeners):
            fn(t, reason)

    def open_flow(self, flow: int, weight: float = 1.0,
                  t: Optional[float] = None) -> None:
        """Register a flow.  With ``ramp="slowstart"`` and a join time
        ``t``, the flow starts at ``ramp_init`` of its share and doubles
        every ``ramp_interval`` seconds (epochs ride the bound event
        queue); without ``t`` (or in ``instant`` mode) it joins at full
        share."""
        self._weights[flow] = float(weight)
        # every open (including a reopen of a reused flow id) starts a
        # fresh ramp generation; epochs scheduled by prior opens of the
        # same id become stale and are dropped in _ramp_epoch
        gen = self._ramp_gen.get(flow, 0) + 1
        self._ramp_gen[flow] = gen
        if flow not in self._order:
            self._order.append(flow)
            self._deficit.setdefault(flow, 0.0)
        if self.ramp == "slowstart" and t is not None \
                and self._push is not None:
            self._ramp[flow] = self.ramp_init
            self._push(t + self.ramp_interval,
                       lambda tt, fl=flow, g=gen: self._ramp_epoch(fl, tt, g))
        else:
            self._ramp.pop(flow, None)
        self._notify_share(t, "flow_join")

    def _ramp_epoch(self, flow: int, t: float, gen: int) -> None:
        """One slow-start doubling; re-times in-flight transfers."""
        if gen != self._ramp_gen.get(flow):
            return  # stale epoch from a previous open of this flow id
        cur = self._ramp.get(flow)
        if cur is None or flow not in self._weights:
            return  # flow finished ramping or already closed
        if self.policy == "fair":
            self._advance(t)
        nxt = min(1.0, cur * 2.0)
        if nxt >= 1.0:
            self._ramp.pop(flow, None)
        else:
            self._ramp[flow] = nxt
            self._push(t + self.ramp_interval,
                       lambda tt, fl=flow, g=gen: self._ramp_epoch(fl, tt, g))
        if self.policy == "fair":
            self._reschedule()
        self._notify_share(t, "ramp_epoch")

    def close_flow(self, flow: int, t: Optional[float] = None) -> None:
        """Unregister a flow.  ``t`` (optional) timestamps the leave for
        share-change listeners; legacy callers that omit it skip the
        notification (a leave only ever *raises* the survivors' shares,
        so no down-switch is missed)."""
        self._weights.pop(flow, None)
        self._ramp.pop(flow, None)
        self._reap(flow)
        self._notify_share(t, "flow_leave")

    # -- trace passthrough (estimator seeding; bulk blocking baseline) ------
    def bw_at(self, t: float) -> float:
        """Full-trace capacity at ``t`` in bytes/sec (flow shares are a
        runtime property; estimators learn them from observed chunks)."""
        return self.trace.bw_at(t)

    def transmit(self, nbytes: float, t0: float) -> float:
        """Unarbitrated bulk transfer occupying the whole trace: the
        inference-blocking (LMCache-style) baseline path."""
        return self.trace.transmit(nbytes, t0)

    # -- arbitrated submission ----------------------------------------------
    def submit(self, flow: int, nbytes: float, t0: float,
               cb: Callable[[float], None]) -> object:
        """Start an ``nbytes`` chunk transfer for ``flow`` at ``t0``;
        ``cb(t_done)`` fires from the controller's event queue when the
        wire transfer completes under the arbitration policy.  Returns an
        opaque handle accepted by :meth:`cancel`."""
        assert self._push is not None, "SharedLink.bind() not called"
        x = _Xfer(flow, float(nbytes), float(nbytes), t0, cb)
        if self.policy == "fair":
            self._advance(t0)
            self._xfers.append(x)
            self._reschedule()
        else:
            self._queue.append(x)
            if self._serving is None:
                self._dispatch(max(t0, self._busy_until))
        return x

    def cancel(self, handle: object, t: float) -> None:
        """Abandon an in-flight transfer (a superseded retransmit
        duplicate): its callback never fires.  Under ``fair`` the
        remaining bytes leave the fluid at ``t`` and the other transfers
        are re-timed; under ``drr`` a queued chunk is pulled from the
        queue, while a chunk already on the wire finishes occupying it
        (those bytes are committed) with its completion suppressed."""
        x = handle
        if not isinstance(x, _Xfer) or x.cancelled:
            return
        x.cancelled = True
        if self.policy == "fair":
            if x in self._xfers:
                self._advance(t)
                self._xfers.remove(x)
                self._reschedule()
        else:
            if x in self._queue:
                self._queue.remove(x)
                self._reap(x.flow)

    def _reap(self, flow: int) -> None:
        """Drop a closed flow from the DRR round-robin state once it has
        nothing queued or serving (deferred close_flow cleanup)."""
        if flow in self._weights or flow not in self._order:
            return
        busy = ((self._serving is not None and self._serving.flow == flow)
                or any(x.flow == flow for x in self._queue))
        if busy:
            return
        i = self._order.index(flow)
        self._order.remove(flow)
        if self._rr > i:
            self._rr -= 1
        if self._order:
            self._rr %= len(self._order)
        self._deficit.pop(flow, None)

    # -- fair: fluid weighted processor sharing -----------------------------
    def _shares(self) -> Dict[int, float]:
        """Per-transfer capacity fractions: each flow gets its (ramp-
        scaled) weighted share split evenly over its in-flight transfers;
        capacity that ramping flows leave unclaimed is redistributed to
        fully-ramped flows by weight (or left idle if all are ramping)."""
        per_flow: Dict[int, int] = {}
        for x in self._xfers:
            per_flow[x.flow] = per_flow.get(x.flow, 0) + 1
        w = {f: self._weights.get(f, 1.0) for f in per_flow}
        W = sum(w.values())
        share = {f: w[f] / W * self._ramp.get(f, 1.0) for f in per_flow}
        leftover = 1.0 - sum(share.values())
        full = [f for f in per_flow if f not in self._ramp]
        if leftover > 1e-12 and full:
            Wf = sum(w[f] for f in full)
            for f in full:
                share[f] += leftover * w[f] / Wf
        return {id(x): share[x.flow] / per_flow[x.flow]
                for x in self._xfers}

    def _advance(self, t: float) -> None:
        """Drain in-flight bytes at the current shares up to time ``t``."""
        while self._xfers and self._t < t:
            shares = self._shares()
            step = min(t, self.trace.next_change(self._t))
            bw = self.trace.bw_at(self._t)
            dt = step - self._t
            for x in self._xfers:
                x.left -= bw * shares[id(x)] * dt
            self._t = step
        self._t = max(self._t, t)

    def _reschedule(self) -> None:
        """Push a (possibly superseding) event at the earliest projected
        completion; stale events are ignored via the epoch counter."""
        self._epoch += 1
        if not self._xfers:
            return
        shares = self._shares()
        t_next = min(self.trace.transmit(max(x.left, 0.0) / shares[id(x)],
                                         self._t) for x in self._xfers)
        ep = self._epoch
        self._push(t_next, lambda t: self._tick(t, ep))

    @staticmethod
    def _drained(x: _Xfer) -> bool:
        # relative tolerance: integration error scales with transfer size
        return x.left <= 1e-6 + 1e-9 * x.nbytes

    def _tick(self, t: float, epoch: int) -> None:
        if epoch != self._epoch:
            return  # superseded by a later join/leave
        self._advance(t)
        done = [x for x in self._xfers if self._drained(x)]
        if not done and self._xfers:
            # numerical guard: if the earliest projected completion can no
            # longer advance the clock, the residue is pure float error —
            # force-complete it instead of ticking forever at time t
            shares = self._shares()
            nxt = min(self._xfers,
                      key=lambda x: self.trace.transmit(
                          x.left / shares[id(x)], t))
            if self.trace.transmit(nxt.left / shares[id(nxt)],
                                   t) <= t + 1e-9 * max(t, 1.0):
                nxt.left = 0.0
                done = [nxt]
        self._xfers = [x for x in self._xfers if x not in done]
        for x in done:
            # a callback earlier in this loop may have cancelled a later
            # transfer that drained in the same tick (e.g. a fetch abort
            # at a shared trace boundary) — honor it, as _drr_done does
            if not x.cancelled:
                x.cb(t)
        self._reschedule()

    # -- drr: serialized wire, deficit-round-robin chunk interleave ---------
    def _dispatch(self, t: float) -> None:
        backlogged = {x.flow for x in self._queue}
        if not backlogged:
            return
        while True:
            flow = self._order[self._rr]
            self._rr = (self._rr + 1) % len(self._order)
            if flow not in backlogged:
                continue
            self._deficit[flow] = self._deficit.get(flow, 0.0) + \
                self.DRR_QUANTUM * self._weights.get(flow, 1.0) * \
                self._ramp.get(flow, 1.0)
            head = next(x for x in self._queue if x.flow == flow)
            if self._deficit[flow] < head.nbytes:
                continue
            self._deficit[flow] -= head.nbytes
            self._queue.remove(head)
            if not any(x.flow == flow for x in self._queue):
                self._deficit[flow] = 0.0  # no banking credit while idle
            t_start = max(t, head.t_ready)
            t_done = self.trace.transmit(head.nbytes, t_start)
            self._serving = head
            self._busy_until = t_done
            self._push(t_done, lambda tt, h=head: self._drr_done(h, tt))
            return

    def _drr_done(self, x: _Xfer, t: float) -> None:
        self._serving = None
        if x.cancelled:  # abandoned mid-wire: bytes burned, no callback
            self._reap(x.flow)
        else:
            x.cb(t)  # may submit the flow's next chunk synchronously
        if self._serving is None and self._queue:
            self._dispatch(max(t, self._busy_until))

    @property
    def in_flight(self) -> int:
        return len(self._xfers) + len(self._queue) + \
            (1 if self._serving is not None else 0)

    @property
    def n_flows(self) -> int:
        """Open flows on this link (the serving node knows its own
        concurrency — used to seed projected service times before the
        first goodput sample lands)."""
        return len(self._weights)

    def demand_flows(self) -> int:
        """Open flows with non-negative ids.  Background transfers
        (storage heals, speculative prefetches) use negative flow ids by
        repo convention, so this counts the demand fetches currently on
        the link — the prefetcher defers new speculation while it is
        non-zero."""
        return sum(1 for fl in self._weights if fl >= 0)

    def ramp_factor(self, flow: int) -> float:
        """Current slow-start factor of ``flow`` (1.0 once fully ramped
        or in ``instant`` mode).  A sender knows its own congestion
        window: the fetch controller divides its projected service time
        by this, so self-imposed ramp slowness never reads as loss."""
        return self._ramp.get(flow, 1.0)

    def flow_share(self, flow: int) -> float:
        """Fraction of the trace capacity ``flow`` would receive right
        now under the fluid model: its (ramp-scaled) weighted share over
        every *open* flow, plus its part of the capacity that ramping
        flows leave unclaimed (redistributed to fully-ramped flows by
        weight, mirroring :meth:`_shares`).  Unlike ``_shares`` this is
        a pure function of the open/close/ramp state — no in-flight
        transfer bookkeeping — so the fetch controller can use it to
        rescale its bandwidth estimate deterministically when the share
        structure moves (ABR down-switching).  An unknown flow sees the
        full pipe (1.0)."""
        if flow not in self._weights:
            return 1.0
        w = self._weights
        W = sum(w.values())
        share = {f: w[f] / W * self._ramp.get(f, 1.0) for f in w}
        leftover = 1.0 - sum(share.values())
        full = [f for f in w if f not in self._ramp]
        if leftover > 1e-12 and full:
            Wf = sum(w[f] for f in full)
            for f in full:
                share[f] += leftover * w[f] / Wf
        return share[flow]


def make_link(bandwidth, policy: Optional[str] = None,
              loss: Optional[LossModel] = None,
              ramp: Optional[str] = None) -> SharedLink:
    """Wrap a :class:`BandwidthTrace` (or anything exposing ``bw_at`` /
    ``transmit``) into a :class:`SharedLink`; pass an existing link
    through unchanged (asserting no conflicting loss/policy/ramp
    request).  ``policy=None`` / ``ramp=None`` mean "caller doesn't
    care": bare traces get ``fair`` / ``instant``, existing links keep
    whatever they were built with."""
    if isinstance(bandwidth, SharedLink):
        assert loss is None or bandwidth.loss is loss, \
            "conflicting LossModel for an already-built SharedLink"
        assert policy is None or bandwidth.policy == policy, \
            f"link is {bandwidth.policy!r}, caller asked for {policy!r}"
        assert ramp is None or bandwidth.ramp == ramp, \
            f"link ramps {bandwidth.ramp!r}, caller asked for {ramp!r}"
        return bandwidth
    return SharedLink(bandwidth, policy=policy or "fair", loss=loss,
                      ramp=ramp or "instant")
