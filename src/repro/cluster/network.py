"""Network model: piecewise-constant bandwidth traces with jitter.

Transmission times integrate the trace exactly, so adaptive-resolution
decisions see realistic partial-chunk bandwidth shifts (paper Fig. 17).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

GBPS = 1e9 / 8.0


@dataclasses.dataclass
class BandwidthTrace:
    times: np.ndarray  # [n] segment start times, times[0] == 0
    bps: np.ndarray  # [n] bytes/sec in each segment

    @staticmethod
    def constant(gbps: float) -> "BandwidthTrace":
        return BandwidthTrace(np.array([0.0]), np.array([gbps * GBPS]))

    @staticmethod
    def steps(segs: Sequence[Tuple[float, float]]) -> "BandwidthTrace":
        """segs: [(t_start, gbps), ...] with t_start ascending from 0."""
        t = np.array([s[0] for s in segs], np.float64)
        b = np.array([s[1] * GBPS for s in segs], np.float64)
        assert t[0] == 0.0
        return BandwidthTrace(t, b)

    @staticmethod
    def jittered(rng: np.random.Generator, base_gbps: float,
                 duration: float, seg_len: float = 2.0,
                 rel_std: float = 0.35,
                 floor_frac: float = 0.25) -> "BandwidthTrace":
        n = max(2, int(duration / seg_len) + 1)
        mult = np.clip(rng.normal(1.0, rel_std, n), floor_frac, 2.5)
        return BandwidthTrace(np.arange(n) * seg_len,
                              base_gbps * GBPS * mult)

    def bw_at(self, t: float) -> float:
        i = int(np.searchsorted(self.times, t, side="right") - 1)
        return float(self.bps[max(i, 0)])

    def transmit(self, nbytes: float, t0: float) -> float:
        """Finish time of an nbytes transfer starting at t0."""
        remaining = float(nbytes)
        t = t0
        i = int(np.searchsorted(self.times, t0, side="right") - 1)
        i = max(i, 0)
        while True:
            bw = float(self.bps[i])
            seg_end = (float(self.times[i + 1])
                       if i + 1 < len(self.times) else np.inf)
            dt = remaining / bw
            if t + dt <= seg_end:
                return t + dt
            remaining -= (seg_end - t) * bw
            t = seg_end
            i += 1
