"""Discrete-event serving simulator: the TTFT/TPOT experiment harness.

The *policy code* under test (fetching-aware scheduler, Alg. 1 adaptive
resolution, Appx A.3 layer-wise admission) is the production code from
repro.core — the simulator only supplies clocks: an analytic engine cost
model (costmodel.py), bandwidth traces (network.py) and decode pools with
the paper's profiled NVDEC tables (decodepool.py). Compressed chunk sizes
are driven by ratios measured with the real codec on real KV tensors.

Methods modeled (paper §5.1 baselines):
  kvfetcher    video codec (ours), adaptive res, fetch-aware sched,
               layer-wise early admission, frame-wise restoration
  llm265       video codec w/o inter-frame prediction (lower ratio), fixed
               resolution, fetch-agnostic batching, chunk-wise restoration
  cachegen     arithmetic coding ratio, GPU CUDA decompression (contends:
               +50% prefill, +20% decode while active), HOL scheduling
  raw          Mooncake-style raw KV transfer, layer-wise pipeline, no
               decode stage
  lmcache_raw  raw KV transfer, inference-blocking fetch
  full_prefill no reuse at all
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.adaptive import (BandwidthEstimator, DecodeTable,
                                 select_resolution)
from repro.core.pipelining import non_blocking_ok
from repro.core.scheduler import FetchingAwareScheduler, ReqState, Request
from repro.cluster.costmodel import CHIPS, EngineCostModel
from repro.cluster.decodepool import DecodePool
from repro.cluster.network import BandwidthTrace

RESOLUTIONS = ("240p", "480p", "640p", "1080p")


@dataclasses.dataclass
class MethodSpec:
    name: str
    reuse: bool = True
    # fp16-relative compression ratio per resolution (video methods) or a
    # single "ratio" entry (byte-stream methods); 1.0 == raw fp16
    ratios: Dict[str, float] = dataclasses.field(default_factory=dict)
    adaptive: bool = False
    fixed_resolution: str = "1080p"
    uses_decode_pool: bool = True
    gpu_decomp_tokens_per_s: float = 0.0  # CacheGen-style CUDA decomp
    prefill_slowdown: float = 1.0  # while GPU decompression is active
    decode_slowdown: float = 1.0
    scheduler_policy: str = "kvfetcher"  # or fetch_agnostic
    layerwise_admission: bool = False
    framewise_restoration: bool = True
    blocking_fetch: bool = False  # LMCache: engine idles during fetch
    # Reproduce the paper's own chunk-size operating point (Appx A.2
    # tables: 180-256 MB per chunk) instead of deriving sizes from the
    # measured compression ratio. Used by the Fig. 17/23 experiments.
    use_table_sizes: bool = False


def kvfetcher_spec(ratios: Dict[str, float]) -> MethodSpec:
    return MethodSpec("kvfetcher", ratios=ratios, adaptive=True,
                      scheduler_policy="kvfetcher",
                      layerwise_admission=True, framewise_restoration=True)


def llm265_spec(ratio: float) -> MethodSpec:
    return MethodSpec("llm265", ratios={r: ratio for r in RESOLUTIONS},
                      adaptive=False, fixed_resolution="1080p",
                      scheduler_policy="fetch_agnostic",
                      framewise_restoration=False)


def cachegen_spec(ratio: float) -> MethodSpec:
    return MethodSpec("cachegen", ratios={"stream": ratio},
                      uses_decode_pool=False,
                      gpu_decomp_tokens_per_s=60_000,
                      prefill_slowdown=1.5, decode_slowdown=1.2,
                      scheduler_policy="fetch_agnostic",
                      framewise_restoration=False)


def raw_spec() -> MethodSpec:
    return MethodSpec("raw", ratios={"stream": 1.0}, uses_decode_pool=False,
                      scheduler_policy="kvfetcher",
                      layerwise_admission=True)


def lmcache_raw_spec() -> MethodSpec:
    return MethodSpec("lmcache_raw", ratios={"stream": 1.0},
                      uses_decode_pool=False,
                      scheduler_policy="fetch_agnostic",
                      blocking_fetch=True)


def full_prefill_spec() -> MethodSpec:
    return MethodSpec("full_prefill", reuse=False)


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    decode_pool_utilization: float
    decompress_buffer_high_water: float
    sim_time: float

    def fetching(self) -> List[Request]:
        return [r for r in self.requests if r.needs_fetch]

    def non_reuse(self) -> List[Request]:
        return [r for r in self.requests if not r.needs_fetch]


@dataclasses.dataclass
class _Fetch:
    req: Request
    n_chunks: int
    chunks_done: int = 0
    next_chunk: int = 0
    trans_free_at: float = 0.0
    est: Optional[BandwidthEstimator] = None
    active_res: Optional[str] = None
    gpu_decomp_until: float = 0.0
    chunk_latencies: List[float] = dataclasses.field(default_factory=list)


class ServingSimulator:
    def __init__(self, cfg: ModelConfig, method: MethodSpec, *,
                 chip: str = "h20", n_chips: int = 2,
                 bandwidth: BandwidthTrace,
                 table: Optional[DecodeTable] = None,
                 chunk_tokens: int = 10_000,
                 prefill_chunk: int = 2048,
                 max_running: int = 8,
                 mfu: float = 0.45):
        self.cfg = cfg
        self.method = method
        self.cost = EngineCostModel(cfg, CHIPS[chip], n_chips, mfu=mfu)
        self.bw = bandwidth
        self.table = table
        self.pool = DecodePool(table) if (table and
                                          method.uses_decode_pool) else None
        self.chunk_tokens = chunk_tokens
        self.prefill_chunk = prefill_chunk
        self.sched = FetchingAwareScheduler(
            method.scheduler_policy, max_running=max_running)
        self.fetches: Dict[int, _Fetch] = {}
        self.events: List[Tuple[float, int, Callable[[float], None]]] = []
        self._eid = 0
        self.buffer_high_water = 0.0
        # per-request engine progress
        self.prefill_remaining: Dict[int, int] = {}
        self.context_done: Dict[int, int] = {}

    # -- event helpers -------------------------------------------------------
    def _push(self, t: float, fn: Callable[[float], None]) -> None:
        self._eid += 1
        heapq.heappush(self.events, (t, self._eid, fn))

    def _drain(self, until: float) -> None:
        while self.events and self.events[0][0] <= until:
            t, _, fn = heapq.heappop(self.events)
            fn(t)

    # -- chunk size model ------------------------------------------------------
    def _chunk_bytes(self, n_tokens: int, res: str) -> float:
        """One chunk = one kind (K or V) x one 3-layer group x n_tokens."""
        if self.method.use_table_sizes and self.table is not None \
                and res in self.table.chunk_size_mb:
            return self.table.chunk_size_mb[res] * 1e6
        per_layer_kind = self.cfg.num_kv_heads * self.cfg.head_dim * 2
        raw = per_layer_kind * 3 * n_tokens
        key = res if res in self.method.ratios else "stream"
        return raw / self.method.ratios[key]

    def _n_chunks(self, reuse_tokens: int) -> int:
        # one video chunk covers chunk_tokens tokens x 3 layers (K and V):
        n_groups = max(1, -(-sum(1 for k in self.cfg.layer_kinds()
                                 if k == "attn") // 3))
        per_group = max(1, -(-reuse_tokens // self.chunk_tokens))
        return n_groups * per_group * 2  # k and v

    # -- fetch pipeline ---------------------------------------------------------
    def _start_fetch(self, req: Request, now: float) -> None:
        req.fetch_started = now
        f = _Fetch(req, self._n_chunks(req.reuse_tokens))
        f.est = BandwidthEstimator(self.bw.bw_at(now))
        f.trans_free_at = now
        self.fetches[req.rid] = f
        if self.method.blocking_fetch:
            # LMCache: engine idles; model as one bulk transfer + decode
            total = sum(self._chunk_bytes(self._tokens_of_chunk(f, i),
                                          self.method.fixed_resolution)
                        for i in range(f.n_chunks))
            t_done = self.bw.transmit(total, now)
            if self.pool:
                _, t_done = self.pool.decode(self.method.fixed_resolution,
                                             t_done,
                                             size_scale=f.n_chunks)
            self._track_buffer_chunkwise(f)
            self._push(t_done, lambda t, r=req: self._fetch_done(r, t))
            return
        self._send_next_chunk(f, now)

    def _tokens_of_chunk(self, f: _Fetch, i: int) -> int:
        per_group = max(1, -(-f.req.reuse_tokens // self.chunk_tokens))
        idx = i % per_group
        t0 = idx * self.chunk_tokens
        return max(0, min(f.req.reuse_tokens - t0, self.chunk_tokens))

    def _send_next_chunk(self, f: _Fetch, now: float) -> None:
        if f.next_chunk >= f.n_chunks:
            return
        i = f.next_chunk
        f.next_chunk += 1
        n_tok = self._tokens_of_chunk(f, i)
        if self.method.adaptive and self.table is not None:
            sizes = (None if self.method.use_table_sizes else
                     {r: int(self._chunk_bytes(n_tok, r))
                      for r in RESOLUTIONS})
            load = self.pool.load_at(now) if self.pool else 0
            res, _ = select_resolution(f.est.est, load, self.table,
                                       sizes_bytes=sizes,
                                       active_resolution=f.active_res)
        else:
            res = self.method.fixed_resolution
        f.active_res = res
        nbytes = self._chunk_bytes(n_tok, res)
        t_start = max(now, f.trans_free_at)
        t_done = self.bw.transmit(nbytes, t_start)
        f.trans_free_at = t_done
        f.est.observe(int(nbytes), t_done - t_start)

        def on_transmitted(t: float, f=f, res=res, nbytes=nbytes,
                           n_tok=n_tok, t_start=t_start):
            self._on_chunk_transmitted(f, res, nbytes, n_tok, t_start, t)

        self._push(t_done, on_transmitted)

    def _on_chunk_transmitted(self, f: _Fetch, res: str, nbytes: float,
                              n_tok: int, t_start: float, now: float
                              ) -> None:
        # keep the transmission pipe busy
        self._send_next_chunk(f, now)
        if self.pool is not None:
            ref_bytes = self.table.chunk_size_mb[res] * 1e6
            scale = max(nbytes / ref_bytes, 0.05)
            _, t_dec = self.pool.decode(res, now, size_scale=scale)
        elif self.method.gpu_decomp_tokens_per_s:
            # throughput is in full-KV tokens/s; one chunk holds only a
            # (3 layers x 1 kind) share of each token's KV
            n_attn = sum(1 for k in self.cfg.layer_kinds() if k == "attn")
            share = 3.0 / max(2 * n_attn, 1)
            dur = n_tok * share / self.method.gpu_decomp_tokens_per_s
            t_dec = max(now, f.gpu_decomp_until) + dur
            f.gpu_decomp_until = t_dec
        else:
            t_dec = now  # raw: nothing to decode
        if self.method.framewise_restoration:
            restore = 0.002
            frame_bytes = self.cfg.kv_bytes_per_token() / 2 * 64
            self.buffer_high_water = max(self.buffer_high_water,
                                         2 * frame_bytes)
        else:
            raw_chunk = self.cfg.kv_bytes_per_token() * n_tok
            restore = raw_chunk / (self.cost.chip.hbm_bw * 0.5)
            self.buffer_high_water = max(self.buffer_high_water,
                                         2.7 * raw_chunk)
        t_done = t_dec + restore
        f.chunk_latencies.append(t_done - t_start)
        self._push(t_done, lambda t, f=f: self._on_chunk_restored(f, t))

    def _track_buffer_chunkwise(self, f: _Fetch) -> None:
        raw_chunk = self.cfg.kv_bytes_per_token() * min(
            f.req.reuse_tokens, self.chunk_tokens)
        self.buffer_high_water = max(self.buffer_high_water, 2.7 * raw_chunk)

    def _on_chunk_restored(self, f: _Fetch, now: float) -> None:
        f.chunks_done += 1
        req = f.req
        if f.chunks_done >= f.n_chunks:
            self._fetch_done(req, now)
            return
        if (self.method.layerwise_admission and not req.early_admitted
                and req.state is ReqState.WAITING_FOR_KV):
            # estimate remaining per-layer decode and per-layer compute
            L = self.cfg.num_layers
            frac = f.chunks_done / f.n_chunks
            buffered = int(frac * L)
            rate = (np.mean(f.chunk_latencies[-4:])
                    if f.chunk_latencies else 1.0)
            per_layer_dec = rate * f.n_chunks / max(L, 1)
            dec = [per_layer_dec] * L
            comp = self.cost.layer_comp_times(req.prompt_len
                                              - req.reuse_tokens
                                              + self.prefill_chunk)
            if non_blocking_ok(dec, comp, buffered):
                self.sched.notify_early_admissible(req, now)

    def _fetch_done(self, req: Request, now: float) -> None:
        req.layers_ready = self.cfg.num_layers
        self.sched.notify_fetch_done(req, now)

    # -- main loop ----------------------------------------------------------------
    def run(self, requests: List[Request], max_new_tokens: int = 32,
            horizon: float = 100_000.0) -> SimResult:
        arrivals = sorted(requests, key=lambda r: r.arrival)
        ai = 0
        now = 0.0
        for req in arrivals:
            self.prefill_remaining[req.rid] = req.prompt_len
            self.context_done[req.rid] = 0
        while now < horizon:
            # admit arrivals and process async events up to `now`
            while ai < len(arrivals) and arrivals[ai].arrival <= now:
                r = arrivals[ai]
                if not self.method.reuse:
                    r.reuse_tokens = 0
                self.sched.submit(r, r.arrival)
                ai += 1
            self._drain(now)
            admitted = self.sched.schedule(now)
            for req in admitted:
                if req.needs_fetch and self.method.reuse:
                    # reused prefix KV is restored: prefill the suffix only
                    self.prefill_remaining[req.rid] = max(
                        req.prompt_len - req.reuse_tokens, 0)
                    self.context_done[req.rid] = req.reuse_tokens
            for req in self.sched.take_fetches():
                self._start_fetch(req, now)
            # engine work for this iteration
            prefills = [r for r in self.sched.running
                        if self.prefill_remaining[r.rid] > 0]
            decodes = [r for r in self.sched.running
                       if self.prefill_remaining[r.rid] == 0
                       and r.tokens_out < max_new_tokens]
            step = 0.0
            if prefills:
                head = prefills[0]
                chunk = min(self.prefill_chunk,
                            max(self.prefill_remaining[head.rid], 1))
                step += self.cost.prefill_time(
                    chunk, ctx=self.context_done[head.rid])
                self.prefill_remaining[head.rid] -= chunk
                self.context_done[head.rid] += chunk
                if self.prefill_remaining[head.rid] <= 0:
                    self.prefill_remaining[head.rid] = 0
            if decodes:
                ctx = np.mean([r.prompt_len + r.tokens_out
                               for r in decodes])
                step += self.cost.decode_step_time(len(decodes), ctx)
            if step == 0.0:
                # idle: jump to the next event/arrival
                nxt = []
                if self.events:
                    nxt.append(self.events[0][0])
                if ai < len(arrivals):
                    nxt.append(arrivals[ai].arrival)
                if not nxt:
                    break
                now = max(now, min(nxt))
                continue
            # CacheGen-style contention while CUDA decompression is active
            decomp_active = any(f.gpu_decomp_until > now
                                for f in self.fetches.values())
            if decomp_active:
                step *= (self.method.prefill_slowdown if prefills
                         else self.method.decode_slowdown)
            now += step
            tnow = now
            for req in prefills:
                if self.prefill_remaining[req.rid] == 0 \
                        and req.t_first_token is None:
                    req.t_first_token = tnow
                    req.tokens_out = 1
                    req.token_times.append(tnow)
            for req in decodes:
                if req.t_first_token is None:  # zero-suffix fetch request
                    req.t_first_token = tnow
                req.tokens_out += 1
                req.token_times.append(tnow)
                if req.tokens_out >= max_new_tokens:
                    self.sched.finish(req, tnow)
        util = (self.pool.stats.utilization(self.pool.n)
                if self.pool else 0.0)
        return SimResult(requests=arrivals,
                         decode_pool_utilization=util,
                         decompress_buffer_high_water=self.buffer_high_water,
                         sim_time=now)
