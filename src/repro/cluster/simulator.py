"""Discrete-event serving simulator: the TTFT/TPOT experiment harness.

The *policy code* under test (fetching-aware scheduler, Alg. 1 adaptive
resolution, Appx A.3 layer-wise admission) is the production code from
repro.core — since the async-fetch refactor the whole transmit -> decode
-> restore pipeline state machine is `repro.core.fetch_controller`, the
SAME code the live engine pumps; the simulator only supplies clocks: an
analytic engine cost model (costmodel.py), a WAN link model — bandwidth
traces shared across concurrent fetches by a fair/DRR arbiter, with
optional seeded chunk loss and retransmission (network.py) — and decode
pools with the paper's profiled NVDEC tables (decodepool.py).
Compressed chunk sizes are driven by ratios measured with the real codec
on real KV tensors.

With ``storage=`` a multi-node prefix tier (storage.py,
docs/storage_tier.md) resolves every fetch before it starts: full hits
fetch over the serving node's own link, partial hits fetch the resident
ancestor and recompute the tail, misses fall back to a full prefill —
and the tier's delayed write-on-miss re-admits the prefix only once
that prefill reaches its first token.  ``fail_at=[(t, node_id)]`` /
``recover_at=`` script node churn mid-run: failed nodes' keys re-route
to ring successors and re-replication heals stream over the nodes' own
links, contending with live fetches (ttft.storage.failover.* rows).

Methods modeled (paper §5.1 baselines):
  kvfetcher    video codec (ours), adaptive res, fetch-aware sched,
               layer-wise early admission, frame-wise restoration
  llm265       video codec w/o inter-frame prediction (lower ratio), fixed
               resolution, fetch-agnostic batching, chunk-wise restoration
  cachegen     arithmetic coding ratio, GPU CUDA decompression (contends:
               +50% prefill, +20% decode while active), HOL scheduling
  raw          Mooncake-style raw KV transfer, layer-wise pipeline, no
               decode stage
  lmcache_raw  raw KV transfer, inference-blocking fetch
  full_prefill no reuse at all
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.adaptive import DecodeTable
from repro.core.fetch import FetchPlan, synthetic_plan
from repro.core.fetch_controller import (ActiveFetch, FetchController,
                                         FetchHooks, PipelineConfig)
from repro.core.scheduler import FetchingAwareScheduler, Request
from repro.cluster.costmodel import CHIPS, EngineCostModel
from repro.cluster.decodepool import DecodePool
from repro.cluster.network import BandwidthTrace, LossModel, make_link
from repro.cluster.storage import StorageCluster

RESOLUTIONS = ("240p", "480p", "640p", "1080p")


@dataclasses.dataclass
class MethodSpec:
    name: str
    reuse: bool = True
    # fp16-relative compression ratio per resolution (video methods) or a
    # single "ratio" entry (byte-stream methods); 1.0 == raw fp16
    ratios: Dict[str, float] = dataclasses.field(default_factory=dict)
    adaptive: bool = False
    fixed_resolution: str = "1080p"
    uses_decode_pool: bool = True
    gpu_decomp_tokens_per_s: float = 0.0  # CacheGen-style CUDA decomp
    prefill_slowdown: float = 1.0  # while GPU decompression is active
    decode_slowdown: float = 1.0
    scheduler_policy: str = "kvfetcher"  # or fetch_agnostic
    layerwise_admission: bool = False
    framewise_restoration: bool = True
    blocking_fetch: bool = False  # LMCache: engine idles during fetch
    # False models the chunk-serial sync baseline (chunk i+1 waits for
    # chunk i's restore) — the WAN async-vs-sync comparisons flip this.
    pipelined: bool = True
    # Reproduce the paper's own chunk-size operating point (Appx A.2
    # tables: 180-256 MB per chunk) instead of deriving sizes from the
    # measured compression ratio. Used by the Fig. 17/23 experiments.
    use_table_sizes: bool = False
    # Retransmit-timeout mode: "adaptive" = per-flow Jacobson/Karels
    # estimator (default), "fixed" = projected wire time + the constant
    # PipelineConfig.retransmit_timeout grace (the non-adaptive baseline
    # the ttft.wan.adaptive.* bench rows compare against).
    rto_mode: str = "adaptive"
    # Per-chunk transmission-attempt cap; exhaustion (every copy lost)
    # aborts the fetch and falls back to full prefill via
    # notify_fetch_miss instead of stalling the request forever.
    max_attempts: int = 64
    # Resolution ladder the fetcher may select from (ABR selection picks
    # within this set; a storage hit further restricts it to the rungs
    # still resident on the serving node).  Cross-env tests narrow this
    # to match the live engine's registered manifest ladder.
    resolutions: Tuple[str, ...] = RESOLUTIONS


def kvfetcher_spec(ratios: Dict[str, float]) -> MethodSpec:
    return MethodSpec("kvfetcher", ratios=ratios, adaptive=True,
                      scheduler_policy="kvfetcher",
                      layerwise_admission=True, framewise_restoration=True)


def llm265_spec(ratio: float) -> MethodSpec:
    return MethodSpec("llm265", ratios={r: ratio for r in RESOLUTIONS},
                      adaptive=False, fixed_resolution="1080p",
                      scheduler_policy="fetch_agnostic",
                      framewise_restoration=False)


def cachegen_spec(ratio: float) -> MethodSpec:
    return MethodSpec("cachegen", ratios={"stream": ratio},
                      uses_decode_pool=False,
                      gpu_decomp_tokens_per_s=60_000,
                      prefill_slowdown=1.5, decode_slowdown=1.2,
                      scheduler_policy="fetch_agnostic",
                      framewise_restoration=False)


def raw_spec() -> MethodSpec:
    return MethodSpec("raw", ratios={"stream": 1.0}, uses_decode_pool=False,
                      scheduler_policy="kvfetcher",
                      layerwise_admission=True)


def lmcache_raw_spec() -> MethodSpec:
    return MethodSpec("lmcache_raw", ratios={"stream": 1.0},
                      uses_decode_pool=False,
                      scheduler_policy="fetch_agnostic",
                      blocking_fetch=True)


def full_prefill_spec() -> MethodSpec:
    return MethodSpec("full_prefill", reuse=False)


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    decode_pool_utilization: float
    decompress_buffer_high_water: float
    sim_time: float
    retransmits: int = 0  # loss-driven (genuine) resends
    # resends whose original (slow, not lost) copy later delivered: the
    # duplicate was cancelled and its bytes wasted — the signature of a
    # retransmit timeout shorter than the contended chunk service time
    spurious_retransmits: int = 0
    # ABR down/up-switch events, in emission order:
    # (rid, chunk_seq, from_res, to_res, reason) — timestamp-free so the
    # cross-environment replay tests compare them directly
    resolution_switches: List[Tuple[int, int, str, str, str]] = \
        dataclasses.field(default_factory=list)
    # user-level fairness decision log, in emission order:
    # (user, rid, kind, milli-counter) — timestamp-free, byte-identical
    # across environments for the same trace (docs/fairness.md); empty
    # unless the simulator was built with fairness=
    fairness_events: List[Tuple[str, int, str, int]] = \
        dataclasses.field(default_factory=list)

    def fetching(self) -> List[Request]:
        return [r for r in self.requests if r.needs_fetch]

    def non_reuse(self) -> List[Request]:
        return [r for r in self.requests if not r.needs_fetch]


class _SimHooks(FetchHooks):
    """Analytic cost models standing in for the live codec/restore path."""

    def __init__(self, sim: "ServingSimulator"):
        self.sim = sim

    @staticmethod
    def _n_tok(pc) -> int:
        return pc.ref.token_end - pc.ref.token_start

    def chunk_bytes(self, fetch: ActiveFetch, pc, res: str) -> float:
        return self.sim._chunk_bytes(self._n_tok(pc), res)

    def gpu_decomp_seconds(self, fetch: ActiveFetch, pc) -> float:
        # throughput is in full-KV tokens/s; one chunk holds only a
        # (3 layers x 1 kind) share of each token's KV
        cfg = self.sim.cfg
        n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
        share = 3.0 / max(2 * n_attn, 1)
        return (self._n_tok(pc) * share
                / self.sim.method.gpu_decomp_tokens_per_s)

    def restore_seconds(self, fetch: ActiveFetch, pc) -> float:
        if self.sim.method.framewise_restoration:
            return 0.002
        raw_chunk = self.sim.cfg.kv_bytes_per_token() * self._n_tok(pc)
        return raw_chunk / (self.sim.cost.chip.hbm_bw * 0.5)

    def buffer_bytes(self, fetch: ActiveFetch, pc) -> float:
        if self.sim.method.framewise_restoration:
            frame_bytes = self.sim.cfg.kv_bytes_per_token() / 2 * 64
            return 2 * frame_bytes  # residual + reference frame
        return 2.7 * self.sim.cfg.kv_bytes_per_token() * self._n_tok(pc)

    def bulk_buffer_bytes(self, fetch: ActiveFetch) -> float:
        raw_chunk = self.sim.cfg.kv_bytes_per_token() * min(
            fetch.req.reuse_tokens, self.sim.chunk_tokens)
        return 2.7 * raw_chunk

    def comp_times(self, req: Request):
        return self.sim.cost.layer_comp_times(
            req.prompt_len - req.reuse_tokens + self.sim.prefill_chunk)


class ServingSimulator:
    def __init__(self, cfg: ModelConfig, method: MethodSpec, *,
                 # analytic engine cost model knobs — simulator-only by
                 # construction (the live engine runs real compute)
                 # repro-lint: allow(cross-env-parity)
                 chip: str = "h20", n_chips: int = 2,
                 bandwidth: BandwidthTrace,
                 loss: Optional[LossModel] = None,
                 link_policy: Optional[str] = None,  # None -> "fair"
                 link_ramp: Optional[str] = None,  # None -> "instant"
                 storage: Optional[StorageCluster] = None,
                 # speculative prefetch + host staging tier: a
                 # repro.cluster.staging.PrefetchManager over `storage`
                 prefetch=None,
                 # scripted storage-node churn: fail_at=[(t, node_id)]
                 # kills nodes mid-run, recover_at brings them back.
                 # Sim-only ctor form: LiveEngine scripts the identical
                 # churn imperatively via fail_node()/recover_node()
                 # (clock-scale-free, so the logs still replay)
                 # repro-lint: allow(cross-env-parity)
                 fail_at: Optional[List[Tuple[float, str]]] = None,
                 # repro-lint: allow(cross-env-parity)
                 recover_at: Optional[List[Tuple[float, str]]] = None,
                 table: Optional[DecodeTable] = None,
                 # user-level fair scheduling: a
                 # repro.cluster.fairness.FairScheduler shared with the
                 # FetchingAwareScheduler (docs/fairness.md)
                 fairness=None,
                 # analytic chunking/throughput knobs (the live engine
                 # derives these from the model + paged memory)
                 # repro-lint: allow(cross-env-parity)
                 chunk_tokens: int = 10_000,
                 # repro-lint: allow(cross-env-parity)
                 prefill_chunk: int = 2048,
                 max_running: int = 8,
                 # repro-lint: allow(cross-env-parity)
                 mfu: float = 0.45):
        self.cfg = cfg
        self.method = method
        self.cost = EngineCostModel(cfg, CHIPS[chip], n_chips, mfu=mfu)
        # concurrent fetches share (and contend for) one WAN link; chunks
        # may additionally be dropped by the loss model and retransmitted.
        # With a multi-node ``storage`` tier each fetch is instead routed
        # over the serving node's own link (this one stays the default for
        # nodes without a dedicated link).
        self.storage = storage
        if storage is not None and (loss is not None
                                    or link_policy is not None
                                    or link_ramp is not None):
            assert all(n.link is None for n in storage.nodes), \
                "loss=/link_policy=/link_ramp= only shape the default " \
                "link; nodes with their own links must carry their own " \
                "LossModel/policy/ramp: StorageNode(link=make_link(" \
                "trace, policy=, loss=, ramp=))"
        self.link = make_link(bandwidth, policy=link_policy, loss=loss,
                              ramp=link_ramp)
        self.bw = self.link.trace
        self.table = table
        self.pool = DecodePool(table) if (table and
                                          method.uses_decode_pool) else None
        self.chunk_tokens = chunk_tokens
        self.prefill_chunk = prefill_chunk
        self.fairness = fairness
        self.sched = FetchingAwareScheduler(
            method.scheduler_policy, max_running=max_running,
            fairness=fairness)
        self.ctrl = FetchController(
            self.sched, self.link, table=table, pool=self.pool,
            config=PipelineConfig(
                adaptive=method.adaptive,
                fixed_resolution=method.fixed_resolution,
                pipelined=method.pipelined,
                layerwise_admission=method.layerwise_admission,
                blocking_fetch=method.blocking_fetch,
                gpu_decomp_tokens_per_s=method.gpu_decomp_tokens_per_s,
                use_table_sizes=method.use_table_sizes,
                resolutions=method.resolutions,
                rto_mode=method.rto_mode,
                max_attempts=method.max_attempts),
            hooks=_SimHooks(self), prefetcher=prefetch)
        # scripted node churn, merged and time-ordered; heal transfers
        # (heal="link") schedule their completions on the controller's
        # event queue so they contend with live fetches
        assert not (fail_at or recover_at) or storage is not None, \
            "fail_at/recover_at need a storage cluster"
        self._churn: List[Tuple[float, str, str]] = sorted(
            [(t, "fail", nid) for t, nid in (fail_at or [])]
            + [(t, "recover", nid) for t, nid in (recover_at or [])])
        if storage is not None:
            storage.bind(self.ctrl.push_event)
            # completed fetches report their flow's smoothed RTT keyed
            # by serving node — drives RTT-aware replica/heal selection
            self.ctrl.rtt_sink = storage.observe_rtt
            # ...and which resolutions they actually delivered, steering
            # per-resolution eviction on the serving node
            self.ctrl.res_sink = storage.note_resolution_use
        self.prefetch = prefetch
        if prefetch is not None:
            assert storage is not None, "prefetch= needs a storage cluster"
            prefetch.bind(self.ctrl.push_event)
        # per-request engine progress
        self.prefill_remaining: Dict[int, int] = {}
        self.context_done: Dict[int, int] = {}

    # -- chunk size model ------------------------------------------------------
    def _chunk_bytes(self, n_tokens: int, res: str) -> float:
        """One chunk = one kind (K or V) x one 3-layer group x n_tokens."""
        if self.method.use_table_sizes and self.table is not None \
                and res in self.table.chunk_size_mb:
            return self.table.chunk_size_mb[res] * 1e6
        per_layer_kind = self.cfg.num_kv_heads * self.cfg.head_dim * 2
        raw = per_layer_kind * 3 * n_tokens
        key = res if res in self.method.ratios else "stream"
        return raw / self.method.ratios[key]

    def _build_plan(self, req: Request) -> FetchPlan:
        n_attn = sum(1 for k in self.cfg.layer_kinds() if k == "attn")
        return synthetic_plan(req.rid, req.reuse_tokens, n_attn,
                              self.chunk_tokens)

    # -- storage-tier fetch dispatch ---------------------------------------
    def _dispatch_fetch(self, req: Request, now: float) -> bool:
        """Start ``req``'s fetch; with a storage tier, resolve residency
        first.  A full hit fetches everything over the serving node's
        link; a partial hit fetches the resident *ancestor* (the tail is
        recomputed as extra suffix prefill); a miss re-queues the request
        as a plain full prefill.  Returns True on a miss (the caller must
        re-run admission — there is no fetch event to wait for)."""
        if self.storage is None:
            self.ctrl.start(req, self._build_plan(req), now)
            return False
        if self.prefetch is not None:
            staged = self.prefetch.host_lookup(req.prefix,
                                               req.reuse_tokens, now)
            if staged is not None:
                # host-first: a staged full hit rides the staging
                # tier's h2d link — the WAN is off the TTFT path
                req.storage_hit = "host"
                req.storage_node = "host"
                self.prefetch.observe(req.prefix, now)
                self.ctrl.start(req, self._build_plan(req), now,
                                link=self.prefetch.staging.link)
                return False
        hit = self.storage.lookup(req.prefix, now,
                                  requested_tokens=req.reuse_tokens)
        if self.prefetch is not None:
            self.prefetch.observe(req.prefix, now)
        req.storage_hit = hit.kind
        if hit.kind == "miss":
            req.storage_miss_key = hit.missed_key
            self.sched.notify_fetch_miss(req, now)
            return True
        req.storage_node = hit.node.node_id
        if hit.kind == "partial":
            req.requested_reuse_tokens = req.reuse_tokens
            req.reuse_tokens = hit.covered_tokens
        self.ctrl.start(req, self._build_plan(req), now,
                        link=hit.node.link,
                        resolutions=hit.resolutions,
                        served_key=hit.entry.key)
        return False

    # -- main loop ----------------------------------------------------------------
    def run(self, requests: List[Request], max_new_tokens: int = 32,
            horizon: float = 100_000.0) -> SimResult:
        arrivals = sorted(requests, key=lambda r: r.arrival)
        ai = 0
        now = 0.0
        for req in arrivals:
            self.prefill_remaining[req.rid] = req.prompt_len
            self.context_done[req.rid] = 0
        while now < horizon:
            # scripted node churn due by `now` (before arrivals, so a
            # request arriving at the failure instant sees the new ring)
            while self._churn and self._churn[0][0] <= now:
                t, kind, nid = self._churn.pop(0)
                if kind == "fail":
                    self.storage.fail_node(nid, t)
                else:
                    self.storage.recover_node(nid, t)
            # admit arrivals and process pipeline events up to `now`
            while ai < len(arrivals) and arrivals[ai].arrival <= now:
                r = arrivals[ai]
                if not self.method.reuse:
                    r.reuse_tokens = 0
                self.sched.submit(r, r.arrival)
                ai += 1
            self.ctrl.pump(now)
            admitted = self.sched.schedule(now)
            for req in admitted:
                if req.needs_fetch and self.method.reuse:
                    # reused prefix KV is restored: prefill the suffix only
                    self.prefill_remaining[req.rid] = max(
                        req.prompt_len - req.reuse_tokens, 0)
                    self.context_done[req.rid] = req.reuse_tokens
            missed = False
            for req in self.sched.take_fetches():
                missed |= self._dispatch_fetch(req, now)
            if self.prefetch is not None:
                # sglang-style tick: launch speculation for heated
                # prefixes (deferred while demand holds the link)
                self.prefetch.tick(now)
            if missed:
                # miss fallbacks re-entered the waiting queue with
                # reuse_tokens=0; admit them now (their full-prompt
                # prefill state was set at arrival and still stands)
                self.sched.schedule(now)
            # engine work for this iteration
            prefills = [r for r in self.sched.running
                        if self.prefill_remaining[r.rid] > 0]
            decodes = [r for r in self.sched.running
                       if self.prefill_remaining[r.rid] == 0
                       and r.tokens_out < max_new_tokens]
            step = 0.0
            if prefills:
                head = prefills[0]
                chunk = min(self.prefill_chunk,
                            max(self.prefill_remaining[head.rid], 1))
                step += self.cost.prefill_time(
                    chunk, ctx=self.context_done[head.rid])
                self.prefill_remaining[head.rid] -= chunk
                self.context_done[head.rid] += chunk
                if self.prefill_remaining[head.rid] <= 0:
                    self.prefill_remaining[head.rid] = 0
            if decodes:
                ctx = np.mean([r.prompt_len + r.tokens_out
                               for r in decodes])
                step += self.cost.decode_step_time(len(decodes), ctx)
            if step == 0.0:
                # idle: jump to the next event/arrival/churn instant
                nxt = []
                t_ev = self.ctrl.next_event_time()
                if t_ev is not None:
                    nxt.append(t_ev)
                if ai < len(arrivals):
                    nxt.append(arrivals[ai].arrival)
                if self._churn:
                    # churn fires at its scheduled instant even after
                    # the last arrival: an in-flight fetch must see the
                    # heal-flow contention, and recover_at entries must
                    # execute so the cluster's post-run state is honest
                    nxt.append(self._churn[0][0])
                if not nxt:
                    break
                now = max(now, min(nxt))
                continue
            # CacheGen-style contention while CUDA decompression is active
            decomp_active = any(f.gpu_decomp_until > now
                                for f in self.ctrl.active.values())
            if decomp_active:
                step *= (self.method.prefill_slowdown if prefills
                         else self.method.decode_slowdown)
            now += step
            tnow = now
            for req in prefills:
                if self.prefill_remaining[req.rid] == 0 \
                        and req.t_first_token is None:
                    req.t_first_token = tnow
                    req.tokens_out = 1
                    req.token_times.append(tnow)
                    if (req.storage_hit == "miss" and self.storage
                            and req.storage_miss_key):
                        # delayed write-on-miss: the recomputed KV
                        # exists from this instant, not from lookup time
                        self.storage.notify_recompute_done(
                            req.storage_miss_key, tnow)
            for req in decodes:
                if req.t_first_token is None:  # zero-suffix fetch request
                    req.t_first_token = tnow
                req.tokens_out += 1
                req.token_times.append(tnow)
                if req.tokens_out >= max_new_tokens:
                    self.sched.finish(req, tnow)
        util = (self.pool.stats.utilization(self.pool.n)
                if self.pool else 0.0)
        return SimResult(requests=arrivals,
                         decode_pool_utilization=util,
                         decompress_buffer_high_water=(
                             self.ctrl.buffer_high_water),
                         sim_time=now,
                         retransmits=self.ctrl.retransmits_total,
                         spurious_retransmits=(
                             self.ctrl.spurious_retransmits_total),
                         resolution_switches=(
                             self.ctrl.resolution_switches),
                         fairness_events=(
                             list(self.fairness.events)
                             if self.fairness is not None else []))
