"""Multi-node prefix storage tier: capacity-bounded placement, eviction,
and longest-prefix-match lookup for encoded KV manifests.

The paper's remote-reuse wins assume the encoded prefix is actually
*resident* somewhere fetchable.  In production that residency is managed
by a dedicated storage layer (LMCache-style pools, Mooncake-style
disaggregated stores); this module models that layer as a first-class
subsystem with three pieces:

  * :class:`StoredPrefix` — the unit of placement: one reusable prefix's
    encoded artifacts (multi-resolution blob sizes, optional real
    `KVManifest`, optional token ids) plus its ancestry link for
    longest-prefix matching.
  * :class:`StorageNode` — one capacity-bounded server: byte-accurate
    admission with pluggable eviction (``lru``, ``lfu``, or the
    cost-aware ``cost`` policy scoring bytes-saved-per-byte-stored), and
    optionally its *own* `repro.cluster.network.SharedLink`, so where a
    prefix lives changes the observed fetch path (and therefore TTFT).
  * :class:`StorageCluster` — places prefixes across nodes (consistent
    hashing, or popularity-aware replication on top of it), serves
    lookups that may be **full** hits, **partial** hits (a stored
    *ancestor* prefix: fetch the ancestor, recompute the tail), or
    misses (recompute everything; the prefix is re-admitted from the
    durable catalog — a pull-through cache).

The tier is **fault-tolerant and admission-controlled** (ISSUE 4):

  * :meth:`StorageNode.fail` / :meth:`StorageNode.recover` model node
    churn — a failed node loses its residents (the catalog is the
    durable origin) and leaves the ring until it recovers.
  * **Ring heal**: :meth:`StorageCluster.fail_node` re-routes the failed
    node's keys to their ring successors and enqueues re-replication
    tasks that restore the replication factor from surviving replicas
    (or the durable catalog when none survive).  With ``heal="link"``
    each heal transfer rides the source node's own `SharedLink` at
    :data:`repro.cluster.network.HEAL_WEIGHT`, so heal traffic contends
    with live fetches; ``heal="sync"`` (default) completes heals
    immediately — clock-free, for cross-environment replay tests.
  * **TTL + pinning**: a :class:`StoredPrefix` may carry ``ttl`` seconds
    (enforced lazily at lookup and eagerly at the eviction scan) and a
    ``pinned`` flag (never evicted, never expired).
  * **Delayed write-on-miss**: a miss no longer re-admits immediately —
    the environment calls :meth:`StorageCluster.notify_recompute_done`
    when the fallback full prefill actually completes (hooked from the
    `FetchingAwareScheduler.notify_fetch_miss` resolution), modeling the
    donor re-uploading only after the KV exists again.
  * **Admission control** decides what gets stored at all:
    ``admission="second_hit"`` admits a prefix only once it has been
    asked for ``admission_min_asks`` times; ``admission="cost"`` gates
    on the projected bytes-saved-per-byte-stored score.  Declined
    writes log ``reject`` events.

The cluster's :attr:`StorageCluster.events` log records every admit /
evict / hit / partial / miss / replicate / fail / heal / recover /
expire / reject decision in order.  All decisions are pure functions of
the access sequence, entry sizes, and the churn schedule (no internal
RNG), so the analytic simulator and the live engine replay the
*identical* event sequence for the same workload — tested in
``tests/test_storage.py``, including a node failure mid-trace.

Units
-----
All capacities and sizes are **bytes** internally (``stored_bytes``,
``capacity_bytes``, per-resolution accounting); timestamps are
**seconds** on the caller's clock.  ``__repr__`` renders GB/MB (like
`SharedLink` renders Gbps) so printed nodes are readable.

See ``docs/storage_tier.md`` for the data model, eviction semantics,
placement policies, and the partial-hit timeline.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.chunks import KVManifest, encode_prefix, prefix_key
from repro.core.layout import RESOLUTION_ORDER
from repro.cluster.network import HEAL_WEIGHT, make_link

#: bytes per gigabyte, for constructors/repr (internal unit is bytes).
GB = 1e9


# ---------------------------------------------------------------------------
# The unit of placement
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StoredPrefix:
    """One reusable prefix's encoded artifacts, as the storage tier sees
    them.

    ``bytes_by_resolution`` is the encoded footprint per resolution (all
    resolutions of a prefix are stored together — the adaptive fetcher
    picks among them at fetch time, so a node must hold the full ladder).
    ``raw_kv_bytes`` is the uncompressed KV footprint a hit avoids
    recomputing/transferring; the cost-aware eviction score uses it.
    ``parent`` links to the longest registered ancestor prefix (or None),
    forming the trie that longest-prefix-match lookups walk.
    ``manifest``/``token_ids`` are present on the live path and absent
    for the simulator's synthetic entries.

    ``ttl`` (seconds, None = immortal) bounds residency measured from
    the entry's ``stored_at`` time: a stale copy is dropped lazily at
    the next lookup that touches it and eagerly by the eviction scan
    (re-admission refreshes the clock).  ``ttl=0`` means "expire on the
    next access after storage" — a clock-scale-free idiom the
    cross-environment tests rely on.  ``pinned`` entries are never
    evicted and never expire (operator-protected residency).
    """

    key: str
    n_tokens: int
    bytes_by_resolution: Dict[str, int]
    raw_kv_bytes: int = 0
    parent: Optional[str] = None
    manifest: Optional[KVManifest] = None
    token_ids: Optional[np.ndarray] = None
    ttl: Optional[float] = None
    pinned: bool = False

    @property
    def stored_bytes(self) -> int:
        """Total encoded footprint (bytes) — the admission/eviction unit."""
        return sum(self.bytes_by_resolution.values())

    @staticmethod
    def from_manifest(manifest: KVManifest, *,
                      raw_kv_bytes: int = 0,
                      parent: Optional[str] = None,
                      token_ids: Optional[np.ndarray] = None,
                      ttl: Optional[float] = None,
                      pinned: bool = False) -> "StoredPrefix":
        by_res: Dict[str, int] = {}
        for (_, res), blob in manifest.blobs.items():
            by_res[res] = by_res.get(res, 0) + len(blob)
        return StoredPrefix(key=manifest.prefix, n_tokens=manifest.n_tokens,
                            bytes_by_resolution=by_res,
                            raw_kv_bytes=raw_kv_bytes, parent=parent,
                            manifest=manifest, token_ids=token_ids,
                            ttl=ttl, pinned=pinned)

    def __repr__(self) -> str:
        mb = self.stored_bytes / 1e6
        par = f", parent={self.parent}" if self.parent else ""
        return (f"StoredPrefix({self.key}, {self.n_tokens} tok, "
                f"{mb:.2f} MB{par})")


def synthetic_stored_prefix(key: str, n_tokens: int, *,
                            raw_bytes_per_token: float,
                            ratios: Dict[str, float],
                            parent: Optional[str] = None,
                            ttl: Optional[float] = None,
                            pinned: bool = False) -> "StoredPrefix":
    """Manifest-less entry for the simulator: encoded sizes are derived
    from the raw KV footprint and per-resolution compression ratios, the
    same model `ServingSimulator._chunk_bytes` uses for wire sizes."""
    raw = int(raw_bytes_per_token * n_tokens)
    by_res = {res: int(raw / ratio) for res, ratio in ratios.items()}
    return StoredPrefix(key=key, n_tokens=n_tokens,
                        bytes_by_resolution=by_res, raw_kv_bytes=raw,
                        parent=parent, ttl=ttl, pinned=pinned)


# ---------------------------------------------------------------------------
# One capacity-bounded node
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Resident:
    """Node-local accounting for one resident prefix.

    ``res_bytes`` is the *resident* subset of the entry's resolution
    ladder (per-resolution eviction shrinks it; the catalog entry keeps
    the full ladder).  ``res_hits``/``res_used`` record which rungs the
    adaptive fetcher actually delivered (fed by
    :meth:`StorageNode.note_resolution_use`); ``res_used`` is a
    node-global use sequence number, not a clock, so recency compares
    identically in both environments.
    """
    entry: StoredPrefix
    stored_at: float
    last_used: float
    hits: int = 0
    seq: int = 0  # admission order, the deterministic tie-breaker
    res_bytes: Dict[str, int] = dataclasses.field(default_factory=dict)
    res_hits: Dict[str, int] = dataclasses.field(default_factory=dict)
    res_used: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class NodeStats:
    hits: int = 0
    evictions: int = 0
    admissions: int = 0
    rejections: int = 0  # entry alone exceeds capacity / pinned-full node
    bytes_served: int = 0  # encoded bytes of served (full-hit) lookups
    expirations: int = 0  # TTL-expired entries dropped (lazy or eager)
    failures: int = 0  # times this node failed (residents lost)


class StorageNode:
    """One storage server: capacity in bytes, pluggable eviction, and an
    optional dedicated network link.

    Eviction policies (who goes first when over capacity):

    ``lru``   least-recently-used entry (oldest ``last_used``).
    ``lfu``   least-frequently-used (fewest hits; LRU among ties).
    ``cost``  lowest bytes-saved-per-byte-stored score
              ``hits * raw_kv_bytes / stored_bytes`` — an entry earns its
              residency by the raw KV bytes its hits avoided, normalized
              by the encoded bytes it occupies.  Never-hit entries score
              0 and churn among themselves (LRU order) while proven-hot
              prefixes survive scan pressure that would flush an LRU.

    ``capacity_bytes=None`` means unbounded (the legacy flat-store
    behaviour `KVStore` keeps).  ``link`` is the node's own
    `SharedLink`; fetches for prefixes resident here are routed over it,
    so placement decisions change observed TTFT.

    Eviction granularity (ISSUE 7):

    ``evict_granularity="prefix"`` (default) evicts whole prefixes —
    the legacy behaviour every existing baseline assumes.
    ``"resolution"`` evicts one *resolution rung* at a time: the victim
    is the coldest ``(prefix, resolution)`` pair under the node's
    policy (per-rung hits/recency fed by :meth:`note_resolution_use`,
    same tie-breakers), so capacity pressure sheds the ladder rungs the
    adaptive fetcher never picks while the prefix itself stays
    fetchable.  Only when a prefix's *last* rung is the victim does the
    whole prefix go.  The resident subset is visible via
    :meth:`resident_resolutions` and travels on `StorageHit.resolutions`
    so the fetch controller only selects among rungs that still exist.
    """

    POLICIES = ("lru", "lfu", "cost")

    def __init__(self, node_id: str, capacity_bytes: Optional[float] = None,
                 *, policy: str = "lru", link=None,
                 evict_granularity: str = "prefix"):
        assert policy in self.POLICIES, policy
        assert evict_granularity in ("prefix", "resolution"), \
            evict_granularity
        self.node_id = node_id
        self.capacity_bytes = (None if capacity_bytes is None
                               else int(capacity_bytes))
        self.policy = policy
        self.evict_granularity = evict_granularity
        # one persistent SharedLink per node (a bare BandwidthTrace is
        # wrapped once here, NOT per fetch, so concurrent fetches from
        # this node contend on the same arbiter)
        self.link = None if link is None else make_link(link)
        self.residents: Dict[str, _Resident] = {}
        self.used_bytes = 0
        self.bytes_by_resolution: Dict[str, int] = {}
        self.stats = NodeStats()
        self.failed = False
        self._seq = 0
        self._use_seq = 0  # per-resolution recency counter (clock-free)

    def __repr__(self) -> str:
        cap = ("unbounded" if self.capacity_bytes is None else
               f"{self.used_bytes / GB:.2f}/{self.capacity_bytes / GB:.2f} GB")
        state = ", FAILED" if self.failed else ""
        return (f"StorageNode({self.node_id}, {cap}, policy={self.policy}, "
                f"{len(self.residents)} prefixes{state})")

    # -- failure ------------------------------------------------------------
    @property
    def alive(self) -> bool:
        return not self.failed

    def fail(self) -> List[str]:
        """Take this node down: every resident prefix is lost (residency
        is volatile; the cluster catalog is the durable copy).  Returns
        the lost keys in admission order so the cluster can plan heals
        deterministically."""
        lost = list(self.residents)
        self.residents.clear()
        self.used_bytes = 0
        self.bytes_by_resolution = {}
        self.failed = True
        self.stats.failures += 1
        return lost

    def recover(self) -> None:
        """Bring the node back, empty: it rejoins the ring and refills
        organically (placement, heals, write-on-miss)."""
        self.failed = False

    # -- TTL ----------------------------------------------------------------
    def is_expired(self, key: str, now: float) -> bool:
        r = self.residents.get(key)
        if r is None or r.entry.pinned or r.entry.ttl is None:
            return False
        return now - r.stored_at > r.entry.ttl

    def expire_key(self, key: str) -> None:
        self._remove(key)
        self.stats.expirations += 1

    def sweep_expired(self, now: float) -> List[str]:
        """Eager TTL scan (runs before any eviction decision): drop every
        expired entry so a stale copy never wins residency over a live
        admission.  Returns the dropped keys in admission order."""
        stale = [k for k, r in self.residents.items()
                 if self.is_expired(k, now)]
        for k in stale:
            self.expire_key(k)
        return stale

    # -- residency ----------------------------------------------------------
    def contains(self, key: str) -> bool:
        return key in self.residents

    def get(self, key: str, now: float) -> Optional[StoredPrefix]:
        """Serve a lookup: touches recency/frequency accounting.  A
        TTL-expired entry is dropped lazily here and misses."""
        r = self.residents.get(key)
        if r is None:
            return None
        if self.is_expired(key, now):
            self.expire_key(key)
            return None
        r.last_used = now
        r.hits += 1
        self.stats.hits += 1
        self.stats.bytes_served += sum(r.res_bytes.values())
        return r.entry

    def put(self, entry: StoredPrefix, now: float
            ) -> Tuple[bool, List[str]]:
        """Admit ``entry``, evicting by policy until it fits.

        Returns ``(admitted, evicted_keys)``.  An entry larger than the
        whole node is rejected (never admitted by flushing everything);
        so is one that cannot fit beside the node's *pinned* residents
        (pins are never evicted to make room).  Expired entries are
        swept eagerly before any victim is chosen.  Re-admitting a
        resident key replaces the stored artifact in place — byte
        accounting follows the new version, hit history is kept (it is
        the same prefix) — and refreshes its TTL clock.
        """
        assert self.alive, f"put() on failed node {self.node_id}"
        self.sweep_expired(now)
        size = entry.stored_bytes
        old = self.residents.get(entry.key)
        if old is not None:
            self._remove(entry.key)
        if self.capacity_bytes is not None:
            pinned_bytes = sum(r.entry.stored_bytes
                               for r in self.residents.values()
                               if r.entry.pinned)
            if size > self.capacity_bytes - pinned_bytes:
                if old is not None:  # keep the previous version resident
                    self.residents[entry.key] = old
                    self._account(old.res_bytes, +1)
                self.stats.rejections += 1
                return False, []
        evicted: List[str] = []
        while (self.capacity_bytes is not None
               and self.used_bytes + size > self.capacity_bytes):
            if self.evict_granularity == "resolution":
                vkey, vres = self._pick_victim_res()
                if vres is None:  # last rung: the whole prefix goes
                    self._drop(vkey)
                    evicted.append(vkey)
                else:
                    self._drop_res(vkey, vres)
                    evicted.append(f"{vkey}/{vres}")
            else:
                victim = self._pick_victim()
                self._drop(victim)
                evicted.append(victim)
        if old is not None:
            seq, hits = old.seq, old.hits
            res_hits, res_used = old.res_hits, old.res_used
        else:
            self._seq += 1
            seq, hits = self._seq, 0
            self.stats.admissions += 1
            res_hits, res_used = {}, {}
        # re-admission restores the full ladder (evicted rungs return)
        self.residents[entry.key] = _Resident(
            entry, stored_at=now, last_used=now, seq=seq, hits=hits,
            res_bytes=dict(entry.bytes_by_resolution),
            res_hits=res_hits, res_used=res_used)
        self._account(entry.bytes_by_resolution, +1)
        return True, evicted

    def _account(self, by_res: Dict[str, int], sign: int) -> None:
        for res, b in by_res.items():
            self.used_bytes += sign * b
            self.bytes_by_resolution[res] = \
                self.bytes_by_resolution.get(res, 0) + sign * b

    def _remove(self, key: str) -> None:
        """Drop residency + byte accounting (no eviction stat)."""
        r = self.residents.pop(key)
        self._account(r.res_bytes, -1)

    def _drop(self, key: str) -> None:
        self._remove(key)
        self.stats.evictions += 1

    def _drop_res(self, key: str, res: str) -> None:
        """Evict one resolution rung of a resident prefix."""
        r = self.residents[key]
        b = r.res_bytes.pop(res)
        self._account({res: b}, -1)
        self.stats.evictions += 1

    def _pick_victim(self) -> str:
        """Deterministic victim selection: policy score, then LRU order,
        then admission order (``seq``) so equal entries break ties the
        same way in every environment.  Pinned entries are never
        candidates (``put`` rejects up front when pins alone leave no
        room, so a victim always exists here)."""
        def lru_key(r: _Resident):
            return (r.last_used, r.seq)

        rs = [r for r in self.residents.values() if not r.entry.pinned]
        if self.policy == "lru":
            victim = min(rs, key=lru_key)
        elif self.policy == "lfu":
            victim = min(rs, key=lambda r: (r.hits,) + lru_key(r))
        else:  # cost: bytes saved per byte stored
            def score(r: _Resident) -> float:
                saved = r.hits * max(r.entry.raw_kv_bytes,
                                     r.entry.stored_bytes)
                return saved / max(r.entry.stored_bytes, 1)
            victim = min(rs, key=lambda r: (score(r),) + lru_key(r))
        return victim.entry.key

    def _pick_victim_res(self) -> Tuple[str, Optional[str]]:
        """Per-resolution victim: the coldest resident ``(prefix,
        rung)`` pair under the node's policy.  Recency is the clock-free
        ``res_used`` sequence; ties break on the prefix's LRU order,
        admission order, then ladder position — deterministic in every
        environment.  Returns ``(key, None)`` when the victim is the
        prefix's last resident rung (caller drops the whole prefix)."""
        res_idx = {r: i for i, r in enumerate(RESOLUTION_ORDER)}

        def cand_key(r: _Resident, res: str):
            recency = (r.res_used.get(res, 0), r.last_used, r.seq,
                       res_idx.get(res, -1))
            if self.policy == "lru":
                return recency
            hits = r.res_hits.get(res, 0)
            if self.policy == "lfu":
                return (hits,) + recency
            # cost: bytes saved per byte stored, per rung
            saved = hits * max(r.entry.raw_kv_bytes, r.res_bytes[res])
            return (saved / max(r.res_bytes[res], 1),) + recency

        best = None
        best_key = None
        for r in self.residents.values():
            if r.entry.pinned:
                continue
            for res in r.res_bytes:
                k = cand_key(r, res)
                if best_key is None or k < best_key:
                    best_key, best = k, (r, res)
        assert best is not None, "no evictable rung (all pinned?)"
        r, res = best
        if len(r.res_bytes) == 1:
            return r.entry.key, None
        return r.entry.key, res

    def note_resolution_use(self, key: str, res: str) -> None:
        """Record that the fetch path actually delivered ``res`` of
        ``key`` from this node (fed by the controller's ``res_sink``
        at fetch completion).  Bumps the rung's hit count and recency
        sequence so per-resolution eviction keeps the rungs the
        adaptive selector really uses."""
        r = self.residents.get(key)
        if r is None or res not in r.res_bytes:
            return
        self._use_seq += 1
        r.res_hits[res] = r.res_hits.get(res, 0) + 1
        r.res_used[res] = self._use_seq

    def resident_resolutions(self, key: str) -> Optional[Tuple[str, ...]]:
        """The resolutions of ``key`` still resident here (ladder order),
        or None when the prefix is not resident at all."""
        r = self.residents.get(key)
        if r is None:
            return None
        res_idx = {res: i for i, res in enumerate(RESOLUTION_ORDER)}
        return tuple(sorted(r.res_bytes, key=lambda s: res_idx.get(s, -1)))

    def stored_bytes(self) -> int:
        """Total encoded bytes resident on this node."""
        return self.used_bytes


# ---------------------------------------------------------------------------
# The cluster: placement, replication, longest-prefix-match lookup
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StorageHit:
    """Result of a cluster lookup.

    ``kind``: ``"full"`` (the requested prefix is resident — fetch it
    all), ``"partial"`` (only an *ancestor* is resident: fetch
    ``entry`` and recompute the ``requested_tokens - covered_tokens``
    tail), or ``"miss"`` (recompute everything; ``entry``/``node`` are
    None).  On a miss of a *cataloged* prefix, ``missed_key`` names it
    so the environment can call
    :meth:`StorageCluster.notify_recompute_done` once the fallback
    prefill finishes (delayed write-on-miss).

    ``resolutions`` is the serving node's *resident* rung set for
    ``entry`` (ladder order) — per-resolution eviction may have shed
    rungs, and the adaptive fetcher must only select among blobs that
    still exist.  None means unrestricted (miss, or caller that does
    not track residency).
    """

    kind: str  # "full" | "partial" | "miss"
    requested_tokens: int
    covered_tokens: int = 0
    entry: Optional[StoredPrefix] = None
    node: Optional[StorageNode] = None
    missed_key: Optional[str] = None
    resolutions: Optional[Tuple[str, ...]] = None


class StorageCluster:
    """Places prefixes across :class:`StorageNode`\\ s and resolves
    lookups to full / partial / miss outcomes.

    Placement
    ---------
    ``hash``     consistent hashing: each node projects ``vnodes``
                 points onto a hash ring; a prefix lives on the
                 successor of its own point.  Node membership changes
                 move only ~1/N of the keys.
    ``popular``  consistent hashing **plus** popularity-aware
                 replication: once a prefix's cluster-wide hits reach
                 ``replicate_threshold`` it is copied to the next
                 distinct node on the ring, and lookups rotate
                 round-robin across the resident replicas' links — hot
                 prefixes stop queueing behind each other.

    The **catalog** is the durable origin (donor-side artifact
    registry): it survives node evictions *and failures*, so a miss
    re-admits the prefix after the recompute finishes (pull-through
    semantics; see :meth:`notify_recompute_done`) and heals re-seed
    from it when no replica survives.  Only node *residency* is
    capacity-bounded.

    Fault tolerance
    ---------------
    ``replication`` is the target copy count at registration (and heal)
    time: an entry is placed on the first ``replication`` distinct
    alive ring nodes.  :meth:`fail_node` drops a node from the ring
    (its keys re-route to their successors), loses its residents, and
    enqueues re-replication tasks; ``heal="sync"`` completes them
    immediately (clock-free — replay tests), ``heal="link"`` streams
    each heal over the source node's own `SharedLink` at
    ``heal_weight`` so heal traffic contends with live fetches (the
    environments wire the event queue via :meth:`bind`).

    Admission control
    -----------------
    ``admission="always"`` stores everything (legacy).
    ``"second_hit"`` stores a prefix only once it has been *asked for*
    ``admission_min_asks`` times (one-shot prefixes never earn bytes).
    ``"cost"`` stores only when the projected
    bytes-saved-per-byte-stored score ``asks * raw_kv_bytes /
    stored_bytes`` reaches ``admission_min_score`` (default 1.0 —
    break-even: the store must expect to save at least the bytes it
    spends; a score of 0 would admit everything).  Heals bypass
    admission (they restore residency the controller already granted).

    Recovery re-balance
    ------------------
    :meth:`recover_node` does not leave the recovered node empty: keys
    whose preferred replica set (first ``replication`` ring nodes) now
    includes it, but whose copies sit on later ring successors, are
    streamed back through the heal machinery (``rebalance`` events) and
    the surplus successor copies are trimmed (``rebalance_drop``) —
    otherwise primary lookups pay the successor hop forever and
    occupancy stays skewed on the ring (ISSUE 6 bugfix).

    RTT-aware source selection
    --------------------------
    The fetch controller reports each completed fetch's smoothed RTT
    via :meth:`observe_rtt`; replica picks and heal sources then avoid
    nodes whose observed RTT is more than ``RTT_SLACK`` above the best
    known node.  Nodes within the slack band (and nodes with no samples
    yet) stay in the legacy round-robin rotation, so behaviour — and
    the event log's determinism as a pure function of the access
    sequence — is unchanged until the RTT signal actually diverges.

    Every decision is appended to :attr:`events` as ``(kind, key,
    node_id)`` tuples — ``admit``/``evict``/``hit``/``partial``/
    ``miss``/``replicate``/``reject``/``fail``/``heal``/``recover``/
    ``rebalance``/``rebalance_drop``/``expire`` — deterministically for
    a given access sequence and churn schedule.
    """

    #: EWMA gain for per-node smoothed-RTT observations.
    RTT_GAIN = 0.3
    #: relative band around the best known node RTT inside which
    #: replicas are considered equivalent and rotation applies
    RTT_SLACK = 0.25

    def __init__(self, nodes: Sequence[StorageNode], *,
                 placement: str = "hash", replicate_threshold: int = 3,
                 vnodes: int = 64, write_on_miss: bool = True,
                 replication: int = 1, heal: str = "sync",
                 heal_weight: float = HEAL_WEIGHT,
                 admission: str = "always", admission_min_asks: int = 2,
                 admission_min_score: float = 1.0):
        assert placement in ("hash", "popular"), placement
        assert heal in ("sync", "link", "manual"), heal
        assert admission in ("always", "second_hit", "cost"), admission
        assert len(nodes) > 0
        assert 1 <= replication <= len(nodes), replication
        assert len({n.node_id for n in nodes}) == len(nodes), \
            "duplicate node ids"
        self.nodes = list(nodes)
        self.by_id = {n.node_id: n for n in self.nodes}
        self.placement = placement
        self.replicate_threshold = replicate_threshold
        self.write_on_miss = write_on_miss
        self.replication = replication
        self.heal = heal
        self.heal_weight = heal_weight
        self.admission = admission
        self.admission_min_asks = admission_min_asks
        self.admission_min_score = admission_min_score
        self.catalog: Dict[str, StoredPrefix] = {}
        self.hits_by_key: Dict[str, int] = {}
        self.asks_by_key: Dict[str, int] = {}  # lookups incl. misses
        self.events: List[Tuple[str, str, str]] = []
        self.lookups = 0
        self.full_hits = 0
        self.partial_hits = 0
        self.misses = 0
        self.heals_completed = 0
        self.rebalances_completed = 0
        # per-node smoothed RTT, fed by the fetch controller from each
        # completed fetch's RttEstimator (ISSUE 6: replica/heal-source
        # selection avoids the most-contended node)
        self.node_rtt: Dict[str, float] = {}
        # heal="manual": tasks wait here for pump_heal() (wall-clock
        # engines have no virtual event queue to schedule them on);
        # entries are (entry, source_id, target_id, kind)
        self.heal_queue: List[
            Tuple[StoredPrefix, Optional[str], str, str]] = []
        # delayed write-on-miss: keys whose recompute is outstanding.
        # An insertion-ordered dict (not a set): the heal/recompute
        # paths may drain it, and a set of str keys would drain in
        # per-process hash order, silently breaking cross-env replay
        # (repro-lint ordered-iteration)
        self._pending_recompute: Dict[str, None] = {}
        # external event-queue hook (heal="link"): push(t, fn)
        self._push = None
        self._heal_flow = 0  # negative flow ids, distinct from rids
        self._ring: List[Tuple[int, str]] = []
        for n in self.nodes:
            for v in range(vnodes):
                self._ring.append((self._point(f"{n.node_id}#{v}"),
                                   n.node_id))
        self._ring.sort()

    def __repr__(self) -> str:
        used = sum(n.used_bytes for n in self.nodes)
        return (f"StorageCluster({len(self.nodes)} nodes, "
                f"{self.placement}, {len(self.catalog)} cataloged, "
                f"{used / GB:.2f} GB resident)")

    @staticmethod
    def _point(s: str) -> int:
        return int.from_bytes(hashlib.sha256(s.encode()).digest()[:8],
                              "big")

    def _ring_nodes(self, key: str) -> List[StorageNode]:
        """Distinct **alive** nodes in ring order starting at ``key``'s
        successor — a failed node simply vanishes from every key's
        successor list, which is the whole re-route story."""
        p = self._point(key)
        i = 0
        while i < len(self._ring) and self._ring[i][0] < p:
            i += 1
        seen: List[str] = []
        for j in range(len(self._ring)):
            nid = self._ring[(i + j) % len(self._ring)][1]
            if nid not in seen:
                seen.append(nid)
            if len(seen) == len(self.nodes):
                break
        return [self.by_id[nid] for nid in seen if self.by_id[nid].alive]

    def primary_node(self, key: str) -> StorageNode:
        ring = self._ring_nodes(key)
        assert ring, "every storage node has failed"
        return ring[0]

    def alive_nodes(self) -> List[StorageNode]:
        return [n for n in self.nodes if n.alive]

    # -- registration -------------------------------------------------------
    def register(self, entry: StoredPrefix, now: float = 0.0) -> None:
        """Catalog ``entry`` and — admission permitting — place it on
        the first ``replication`` alive ring nodes."""
        self.catalog[entry.key] = entry
        self.hits_by_key.setdefault(entry.key, 0)
        if not self._admit_ok(entry):
            self.events.append(("reject", entry.key, ""))
            return
        self._place_replicas(entry, now, skip_resident=False)

    def register_prefix(self, token_ids: np.ndarray, kv_k: np.ndarray,
                        kv_v: np.ndarray, *, now: float = 0.0,
                        ttl: Optional[float] = None, pinned: bool = False,
                        **kw) -> StoredPrefix:
        """Encode real KV into a manifest (like the legacy `KVStore`),
        auto-detect the longest registered ancestor from ``token_ids``,
        and register the result."""
        token_ids = np.asarray(token_ids)
        key = prefix_key(token_ids)
        man = encode_prefix(kv_k, kv_v, prefix=key, **kw)
        parent = self._longest_cataloged(token_ids, below=len(token_ids))
        entry = StoredPrefix.from_manifest(
            man, raw_kv_bytes=int(kv_k.nbytes + kv_v.nbytes),
            parent=parent.key if parent else None, token_ids=token_ids,
            ttl=ttl, pinned=pinned)
        self.register(entry, now)
        return entry

    def _place_replicas(self, entry: StoredPrefix, now: float, *,
                        skip_resident: bool) -> bool:
        """Place ``entry`` on its first ``replication`` alive ring
        nodes.  ``skip_resident=True`` leaves existing copies (and
        their TTL clocks) untouched — the write-on-miss path;
        ``False`` replaces them in place, refreshing the TTL — the
        register/operator-admit semantics."""
        ok = False
        for node in self._ring_nodes(entry.key)[:self.replication]:
            if skip_resident and node.contains(entry.key):
                continue
            ok |= self._place(entry, node, now)
        return ok

    def _place(self, entry: StoredPrefix, node: StorageNode,
               now: float, *, kind: str = "admit") -> bool:
        # eager TTL at the eviction scan, logged here; put() sweeps
        # again internally (node-level contract for direct users like
        # KVStore) but finds nothing — same `now`
        for k in node.sweep_expired(now):
            self.events.append(("expire", k, node.node_id))
        ok, evicted = node.put(entry, now)
        for k in evicted:
            # per-resolution eviction reports "key/res" tokens (prefix
            # keys are hex digests, so "/" is unambiguous)
            kind_ev = ("evict_res" if node.evict_granularity == "resolution"
                       and "/" in k else "evict")
            self.events.append((kind_ev, k, node.node_id))
        if ok:
            self.events.append((kind, entry.key, node.node_id))
        else:
            self.events.append(("reject", entry.key, node.node_id))
        return ok

    # -- admission control ---------------------------------------------------
    def _admit_ok(self, entry: StoredPrefix) -> bool:
        """Should this entry be granted node residency at all?"""
        if self.admission == "always":
            return True
        asks = self.asks_by_key.get(entry.key, 0)
        if self.admission == "second_hit":
            return asks >= self.admission_min_asks
        # documented formula, no floor: an entry whose encoding saves
        # nothing (raw <= stored, or raw unknown) scores accordingly low
        # — those are exactly the writes this gate exists to filter
        return asks * entry.raw_kv_bytes / max(entry.stored_bytes, 1) \
            >= self.admission_min_score

    # -- lookup -------------------------------------------------------------
    def _resident_nodes(self, key: str,
                        now: Optional[float] = None) -> List[StorageNode]:
        """Alive nodes holding ``key``, in deterministic ring order.
        With ``now``, TTL-expired copies are dropped lazily here (and
        logged) before they can serve the lookup."""
        out: List[StorageNode] = []
        for n in self._ring_nodes(key):
            if not n.contains(key):
                continue
            if now is not None and n.is_expired(key, now):
                n.expire_key(key)
                self.events.append(("expire", key, n.node_id))
                continue
            out.append(n)
        return out

    def note_resolution_use(self, node_id: str, key: str,
                            res: str) -> None:
        """Per-resolution usage feedback from the fetch controller's
        ``res_sink`` hook: the fetch for ``key`` served from ``node_id``
        actually delivered resolution ``res``.  Not logged to
        :attr:`events` (it is derived from the fetch outcome, which the
        replay tests already compare); it only steers per-resolution
        eviction recency/frequency on the node."""
        node = self.by_id.get(node_id)
        if node is None or not node.alive:
            return
        node.note_resolution_use(key, res)

    def observe_rtt(self, node_id: str, srtt: float) -> None:
        """Fold one completed fetch's smoothed RTT into ``node_id``'s
        EWMA (fed by ``FetchController`` via its ``rtt_sink`` hook).
        The per-flow `RttEstimator` already smooths within a fetch;
        this smooths across fetches so one contended transfer does not
        blacklist a node forever."""
        if node_id not in self.by_id or srtt is None:
            return
        prev = self.node_rtt.get(node_id)
        self.node_rtt[node_id] = (srtt if prev is None else
                                  prev + self.RTT_GAIN * (srtt - prev))

    def _rtt_candidates(self,
                        nodes: List[StorageNode]) -> List[StorageNode]:
        """Drop nodes whose observed RTT sits more than ``RTT_SLACK``
        above the best known node; unsampled nodes are kept (optimistic
        — they must be explored before they can be judged)."""
        rtts = [self.node_rtt.get(n.node_id) for n in nodes]
        known = [r for r in rtts if r is not None]
        if not known:
            return nodes
        best = min(known)
        return [n for n, r in zip(nodes, rtts)
                if r is None or r <= best * (1.0 + self.RTT_SLACK)]

    def _pick_replica(self, key: str,
                      nodes: List[StorageNode]) -> StorageNode:
        """Rotate across resident replicas by this key's lookup count —
        spreads concurrent fetches over the replicas' links while
        staying a pure function of the access sequence (unlike e.g.
        least-in-flight, which would make the event log clock-dependent
        and break cross-environment determinism).  Replicas whose
        observed RTT has drifted ``RTT_SLACK`` above the best node are
        excluded from the rotation (ISSUE 6: fetches stop piling onto
        the most-contended replica); with no or uniform RTT data this
        degenerates to the legacy rotation."""
        cand = self._rtt_candidates(nodes)
        return cand[self.hits_by_key.get(key, 0) % len(cand)]

    def _pick_heal_source(self,
                          nodes: List[StorageNode]) -> StorageNode:
        """Heal/re-balance source: the lowest observed-RTT holder, ring
        order breaking ties; a node with no samples scores as best
        (legacy ``survivors[0]`` behaviour until data says otherwise)."""
        return min(nodes,
                   key=lambda n: self.node_rtt.get(n.node_id, 0.0))

    def _longest_cataloged(self, token_ids: np.ndarray, *,
                           below: int) -> Optional[StoredPrefix]:
        """Longest cataloged prefix of ``token_ids`` shorter than
        ``below`` tokens (linear scan over the catalog; the catalog holds
        registered prefixes, not per-request state, so it stays small)."""
        best: Optional[StoredPrefix] = None
        for e in self.catalog.values():
            if e.token_ids is None or e.n_tokens >= below:
                continue
            if e.n_tokens > len(token_ids):
                continue
            if best is not None and e.n_tokens <= best.n_tokens:
                continue
            if np.array_equal(e.token_ids,
                              np.asarray(token_ids[:e.n_tokens])):
                best = e
        return best

    def _ancestor_chain(self, key: str) -> List[StoredPrefix]:
        """``key``'s cataloged ancestors, nearest first (via ``parent``
        links; used by the simulator where entries carry no token ids)."""
        out: List[StoredPrefix] = []
        cur = self.catalog.get(key)
        seen = {key}
        while cur is not None and cur.parent and cur.parent not in seen:
            seen.add(cur.parent)
            cur = self.catalog.get(cur.parent)
            if cur is not None:
                out.append(cur)
        return out

    def lookup(self, key: str, now: float,
               requested_tokens: Optional[int] = None) -> StorageHit:
        """Resolve a fetch for prefix ``key``: full hit if resident,
        partial hit on the nearest resident ancestor, else miss.  With
        ``write_on_miss``, a missed *cataloged* prefix becomes a pending
        write that :meth:`notify_recompute_done` resolves once the
        fallback prefill actually finishes — the donor cannot re-upload
        KV that does not exist yet."""
        self.lookups += 1
        self.asks_by_key[key] = self.asks_by_key.get(key, 0) + 1
        want = self.catalog.get(key)
        requested = (requested_tokens if requested_tokens is not None
                     else (want.n_tokens if want else 0))
        candidates = [want] if want else []
        candidates += self._ancestor_chain(key)
        for cand in candidates:
            nodes = self._resident_nodes(cand.key, now)
            if not nodes:
                continue
            node = self._pick_replica(cand.key, nodes)
            node.get(cand.key, now)
            self.hits_by_key[cand.key] = \
                self.hits_by_key.get(cand.key, 0) + 1
            full = cand.key == key and cand.n_tokens >= requested
            kind = "full" if full else "partial"
            self.events.append((kind, cand.key, node.node_id))
            if full:
                self.full_hits += 1
            else:
                self.partial_hits += 1
            self._maybe_replicate(cand, now)
            return StorageHit(kind=kind, requested_tokens=requested,
                              covered_tokens=min(cand.n_tokens, requested),
                              entry=cand, node=node,
                              resolutions=node.resident_resolutions(
                                  cand.key))
        self.misses += 1
        self.events.append(("miss", key, ""))
        if self.write_on_miss and want is not None:
            self._pending_recompute[key] = None
        return StorageHit(kind="miss", requested_tokens=requested,
                          missed_key=want.key if want else None)

    def notify_recompute_done(self, key: str, now: float) -> None:
        """The fallback full prefill for a missed prefix completed: the
        KV exists again, so the delayed write-on-miss can re-admit it
        (admission control permitting).  Called by both environments
        when a ``storage_hit == "miss"`` request reaches its first
        token; a no-op for keys with no pending write."""
        if key not in self._pending_recompute:
            return
        self._pending_recompute.pop(key, None)
        entry = self.catalog.get(key)
        if entry is None:
            return
        if not self._admit_ok(entry):
            self.events.append(("reject", key, ""))
            return
        self._place_replicas(entry, now, skip_resident=True)

    def lookup_tokens(self, token_ids: np.ndarray,
                      now: float) -> StorageHit:
        """Longest-prefix-match lookup by token ids (live-engine path):
        resolve the longest cataloged prefix of ``token_ids``, then fall
        through :meth:`lookup` for residency/ancestors/replication."""
        token_ids = np.asarray(token_ids)
        best = self._longest_cataloged(token_ids,
                                       below=len(token_ids) + 1)
        if best is None:
            key = prefix_key(token_ids)
            self.lookups += 1
            self.asks_by_key[key] = self.asks_by_key.get(key, 0) + 1
            self.misses += 1
            self.events.append(("miss", key, ""))
            return StorageHit(kind="miss",
                              requested_tokens=len(token_ids))
        return self.lookup(best.key, now,
                           requested_tokens=len(token_ids))

    def admit(self, key: str, now: float) -> bool:
        """Explicitly (re-)admit a cataloged prefix onto its first
        ``replication`` alive ring nodes — the operator override that
        bypasses admission control (misses go through the delayed
        :meth:`notify_recompute_done` path instead).  Existing copies
        are replaced in place, refreshing their TTL clocks."""
        entry = self.catalog.get(key)
        if entry is None:
            return False
        return self._place_replicas(entry, now, skip_resident=False)

    def _maybe_replicate(self, entry: StoredPrefix, now: float) -> None:
        if self.placement != "popular":
            return
        if self.hits_by_key.get(entry.key, 0) < self.replicate_threshold:
            return
        for node in self._ring_nodes(entry.key)[1:]:
            if not node.contains(entry.key):
                if self._place(entry, node, now):
                    self.events.append(("replicate", entry.key,
                                        node.node_id))
                return  # one replica per threshold crossing

    # -- node failure + ring heal -------------------------------------------
    def bind(self, push) -> None:
        """Wire the environment's event queue (``push(t, fn)`` — the
        fetch controller's, via `FetchController.push_event`) so
        ``heal="link"`` transfers can schedule their completions on the
        shared virtual clock.  Also binds every node link, so heal flows
        can join links no fetch has touched yet."""
        self._push = push
        for n in self.nodes:
            if n.link is not None:
                n.link.bind(push)

    def fail_node(self, node_id: str, now: float) -> List[str]:
        """Kill a node: its residents are lost, its keys re-route to
        their ring successors, and a re-replication queue restores the
        replication factor of every lost key — from a surviving replica
        when one exists, else from the durable catalog.  Returns the
        lost keys.  Heal transfers either complete immediately
        (``heal="sync"``) or stream over the source node's link at
        ``heal_weight`` (``heal="link"``), contending with live
        fetches."""
        node = self.by_id[node_id]
        assert node.alive, f"{node_id} already failed"
        lost = node.fail()
        self.events.append(("fail", "", node_id))
        assert self.alive_nodes(), "every storage node has failed"
        for key in lost:
            entry = self.catalog.get(key)
            if entry is None:
                continue
            # pass `now` so TTL-expired copies neither count toward the
            # replication factor nor get picked as the heal source
            survivors = self._resident_nodes(key, now)
            need = self.replication - len(survivors)
            targets = [n for n in self._ring_nodes(key)
                       if not n.contains(key)][:max(need, 0)]
            source = (self._pick_heal_source(survivors) if survivors
                      else None)
            for target in targets:
                self._start_heal(entry, source, target, now)
        return lost

    def recover_node(self, node_id: str, now: float) -> None:
        """Bring a failed node back (empty): it rejoins the ring, and
        keys it is now a preferred replica for are proactively streamed
        back from their current holders (``rebalance`` events) — without
        this, keys registered during the outage stay on ring successors
        and every primary lookup pays the successor hop forever."""
        node = self.by_id[node_id]
        assert not node.alive, f"{node_id} is not failed"
        node.recover()
        self.events.append(("recover", "", node_id))
        self._rebalance_onto(node, now)

    def _rebalance_onto(self, node: StorageNode, now: float) -> None:
        """Proactive key re-balance after recovery: every cataloged key
        whose first ``replication`` ring nodes include ``node`` but
        which is resident only on later successors is copied back over
        the heal machinery (same transports/weights); once the copy
        lands, surplus copies beyond the replication factor are trimmed
        from non-preferred holders, de-skewing occupancy.  Catalog
        insertion order keeps the event log a pure function of the
        access/churn sequence."""
        for key, entry in self.catalog.items():
            if node not in self._ring_nodes(key)[:self.replication]:
                continue
            if node.contains(key):
                continue
            holders = self._resident_nodes(key, now)
            if not holders:
                continue  # nothing resident: write-on-miss path owns it
            source = self._pick_heal_source(holders)
            self._start_heal(entry, source, node, now, kind="rebalance")

    def _trim_surplus(self, key: str, now: float) -> None:
        """Drop copies beyond the replication factor from non-preferred
        holders (reverse ring order), keeping preferred copies."""
        preferred = {n.node_id
                     for n in self._ring_nodes(key)[:self.replication]}
        holders = self._resident_nodes(key, now)
        for n in reversed(holders):
            if len(holders) <= self.replication:
                return
            if n.node_id in preferred:
                continue
            n._remove(key)
            holders.remove(n)
            self.events.append(("rebalance_drop", key, n.node_id))

    def _start_heal(self, entry: StoredPrefix,
                    source: Optional[StorageNode],
                    target: StorageNode, now: float, *,
                    kind: str = "heal") -> None:
        """One re-replication transfer.  The wire path is the source
        node's own link (the durable catalog re-seeds over the target's
        link — the donor uploads into the target); a heal flow joins at
        ``heal_weight`` so live fetches keep link priority.  Modes:
        ``sync`` completes here, ``manual`` queues for
        :meth:`pump_heal` (wall-clock engines), ``link`` schedules the
        completion on the bound event queue."""
        if self.heal == "manual":
            self.heal_queue.append(
                (entry, source.node_id if source else None,
                 target.node_id, kind))
            return
        link = source.link if source is not None else target.link
        if self.heal == "sync" or link is None:
            self._finish_heal(entry, target, now, kind=kind)
            return
        assert self._push is not None, \
            "heal='link' needs bind() — pass the cluster to a " \
            "simulator/virtual-clock engine, or use heal='sync'/'manual'"
        self._heal_flow -= 1
        flow = self._heal_flow  # negative: never collides with a rid
        # join at the heal weight; on a ramp="slowstart" link the heal
        # flow slow-starts like any other joiner (live fetches keep
        # priority while the ring re-converges)
        link.open_flow(flow, weight=self.heal_weight, t=now)

        def done(t: float, entry=entry, target=target, link=link,
                 flow=flow, kind=kind) -> None:
            link.close_flow(flow)
            self._finish_heal(entry, target, t, kind=kind)

        link.submit(flow, entry.stored_bytes, now, done)

    def pump_heal(self, now: float) -> int:
        """Complete every queued ``heal="manual"`` task (in enqueue
        order); returns how many landed.  The operator's knob for
        staging recovery in wall-clock environments and tests."""
        tasks, self.heal_queue = self.heal_queue, []
        n = 0
        for entry, _, target_id, kind in tasks:
            target = self.by_id[target_id]
            before = self.heals_completed + self.rebalances_completed
            self._finish_heal(entry, target, now, kind=kind)
            n += (self.heals_completed + self.rebalances_completed
                  - before)
        return n

    def _finish_heal(self, entry: StoredPrefix, target: StorageNode,
                     now: float, *, kind: str = "heal") -> None:
        if not target.alive or target.contains(entry.key):
            return  # target churned away / copy arrived by another path
        if self._place(entry, target, now, kind=kind):
            if kind == "rebalance":
                self.rebalances_completed += 1
                self._trim_surplus(entry.key, now)
            else:
                self.heals_completed += 1  # rejected: not a completion

    # -- stats --------------------------------------------------------------
    def hit_rate(self) -> float:
        """Full+partial hits over all lookups (0.0 when no lookups)."""
        if not self.lookups:
            return 0.0
        return (self.full_hits + self.partial_hits) / self.lookups

    def stored_bytes(self) -> int:
        return sum(n.used_bytes for n in self.nodes)


# ---------------------------------------------------------------------------
# Legacy single-node facade
# ---------------------------------------------------------------------------


class KVStore:
    """The original flat in-process store, now a facade over one
    unbounded :class:`StorageNode` — same API (register / lookup /
    get_chunk return `KVManifest`\\ s), no capacity pressure, no network
    placement.  Integration tests and the quickstart keep using it; the
    multi-node tier above is the production-shaped path."""

    def __init__(self) -> None:
        self.node = StorageNode("local", capacity_bytes=None)

    @property
    def manifests(self) -> Dict[str, KVManifest]:
        return {k: r.entry.manifest for k, r in self.node.residents.items()
                if r.entry.manifest is not None}

    def register(self, manifest: KVManifest) -> None:
        self.node.put(StoredPrefix.from_manifest(manifest), now=0.0)

    def register_prefix(self, token_ids: np.ndarray, kv_k: np.ndarray,
                        kv_v: np.ndarray, **kw) -> KVManifest:
        key = prefix_key(np.asarray(token_ids))
        man = encode_prefix(kv_k, kv_v, prefix=key, **kw)
        self.register(man)
        return man

    def lookup(self, prefix: str) -> Optional[KVManifest]:
        e = self.node.get(prefix, now=0.0)
        return e.manifest if e is not None else None

    def get_chunk(self, prefix: str, chunk_id: str, resolution: str) -> bytes:
        return self.node.residents[prefix].entry.manifest.blobs[
            (chunk_id, resolution)]

    def stored_bytes(self) -> int:
        return self.node.stored_bytes()
