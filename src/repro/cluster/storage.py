"""Remote KV storage node: holds encoded chunk manifests keyed by prefix.

In production this is a dedicated storage server (LMCache-style) or a
disaggregated pool (Mooncake-style); here it is an in-process store whose
bytes are only reachable through the (simulated or live) network path.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.chunks import KVManifest, encode_prefix, prefix_key


class KVStore:
    def __init__(self) -> None:
        self.manifests: Dict[str, KVManifest] = {}

    def register(self, manifest: KVManifest) -> None:
        self.manifests[manifest.prefix] = manifest

    def register_prefix(self, token_ids: np.ndarray, kv_k: np.ndarray,
                        kv_v: np.ndarray, **kw) -> KVManifest:
        key = prefix_key(token_ids)
        man = encode_prefix(kv_k, kv_v, prefix=key, **kw)
        self.register(man)
        return man

    def lookup(self, prefix: str) -> Optional[KVManifest]:
        return self.manifests.get(prefix)

    def get_chunk(self, prefix: str, chunk_id: str, resolution: str) -> bytes:
        return self.manifests[prefix].blobs[(chunk_id, resolution)]

    def stored_bytes(self) -> int:
        return sum(len(b) for m in self.manifests.values()
                   for b in m.blobs.values())
