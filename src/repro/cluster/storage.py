"""Multi-node prefix storage tier: capacity-bounded placement, eviction,
and longest-prefix-match lookup for encoded KV manifests.

The paper's remote-reuse wins assume the encoded prefix is actually
*resident* somewhere fetchable.  In production that residency is managed
by a dedicated storage layer (LMCache-style pools, Mooncake-style
disaggregated stores); this module models that layer as a first-class
subsystem with three pieces:

  * :class:`StoredPrefix` — the unit of placement: one reusable prefix's
    encoded artifacts (multi-resolution blob sizes, optional real
    `KVManifest`, optional token ids) plus its ancestry link for
    longest-prefix matching.
  * :class:`StorageNode` — one capacity-bounded server: byte-accurate
    admission with pluggable eviction (``lru``, ``lfu``, or the
    cost-aware ``cost`` policy scoring bytes-saved-per-byte-stored), and
    optionally its *own* `repro.cluster.network.SharedLink`, so where a
    prefix lives changes the observed fetch path (and therefore TTFT).
  * :class:`StorageCluster` — places prefixes across nodes (consistent
    hashing, or popularity-aware replication on top of it), serves
    lookups that may be **full** hits, **partial** hits (a stored
    *ancestor* prefix: fetch the ancestor, recompute the tail), or
    misses (recompute everything; the prefix is re-admitted from the
    durable catalog — a pull-through cache).

The cluster's :attr:`StorageCluster.events` log records every admit /
evict / hit / partial / miss / replicate decision in order.  All
decisions are pure functions of the access sequence and entry sizes (no
internal RNG), so the analytic simulator and the live engine replay the
*identical* event sequence for the same workload — tested in
``tests/test_storage.py``.

Units
-----
All capacities and sizes are **bytes** internally (``stored_bytes``,
``capacity_bytes``, per-resolution accounting); timestamps are
**seconds** on the caller's clock.  ``__repr__`` renders GB/MB (like
`SharedLink` renders Gbps) so printed nodes are readable.

See ``docs/storage_tier.md`` for the data model, eviction semantics,
placement policies, and the partial-hit timeline.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.chunks import KVManifest, encode_prefix, prefix_key
from repro.cluster.network import make_link

#: bytes per gigabyte, for constructors/repr (internal unit is bytes).
GB = 1e9


# ---------------------------------------------------------------------------
# The unit of placement
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StoredPrefix:
    """One reusable prefix's encoded artifacts, as the storage tier sees
    them.

    ``bytes_by_resolution`` is the encoded footprint per resolution (all
    resolutions of a prefix are stored together — the adaptive fetcher
    picks among them at fetch time, so a node must hold the full ladder).
    ``raw_kv_bytes`` is the uncompressed KV footprint a hit avoids
    recomputing/transferring; the cost-aware eviction score uses it.
    ``parent`` links to the longest registered ancestor prefix (or None),
    forming the trie that longest-prefix-match lookups walk.
    ``manifest``/``token_ids`` are present on the live path and absent
    for the simulator's synthetic entries.
    """

    key: str
    n_tokens: int
    bytes_by_resolution: Dict[str, int]
    raw_kv_bytes: int = 0
    parent: Optional[str] = None
    manifest: Optional[KVManifest] = None
    token_ids: Optional[np.ndarray] = None

    @property
    def stored_bytes(self) -> int:
        """Total encoded footprint (bytes) — the admission/eviction unit."""
        return sum(self.bytes_by_resolution.values())

    @staticmethod
    def from_manifest(manifest: KVManifest, *,
                      raw_kv_bytes: int = 0,
                      parent: Optional[str] = None,
                      token_ids: Optional[np.ndarray] = None
                      ) -> "StoredPrefix":
        by_res: Dict[str, int] = {}
        for (_, res), blob in manifest.blobs.items():
            by_res[res] = by_res.get(res, 0) + len(blob)
        return StoredPrefix(key=manifest.prefix, n_tokens=manifest.n_tokens,
                            bytes_by_resolution=by_res,
                            raw_kv_bytes=raw_kv_bytes, parent=parent,
                            manifest=manifest, token_ids=token_ids)

    def __repr__(self) -> str:
        mb = self.stored_bytes / 1e6
        par = f", parent={self.parent}" if self.parent else ""
        return (f"StoredPrefix({self.key}, {self.n_tokens} tok, "
                f"{mb:.2f} MB{par})")


def synthetic_stored_prefix(key: str, n_tokens: int, *,
                            raw_bytes_per_token: float,
                            ratios: Dict[str, float],
                            parent: Optional[str] = None) -> "StoredPrefix":
    """Manifest-less entry for the simulator: encoded sizes are derived
    from the raw KV footprint and per-resolution compression ratios, the
    same model `ServingSimulator._chunk_bytes` uses for wire sizes."""
    raw = int(raw_bytes_per_token * n_tokens)
    by_res = {res: int(raw / ratio) for res, ratio in ratios.items()}
    return StoredPrefix(key=key, n_tokens=n_tokens,
                        bytes_by_resolution=by_res, raw_kv_bytes=raw,
                        parent=parent)


# ---------------------------------------------------------------------------
# One capacity-bounded node
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Resident:
    """Node-local accounting for one resident prefix."""
    entry: StoredPrefix
    stored_at: float
    last_used: float
    hits: int = 0
    seq: int = 0  # admission order, the deterministic tie-breaker


@dataclasses.dataclass
class NodeStats:
    hits: int = 0
    evictions: int = 0
    admissions: int = 0
    rejections: int = 0  # entry alone exceeds capacity
    bytes_served: int = 0  # encoded bytes of served (full-hit) lookups


class StorageNode:
    """One storage server: capacity in bytes, pluggable eviction, and an
    optional dedicated network link.

    Eviction policies (who goes first when over capacity):

    ``lru``   least-recently-used entry (oldest ``last_used``).
    ``lfu``   least-frequently-used (fewest hits; LRU among ties).
    ``cost``  lowest bytes-saved-per-byte-stored score
              ``hits * raw_kv_bytes / stored_bytes`` — an entry earns its
              residency by the raw KV bytes its hits avoided, normalized
              by the encoded bytes it occupies.  Never-hit entries score
              0 and churn among themselves (LRU order) while proven-hot
              prefixes survive scan pressure that would flush an LRU.

    ``capacity_bytes=None`` means unbounded (the legacy flat-store
    behaviour `KVStore` keeps).  ``link`` is the node's own
    `SharedLink`; fetches for prefixes resident here are routed over it,
    so placement decisions change observed TTFT.
    """

    POLICIES = ("lru", "lfu", "cost")

    def __init__(self, node_id: str, capacity_bytes: Optional[float] = None,
                 *, policy: str = "lru", link=None):
        assert policy in self.POLICIES, policy
        self.node_id = node_id
        self.capacity_bytes = (None if capacity_bytes is None
                               else int(capacity_bytes))
        self.policy = policy
        # one persistent SharedLink per node (a bare BandwidthTrace is
        # wrapped once here, NOT per fetch, so concurrent fetches from
        # this node contend on the same arbiter)
        self.link = None if link is None else make_link(link)
        self.residents: Dict[str, _Resident] = {}
        self.used_bytes = 0
        self.bytes_by_resolution: Dict[str, int] = {}
        self.stats = NodeStats()
        self._seq = 0

    def __repr__(self) -> str:
        cap = ("unbounded" if self.capacity_bytes is None else
               f"{self.used_bytes / GB:.2f}/{self.capacity_bytes / GB:.2f} GB")
        return (f"StorageNode({self.node_id}, {cap}, policy={self.policy}, "
                f"{len(self.residents)} prefixes)")

    # -- residency ----------------------------------------------------------
    def contains(self, key: str) -> bool:
        return key in self.residents

    def get(self, key: str, now: float) -> Optional[StoredPrefix]:
        """Serve a lookup: touches recency/frequency accounting."""
        r = self.residents.get(key)
        if r is None:
            return None
        r.last_used = now
        r.hits += 1
        self.stats.hits += 1
        self.stats.bytes_served += r.entry.stored_bytes
        return r.entry

    def put(self, entry: StoredPrefix, now: float
            ) -> Tuple[bool, List[str]]:
        """Admit ``entry``, evicting by policy until it fits.

        Returns ``(admitted, evicted_keys)``.  An entry larger than the
        whole node is rejected (never admitted by flushing everything).
        Re-admitting a resident key replaces the stored artifact in
        place — byte accounting follows the new version, hit history is
        kept (it is the same prefix).
        """
        size = entry.stored_bytes
        if self.capacity_bytes is not None and size > self.capacity_bytes:
            self.stats.rejections += 1
            return False, []
        old = self.residents.get(entry.key)
        if old is not None:
            self._remove(entry.key)
        evicted: List[str] = []
        while (self.capacity_bytes is not None
               and self.used_bytes + size > self.capacity_bytes):
            victim = self._pick_victim()
            self._drop(victim)
            evicted.append(victim)
        if old is not None:
            seq, hits = old.seq, old.hits
        else:
            self._seq += 1
            seq, hits = self._seq, 0
            self.stats.admissions += 1
        self.residents[entry.key] = _Resident(entry, stored_at=now,
                                              last_used=now, seq=seq,
                                              hits=hits)
        self.used_bytes += size
        for res, b in entry.bytes_by_resolution.items():
            self.bytes_by_resolution[res] = \
                self.bytes_by_resolution.get(res, 0) + b
        return True, evicted

    def _remove(self, key: str) -> None:
        """Drop residency + byte accounting (no eviction stat)."""
        r = self.residents.pop(key)
        self.used_bytes -= r.entry.stored_bytes
        for res, b in r.entry.bytes_by_resolution.items():
            self.bytes_by_resolution[res] -= b

    def _drop(self, key: str) -> None:
        self._remove(key)
        self.stats.evictions += 1

    def _pick_victim(self) -> str:
        """Deterministic victim selection: policy score, then LRU order,
        then admission order (``seq``) so equal entries break ties the
        same way in every environment."""
        def lru_key(r: _Resident):
            return (r.last_used, r.seq)

        rs = self.residents.values()
        if self.policy == "lru":
            victim = min(rs, key=lru_key)
        elif self.policy == "lfu":
            victim = min(rs, key=lambda r: (r.hits,) + lru_key(r))
        else:  # cost: bytes saved per byte stored
            def score(r: _Resident) -> float:
                saved = r.hits * max(r.entry.raw_kv_bytes,
                                     r.entry.stored_bytes)
                return saved / max(r.entry.stored_bytes, 1)
            victim = min(rs, key=lambda r: (score(r),) + lru_key(r))
        return victim.entry.key

    def stored_bytes(self) -> int:
        """Total encoded bytes resident on this node."""
        return self.used_bytes


# ---------------------------------------------------------------------------
# The cluster: placement, replication, longest-prefix-match lookup
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StorageHit:
    """Result of a cluster lookup.

    ``kind``: ``"full"`` (the requested prefix is resident — fetch it
    all), ``"partial"`` (only an *ancestor* is resident: fetch
    ``entry`` and recompute the ``requested_tokens - covered_tokens``
    tail), or ``"miss"`` (recompute everything; ``entry``/``node`` are
    None).
    """

    kind: str  # "full" | "partial" | "miss"
    requested_tokens: int
    covered_tokens: int = 0
    entry: Optional[StoredPrefix] = None
    node: Optional[StorageNode] = None


class StorageCluster:
    """Places prefixes across :class:`StorageNode`\\ s and resolves
    lookups to full / partial / miss outcomes.

    Placement
    ---------
    ``hash``     consistent hashing: each node projects ``vnodes``
                 points onto a hash ring; a prefix lives on the
                 successor of its own point.  Node membership changes
                 move only ~1/N of the keys.
    ``popular``  consistent hashing **plus** popularity-aware
                 replication: once a prefix's cluster-wide hits reach
                 ``replicate_threshold`` it is copied to the next
                 distinct node on the ring, and lookups rotate
                 round-robin across the resident replicas' links — hot
                 prefixes stop queueing behind each other.

    The **catalog** is the durable origin (donor-side artifact
    registry): it survives node evictions, so a miss re-admits the
    prefix from the catalog after recompute (pull-through semantics,
    ``admit``).  Only node *residency* is capacity-bounded.

    Every decision is appended to :attr:`events` as ``(kind, key,
    node_id)`` tuples — ``admit``/``evict``/``hit``/``partial``/
    ``miss``/``replicate``/``reject`` — deterministically for a given
    access sequence.
    """

    def __init__(self, nodes: Sequence[StorageNode], *,
                 placement: str = "hash", replicate_threshold: int = 3,
                 vnodes: int = 64, write_on_miss: bool = True):
        assert placement in ("hash", "popular"), placement
        assert len(nodes) > 0
        assert len({n.node_id for n in nodes}) == len(nodes), \
            "duplicate node ids"
        self.nodes = list(nodes)
        self.by_id = {n.node_id: n for n in self.nodes}
        self.placement = placement
        self.replicate_threshold = replicate_threshold
        self.write_on_miss = write_on_miss
        self.catalog: Dict[str, StoredPrefix] = {}
        self.hits_by_key: Dict[str, int] = {}
        self.events: List[Tuple[str, str, str]] = []
        self.lookups = 0
        self.full_hits = 0
        self.partial_hits = 0
        self.misses = 0
        self._ring: List[Tuple[int, str]] = []
        for n in self.nodes:
            for v in range(vnodes):
                self._ring.append((self._point(f"{n.node_id}#{v}"),
                                   n.node_id))
        self._ring.sort()

    def __repr__(self) -> str:
        used = sum(n.used_bytes for n in self.nodes)
        return (f"StorageCluster({len(self.nodes)} nodes, "
                f"{self.placement}, {len(self.catalog)} cataloged, "
                f"{used / GB:.2f} GB resident)")

    @staticmethod
    def _point(s: str) -> int:
        return int.from_bytes(hashlib.sha256(s.encode()).digest()[:8],
                              "big")

    def _ring_nodes(self, key: str) -> List[StorageNode]:
        """Distinct nodes in ring order starting at ``key``'s successor."""
        p = self._point(key)
        i = 0
        while i < len(self._ring) and self._ring[i][0] < p:
            i += 1
        seen: List[str] = []
        for j in range(len(self._ring)):
            nid = self._ring[(i + j) % len(self._ring)][1]
            if nid not in seen:
                seen.append(nid)
            if len(seen) == len(self.nodes):
                break
        return [self.by_id[nid] for nid in seen]

    def primary_node(self, key: str) -> StorageNode:
        return self._ring_nodes(key)[0]

    # -- registration -------------------------------------------------------
    def register(self, entry: StoredPrefix, now: float = 0.0) -> None:
        """Catalog ``entry`` and place it on its primary ring node."""
        self.catalog[entry.key] = entry
        self.hits_by_key.setdefault(entry.key, 0)
        self._place(entry, self.primary_node(entry.key), now)

    def register_prefix(self, token_ids: np.ndarray, kv_k: np.ndarray,
                        kv_v: np.ndarray, *, now: float = 0.0,
                        **kw) -> StoredPrefix:
        """Encode real KV into a manifest (like the legacy `KVStore`),
        auto-detect the longest registered ancestor from ``token_ids``,
        and register the result."""
        token_ids = np.asarray(token_ids)
        key = prefix_key(token_ids)
        man = encode_prefix(kv_k, kv_v, prefix=key, **kw)
        parent = self._longest_cataloged(token_ids, below=len(token_ids))
        entry = StoredPrefix.from_manifest(
            man, raw_kv_bytes=int(kv_k.nbytes + kv_v.nbytes),
            parent=parent.key if parent else None, token_ids=token_ids)
        self.register(entry, now)
        return entry

    def _place(self, entry: StoredPrefix, node: StorageNode,
               now: float) -> bool:
        ok, evicted = node.put(entry, now)
        for k in evicted:
            self.events.append(("evict", k, node.node_id))
        if ok:
            self.events.append(("admit", entry.key, node.node_id))
        else:
            self.events.append(("reject", entry.key, node.node_id))
        return ok

    # -- lookup -------------------------------------------------------------
    def _resident_nodes(self, key: str) -> List[StorageNode]:
        """Nodes holding ``key``, in deterministic ring order."""
        return [n for n in self._ring_nodes(key) if n.contains(key)]

    def _pick_replica(self, key: str,
                      nodes: List[StorageNode]) -> StorageNode:
        """Rotate across resident replicas by this key's lookup count —
        spreads concurrent fetches over the replicas' links while
        staying a pure function of the access sequence (unlike e.g.
        least-in-flight, which would make the event log clock-dependent
        and break cross-environment determinism)."""
        return nodes[self.hits_by_key.get(key, 0) % len(nodes)]

    def _longest_cataloged(self, token_ids: np.ndarray, *,
                           below: int) -> Optional[StoredPrefix]:
        """Longest cataloged prefix of ``token_ids`` shorter than
        ``below`` tokens (linear scan over the catalog; the catalog holds
        registered prefixes, not per-request state, so it stays small)."""
        best: Optional[StoredPrefix] = None
        for e in self.catalog.values():
            if e.token_ids is None or e.n_tokens >= below:
                continue
            if e.n_tokens > len(token_ids):
                continue
            if best is not None and e.n_tokens <= best.n_tokens:
                continue
            if np.array_equal(e.token_ids,
                              np.asarray(token_ids[:e.n_tokens])):
                best = e
        return best

    def _ancestor_chain(self, key: str) -> List[StoredPrefix]:
        """``key``'s cataloged ancestors, nearest first (via ``parent``
        links; used by the simulator where entries carry no token ids)."""
        out: List[StoredPrefix] = []
        cur = self.catalog.get(key)
        seen = {key}
        while cur is not None and cur.parent and cur.parent not in seen:
            seen.add(cur.parent)
            cur = self.catalog.get(cur.parent)
            if cur is not None:
                out.append(cur)
        return out

    def lookup(self, key: str, now: float,
               requested_tokens: Optional[int] = None) -> StorageHit:
        """Resolve a fetch for prefix ``key``: full hit if resident,
        partial hit on the nearest resident ancestor, else miss (and —
        with ``write_on_miss`` — re-admission from the catalog, modeling
        the donor re-uploading after the recompute)."""
        self.lookups += 1
        want = self.catalog.get(key)
        requested = (requested_tokens if requested_tokens is not None
                     else (want.n_tokens if want else 0))
        candidates = [want] if want else []
        candidates += self._ancestor_chain(key)
        for cand in candidates:
            nodes = self._resident_nodes(cand.key)
            if not nodes:
                continue
            node = self._pick_replica(cand.key, nodes)
            node.get(cand.key, now)
            self.hits_by_key[cand.key] = \
                self.hits_by_key.get(cand.key, 0) + 1
            full = cand.key == key and cand.n_tokens >= requested
            kind = "full" if full else "partial"
            self.events.append((kind, cand.key, node.node_id))
            if full:
                self.full_hits += 1
            else:
                self.partial_hits += 1
            self._maybe_replicate(cand, now)
            return StorageHit(kind=kind, requested_tokens=requested,
                              covered_tokens=min(cand.n_tokens, requested),
                              entry=cand, node=node)
        self.misses += 1
        self.events.append(("miss", key, ""))
        if self.write_on_miss and want is not None:
            self._place(want, self.primary_node(key), now)
        return StorageHit(kind="miss", requested_tokens=requested)

    def lookup_tokens(self, token_ids: np.ndarray,
                      now: float) -> StorageHit:
        """Longest-prefix-match lookup by token ids (live-engine path):
        resolve the longest cataloged prefix of ``token_ids``, then fall
        through :meth:`lookup` for residency/ancestors/replication."""
        token_ids = np.asarray(token_ids)
        best = self._longest_cataloged(token_ids,
                                       below=len(token_ids) + 1)
        if best is None:
            self.lookups += 1
            self.misses += 1
            self.events.append(("miss", prefix_key(token_ids), ""))
            return StorageHit(kind="miss",
                              requested_tokens=len(token_ids))
        return self.lookup(best.key, now,
                           requested_tokens=len(token_ids))

    def admit(self, key: str, now: float) -> bool:
        """Re-admit a cataloged prefix onto its primary node (explicit
        pull-through; :meth:`lookup` already does this on miss when
        ``write_on_miss`` is set)."""
        entry = self.catalog.get(key)
        if entry is None:
            return False
        return self._place(entry, self.primary_node(key), now)

    def _maybe_replicate(self, entry: StoredPrefix, now: float) -> None:
        if self.placement != "popular":
            return
        if self.hits_by_key.get(entry.key, 0) < self.replicate_threshold:
            return
        for node in self._ring_nodes(entry.key)[1:]:
            if not node.contains(entry.key):
                if self._place(entry, node, now):
                    self.events.append(("replicate", entry.key,
                                        node.node_id))
                return  # one replica per threshold crossing

    # -- stats --------------------------------------------------------------
    def hit_rate(self) -> float:
        """Full+partial hits over all lookups (0.0 when no lookups)."""
        if not self.lookups:
            return 0.0
        return (self.full_hits + self.partial_hits) / self.lookups

    def stored_bytes(self) -> int:
        return sum(n.used_bytes for n in self.nodes)


# ---------------------------------------------------------------------------
# Legacy single-node facade
# ---------------------------------------------------------------------------


class KVStore:
    """The original flat in-process store, now a facade over one
    unbounded :class:`StorageNode` — same API (register / lookup /
    get_chunk return `KVManifest`\\ s), no capacity pressure, no network
    placement.  Integration tests and the quickstart keep using it; the
    multi-node tier above is the production-shaped path."""

    def __init__(self) -> None:
        self.node = StorageNode("local", capacity_bytes=None)

    @property
    def manifests(self) -> Dict[str, KVManifest]:
        return {k: r.entry.manifest for k, r in self.node.residents.items()
                if r.entry.manifest is not None}

    def register(self, manifest: KVManifest) -> None:
        self.node.put(StoredPrefix.from_manifest(manifest), now=0.0)

    def register_prefix(self, token_ids: np.ndarray, kv_k: np.ndarray,
                        kv_v: np.ndarray, **kw) -> KVManifest:
        key = prefix_key(np.asarray(token_ids))
        man = encode_prefix(kv_k, kv_v, prefix=key, **kw)
        self.register(man)
        return man

    def lookup(self, prefix: str) -> Optional[KVManifest]:
        e = self.node.get(prefix, now=0.0)
        return e.manifest if e is not None else None

    def get_chunk(self, prefix: str, chunk_id: str, resolution: str) -> bytes:
        return self.node.residents[prefix].entry.manifest.blobs[
            (chunk_id, resolution)]

    def stored_bytes(self) -> int:
        return self.node.stored_bytes()
