"""Analytic engine cost model for the discrete-event simulator.

Prefill is compute-bound (2*N_active*T matmul flops + attention term at an
assumed MFU); decode is memory-bound (params + KV traffic over HBM). GPU
specs cover the paper's three platforms; ``tpu-v5e`` is the target
deployment of this repo's adaptation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: float  # bf16
    hbm_bw: float  # bytes/s
    hbm_bytes: float


CHIPS: Dict[str, ChipSpec] = {
    "h20": ChipSpec("h20", 148e12, 4.0e12, 96e9),
    "a100": ChipSpec("a100", 312e12, 2.0e12, 80e9),
    "l20": ChipSpec("l20", 119e12, 864e9, 48e9),
    "tpu-v5e": ChipSpec("tpu-v5e", 197e12, 819e9, 16e9),
}


@dataclasses.dataclass
class EngineCostModel:
    cfg: ModelConfig
    chip: ChipSpec
    n_chips: int = 2
    mfu: float = 0.45
    hbm_eff: float = 0.75

    def _flops_prefill(self, n_tokens: int, ctx: int) -> float:
        dense = 2.0 * self.cfg.param_count(active_only=True) * n_tokens
        n_attn = sum(1 for k in self.cfg.layer_kinds() if k == "attn")
        attn = (2.0 * 2.0 * n_tokens * (ctx + n_tokens) / 2 * n_attn
                * self.cfg.num_heads * self.cfg.head_dim)
        return dense + attn

    def prefill_time(self, n_tokens: int, ctx: int = 0) -> float:
        return self._flops_prefill(n_tokens, ctx) / (
            self.n_chips * self.chip.peak_flops * self.mfu)

    def decode_step_time(self, batch: int, mean_context: float) -> float:
        pbytes = 2.0 * self.cfg.param_count(active_only=True)
        kv = self.cfg.kv_bytes_per_token() * mean_context * batch
        return (pbytes + kv) / (self.n_chips * self.chip.hbm_bw *
                                self.hbm_eff)

    def layer_comp_times(self, n_tokens: int) -> list:
        """Per-layer prefill compute time (for Appx A.3 admission)."""
        t = self.prefill_time(n_tokens)
        L = self.cfg.num_layers
        return [t / L] * L
