"""User-level fair scheduling for multi-tenant serving (ISSUE 8).

One abusive tenant flooding prefix fetches can starve every
well-behaved user's TTFT on the shared WAN link and the shared storage
nodes.  This module adds the enterprise serving layer the north star
asks for: a virtual-token-counter scheduler (VTC / FairServe-style —
the FCFS-vs-VTC-vs-FairServe experiment driver of SNIPPETS.md #2, the
LMCache serving layer of PAPERS.md) that tracks *per-user served cost*
and always dispatches the most lagging backlogged user next.

Counter model
-------------
Every user ``u`` carries one monotone counter ``C[u]`` in abstract
*cost units*, advanced whenever work is served on u's behalf:

* **fetched bytes** — a completed (or aborted-after-partial-delivery)
  fetch charges ``wire_bytes / byte_unit / W[u]``;
* **decode work** — admission to the running batch charges the
  *expected* serve cost ``(prefill_tokens + output_token_weight *
  max_new_tokens) * token_unit / W[u]`` (FairServe charges expected
  tokens at schedule time, which keeps the event log free of
  compute-side timing).

``W[u]`` is the weight of the user's SLO tier (``slo_tier`` →
:attr:`FairScheduler.tiers`), so a premium user's counter advances
proportionally slower — weighted fair queueing in virtual-time form.
A user (re)joining with an empty backlog is lifted to the minimum
counter among currently backlogged/in-flight users, so idling never
banks credit (the VTC no-gaming rule).

Scheduling levers
-----------------
The same tier weight drives every shared resource:

* **link** — ``Request.weight`` is stamped at arrival, so
  `SharedLink`'s weighted-fair fluid shares and DRR quanta honor the
  tier directly;
* **fetch dispatch** — queued fetches drain through :meth:`take` in
  lagging-user order, at most ``max_inflight`` on the wire at once
  (the VTC admission queue: an abusive flood backlogs behind every
  lagging well-behaved user);
* **storage** — :meth:`apply_storage_priority` maps tiers onto the
  storage tier's levers: top-tier prefixes are pinned (never evicted /
  expired), above-baseline tiers get their admission ask-counter
  seeded so ``second_hit``/``cost`` admission grants residency on
  first touch;
* **prefetch** — :meth:`prefetch_share` splits a
  `PrefetchManager`'s mispredict budget by tier weight, so one
  tenant's bad speculation cannot burn the shared budget
  (``fairness=`` on the manager).

Determinism contract
--------------------
Every decision appends a timestamp-free event ``(user, rid, kind,
counter)`` with the counter quantized to integer milli-units.  Kinds:
``arrive`` / ``dispatch`` / ``fetched`` / ``abort`` / ``miss`` /
``serve``.  All inputs are pure functions of the access sequence
(token counts, table-size wire bytes, arrival order), so the analytic
simulator and the live engine replay byte-identical logs for the same
trace (``tests/test_fairness.py``); see docs/fairness.md for the full
state machine and a worked abusive-flood timeline.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.scheduler import Request

#: event-log counter quantization: counters are logged in integer
#: milli-cost-units so cross-environment comparison is exact
COUNTER_QUANT = 1000.0


class FairScheduler:
    """Virtual-token-counter (VTC) fair scheduler over users.

    Plug it into both environments (``ServingSimulator(fairness=...)``,
    ``LiveEngine(fairness=...)``); they hand it to the shared
    `FetchingAwareScheduler`, so there is no second fairness
    implementation to drift (the no-second-pipeline rule).
    """

    #: default SLO ladder: weight = share multiplier on every lever
    DEFAULT_TIERS = {"free": 1.0, "standard": 2.0, "premium": 4.0}
    DEFAULT_TIER = "standard"

    def __init__(self, *, tiers: Optional[Dict[str, float]] = None,
                 max_inflight: Optional[int] = 2,
                 byte_unit: float = 1e6, token_unit: float = 1e-3,
                 output_token_weight: float = 2.0):
        self.tiers = dict(tiers if tiers is not None
                          else self.DEFAULT_TIERS)
        assert self.tiers and all(w > 0 for w in self.tiers.values()), \
            "tier weights must be positive"
        #: global cap on concurrently dispatched fetches (None = no
        #: cap: lagging-user *ordering* still applies, backlogging
        #: does not)
        self.max_inflight = max_inflight
        self.byte_unit = float(byte_unit)
        self.token_unit = float(token_unit)
        self.output_token_weight = float(output_token_weight)
        #: per-user served-cost counters (weight-normalized cost units)
        self.counters: Dict[str, float] = {}
        #: deterministic decision log: (user, rid, kind, milli-counter)
        self.events: List[Tuple[str, int, str, int]] = []
        self._tier_of: Dict[str, str] = {}
        self._backlog: Dict[str, Deque[Request]] = {}
        self._inflight: Dict[int, str] = {}  # rid -> user
        self._inflight_by_user: Dict[str, int] = {}
        # rids already charged decode work; an insertion-ordered dict
        # (not a set) so any future drain replays in admission order
        self._served: Dict[int, None] = {}
        self._prefix_users: Dict[str, str] = {}  # key -> last demander

    def __repr__(self) -> str:
        return (f"FairScheduler({len(self.counters)} users, "
                f"{sum(len(q) for q in self._backlog.values())} queued, "
                f"{len(self._inflight)} in flight)")

    # -- identity ----------------------------------------------------------
    @staticmethod
    def user_of(req: Request) -> str:
        return req.user if req.user is not None else "anon"

    def register(self, user: str, slo_tier: str) -> float:
        """Pin ``user`` to an SLO tier ahead of any traffic (tenant
        onboarding); returns the tier weight.  Arrivals carrying a
        ``slo_tier`` update the mapping themselves."""
        assert slo_tier in self.tiers, \
            f"unknown tier {slo_tier!r} (have {sorted(self.tiers)})"
        self._tier_of[user] = slo_tier
        return self.tiers[slo_tier]

    def tier_of(self, user: str) -> str:
        return self._tier_of.get(user, self.DEFAULT_TIER)

    def weight_of(self, user: str) -> float:
        return self.tiers.get(self.tier_of(user),
                              self.tiers.get(self.DEFAULT_TIER, 1.0))

    # -- event log ---------------------------------------------------------
    def _emit(self, user: str, rid: int, kind: str) -> None:
        self.events.append(
            (user, rid, kind,
             int(round(self.counters.get(user, 0.0) * COUNTER_QUANT))))

    # -- arrival / queueing -------------------------------------------------
    def _active_counters(self) -> List[float]:
        return [self.counters[u] for u in self.counters
                if self._backlog.get(u) or
                self._inflight_by_user.get(u, 0) > 0]

    def on_arrival(self, req: Request) -> None:
        """A request entered the system: bind the user's tier, stamp the
        link weight, and lift an idle user's counter to the active
        minimum (idling must not bank credit)."""
        u = self.user_of(req)
        if req.slo_tier is not None:
            self.register(u, req.slo_tier)
        req.weight = self.weight_of(u)
        idle = not (self._backlog.get(u)
                    or self._inflight_by_user.get(u, 0) > 0)
        active = self._active_counters()
        if idle and active:
            self.counters[u] = max(self.counters.get(u, 0.0),
                                   min(active))
        else:
            self.counters.setdefault(u, 0.0)
        if req.prefix is not None:
            self._prefix_users[req.prefix] = u
        self._emit(u, req.rid, "arrive")

    def enqueue(self, req: Request) -> None:
        """Queue one fetch for fair dispatch (called by the scheduler
        instead of handing the fetch straight to the controller)."""
        u = self.user_of(req)
        self._backlog.setdefault(u, deque()).append(req)

    def backlog_size(self, user: Optional[str] = None) -> int:
        if user is not None:
            return len(self._backlog.get(user, ()))
        return sum(len(q) for q in self._backlog.values())

    def inflight_size(self) -> int:
        return len(self._inflight)

    # -- dispatch (the VTC decision) ----------------------------------------
    def take(self) -> List[Request]:
        """Drain queued fetches in lagging-user order into the free
        dispatch slots.  Work-conserving: whenever a slot is free and
        any user has backlog, a fetch IS dispatched — fairness only
        decides *whose*.  Ties break toward fewer in-flight fetches,
        then the heavier tier, then the lexicographically smaller user
        (fully deterministic)."""
        out: List[Request] = []
        while any(self._backlog.values()):
            if self.max_inflight is not None \
                    and len(self._inflight) >= self.max_inflight:
                break
            u = min((u for u, q in self._backlog.items() if q),
                    key=lambda u: (self.counters.get(u, 0.0),
                                   self._inflight_by_user.get(u, 0),
                                   -self.weight_of(u), u))
            req = self._backlog[u].popleft()
            if not self._backlog[u]:
                del self._backlog[u]
            self._inflight[req.rid] = u
            self._inflight_by_user[u] = \
                self._inflight_by_user.get(u, 0) + 1
            self._emit(u, req.rid, "dispatch")
            out.append(req)
        return out

    def _release(self, rid: int) -> Optional[str]:
        u = self._inflight.pop(rid, None)
        if u is not None:
            n = self._inflight_by_user.get(u, 0) - 1
            if n > 0:
                self._inflight_by_user[u] = n
            else:
                self._inflight_by_user.pop(u, None)
        return u

    # -- served-cost charges -------------------------------------------------
    def _charge(self, user: str, cost_units: float) -> None:
        self.counters[user] = (self.counters.get(user, 0.0)
                               + cost_units / self.weight_of(user))

    def on_fetch_done(self, req: Request, nbytes: float) -> None:
        """A fetch delivered: free its slot and charge the wire bytes.
        Idempotent per rid, so the wall-clock fallback (which cannot
        meter bytes and charges 0) never double-counts the virtual
        path's charge."""
        u = self._release(req.rid)
        if u is None:
            return
        self._charge(u, nbytes / self.byte_unit)
        self._emit(u, req.rid, "fetched")

    def on_fetch_abort(self, req: Request, nbytes: float) -> None:
        """Transport abort (``max_attempts`` exhausted): the user still
        consumed the delivered bytes — charge them and free the slot."""
        u = self._release(req.rid)
        if u is None:
            return
        self._charge(u, nbytes / self.byte_unit)
        self._emit(u, req.rid, "abort")

    def on_fetch_miss(self, req: Request) -> None:
        """Storage miss at dispatch: nothing moved on the wire — free
        the slot without charging.  No-op when the rid never reached a
        slot (e.g. an abort already released it)."""
        u = self._release(req.rid)
        if u is None:
            return
        self._emit(u, req.rid, "miss")

    def on_admit(self, req: Request) -> None:
        """Admission to the running batch: charge the *expected* serve
        cost (suffix prefill + weighted output tokens) FairServe-style,
        so the decision log never depends on compute-side timing."""
        if req.rid in self._served:
            return
        self._served[req.rid] = None
        u = self.user_of(req)
        tokens = (max(req.prompt_len - req.reuse_tokens, 0)
                  + self.output_token_weight * req.max_new_tokens)
        self._charge(u, tokens * self.token_unit)
        self._emit(u, req.rid, "serve")

    # -- storage tier priority ----------------------------------------------
    def apply_storage_priority(self, cluster, user: str, key: str,
                               now: float = 0.0) -> bool:
        """Map ``user``'s SLO tier onto the storage tier's levers for
        ``key``: top-tier prefixes are pinned (never evicted, never
        expired — `StoredPrefix.pinned`), any tier above the minimum
        weight gets the admission ask-counter seeded to
        ``admission_min_asks`` so ``second_hit``/``cost`` admission
        grants residency on first touch; bottom-tier keys earn
        residency like everyone else.  Returns True when the key is
        cataloged (i.e. the priority could attach)."""
        entry = cluster.catalog.get(key)
        if entry is None:
            return False
        w = self.weight_of(user)
        if w >= max(self.tiers.values()):
            entry.pinned = True
        if w > min(self.tiers.values()):
            cluster.asks_by_key[key] = max(
                cluster.asks_by_key.get(key, 0),
                cluster.admission_min_asks)
        return True

    # -- prefetch budget shares ---------------------------------------------
    def prefix_user(self, key: Optional[str]) -> Optional[str]:
        """Owner attribution for speculation: the last user whose demand
        named this prefix (None if never demanded)."""
        if key is None:
            return None
        return self._prefix_users.get(key)

    def prefetch_share(self, user: Optional[str]) -> float:
        """``user``'s fraction of the shared mispredict budget: tier
        weight over the total weight of all known users (1.0 while no
        user is known — nothing to split yet)."""
        known = set(self._tier_of) | set(self.counters)
        if user is not None:
            known.add(user)
        if not known:
            return 1.0
        total = sum(self.weight_of(u) for u in known)
        return self.weight_of(user if user is not None
                              else "anon") / total
