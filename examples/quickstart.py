"""Quickstart: the KVFetcher codec on real KV tensors in ~40 lines.

Runs a real (reduced) llama-family model, captures its KV cache, searches
the codec-friendly intra-frame layout, encodes at several resolutions, and
verifies the bit-exact round trip.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.codec import KVCodec
from repro.core.quantization import quantize
from repro.serving import paged_model
from repro.models import transformer as tf

cfg = reduce_config(get_config("lwm-7b"))
print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
      f"kv_heads={cfg.num_kv_heads} head_dim={cfg.head_dim}")

params = tf.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
from repro.data.pipeline import _zipf_tokens
tokens = _zipf_tokens(rng, cfg.vocab_size, (256,))

# real KV cache from a real forward pass
_, kvs = paged_model.prefill_collect_kv(params, cfg, tokens[None])
kv_k = np.stack([np.asarray(k[0]) for k, _ in kvs], axis=1)  # [T, L, K, hd]
print(f"KV cache: {kv_k.shape}, {2 * kv_k.nbytes / 1e6:.1f} MB fp16-equiv "
      f"(K+V)")

q, scales = quantize(kv_k[:, :3])  # first 3-layer group
codec = KVCodec(cfg.num_kv_heads, cfg.head_dim)
log = []
best = codec.search_layout(q[:128], "240p", log=log)
print(f"layout search over {len(log)} candidates -> "
      f"(hr={best.hr}, dr={best.dr}), tile {best.tile}")

for res in ("240p", "480p", "1080p"):
    blob = codec.encode_chunk(q, res)
    back = codec.decode_chunk(blob)
    assert np.array_equal(back, q), "codec must be lossless"
    print(f"  {res:>5}: {len(blob):7d} B   "
          f"ratio vs fp16 = {2 * q.nbytes / len(blob):5.2f}x   (bit-exact)")

print("frame-wise decode:", sum(len(t) for t, _ in
                                codec.iter_decode_frames(blob)), "tokens")
print("OK")
