"""Train a ~100M-param dense model for a few hundred steps on synthetic
data (CPU). Demonstrates the full training substrate: AdamW + cosine
schedule, remat'd scanned layers, checkpointing.

    PYTHONPATH=src python examples/train_small.py --steps 300
"""
import argparse
import dataclasses

from repro.configs import get_config, reduce_config
from repro.training.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--ckpt", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # ~100M params: 8 layers x d512 over a 8k vocab
    cfg = reduce_config(get_config(args.arch), d_model=512, num_layers=8,
                        vocab=8192)
    n_params = cfg.param_count()
    print(f"arch {cfg.name}: {n_params / 1e6:.0f}M params")
    hist = train(cfg, steps=args.steps, batch_size=args.batch,
                 seq_len=args.seq, lr=3e-4, ckpt_path=args.ckpt)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"]
    print("OK")


if __name__ == "__main__":
    main()
