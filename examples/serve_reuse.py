"""End-to-end serving driver (the paper's deployment): batched requests
with remote prefix reuse on a real small model.

A donor request populates the remote store with encoded KV chunks; later
requests sharing the prefix fetch, decode, and restore it frame-wise into
paged memory, then prefill only their suffixes. Generations are compared
against full prefill to demonstrate losslessness, and the fetching-aware
scheduler serves non-reuse requests without HOL blocking.

The batched section runs the wall-clock engine (fetches complete at
dispatch — no network model).  The final section serves the same reuse
request over the modeled WAN (``bandwidth=BandwidthTrace(...)``,
``fetch_mode="async"`` — see docs/fetch_pipeline.md and the
``ttft.wan.*`` rows of benchmarks/bench_ttft.py) with a **streaming
per-token client view**: ``on_token=`` delivers each token to the
client callback the moment it exists on the virtual clock, so the
printed TTFT and inter-token gaps are exactly what the metrics report.

    PYTHONPATH=src python examples/serve_reuse.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.cluster.network import BandwidthTrace
from repro.cluster.storage import KVStore
from repro.core.chunks import prefix_key
from repro.data.workload import shared_prefix_tokens
from repro.models import transformer as tf
from repro.serving import paged_model
from repro.serving.engine import LiveEngine
from repro.serving.metrics import split_summary

cfg = reduce_config(get_config("lwm-7b"))
params = tf.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

PREFIX_LEN, SUFFIX_LEN, N_REQ = 96, 8, 3
prefix, prompts = shared_prefix_tokens(rng, cfg.vocab_size, PREFIX_LEN,
                                       N_REQ, SUFFIX_LEN)

# ---- offline: donor run registers the encoded prefix -----------------------
print("== donor: encode + register prefix KV ==")
_, kvs = paged_model.prefill_collect_kv(params, cfg, prefix[None])
kv_k = np.stack([np.asarray(k[0]) for k, _ in kvs], axis=1)
kv_v = np.stack([np.asarray(v[0]) for _, v in kvs], axis=1)
store = KVStore()
key = prefix_key(prefix)
man = store.register_prefix(prefix, kv_k, kv_v, tokens_per_chunk=32,
                            resolutions=("240p", "1080p"))
raw = 2 * (kv_k.nbytes + kv_v.nbytes)
print(f"  prefix {PREFIX_LEN} tokens -> {len(man.refs)} chunks, "
      f"{man.total_bytes('240p') / 1e3:.0f} kB at 240p "
      f"({raw / man.total_bytes('240p'):.1f}x vs fp16)")

# ---- online: batched serving with reuse ------------------------------------
print("== engine: mixed batch (reuse + non-reuse) ==")
eng = LiveEngine(params, cfg, store, policy="kvfetcher", max_running=4)
reqs = []
for i, p in enumerate(prompts):
    reqs.append(eng.submit(p, reuse_prefix=key, reuse_tokens=PREFIX_LEN,
                           max_new_tokens=4))
plain = eng.submit(rng.integers(0, cfg.vocab_size, 24), max_new_tokens=4)
t0 = time.time()
eng.run()
print(f"  served {len(eng.finished)} requests in {time.time() - t0:.1f}s "
      f"(live CPU compute)")
print(f"  restored tokens: {eng.stats.restored_tokens}, "
      f"fetched {eng.stats.fetched_bytes / 1e3:.0f} kB, "
      f"restore buffer high-water {eng.stats.restore_buffer_high_water / 1e3:.0f} kB")

# ---- losslessness check ------------------------------------------------------
# The codec itself is BIT-EXACT after int8 quantization (property-tested
# in tests/test_codec.py); tests/test_live_engine.py asserts identical
# generations on its seeds. This untrained demo model has near-uniform
# logits over a 512-token vocab, so argmax is tie-dominated and the int8
# quantization step (shared with CacheGen/ShadowServe) can flip tokens —
# we report agreement informationally and assert the functional outcome.
print("== verify: reuse vs full prefill ==")
eng_ref = LiveEngine(params, cfg, KVStore(), max_running=4)
ref_req = eng_ref.submit(prompts[0], max_new_tokens=4)
eng_ref.run()
a = eng_ref.outputs[ref_req.rid]
b = eng.outputs[reqs[0].rid]
frac = sum(x == y for x, y in zip(a, b)) / len(a)
print(f"  first token identical: {a[0] == b[0]}; "
      f"token agreement {frac:.0%} (untrained model => argmax ties; "
      "see tests for the exact-match proof)")
assert len(eng.finished) == N_REQ + 1
assert eng.stats.restored_tokens == 2 * PREFIX_LEN * N_REQ
for name, s in split_summary(eng.finished).items():
    if s.get("n"):
        print(f"  {name:10s} n={s['n']:.0f} ttft_mean={s.get('ttft_mean', 0):.2f}s")

# ---- streaming client view over the modeled WAN ----------------------------
# The same reuse request, now fetched over a 0.5 Gbps virtual link with
# the async pipeline.  on_token= fires inside the engine at the instant
# each token exists — first token mid-prefill, then one per decode step —
# so a client sees tokens trickle at virtual-clock pace instead of
# waiting for run() to return the finished batch.
print("== streaming: per-token client view (async WAN, virtual clock) ==")
stream = []


def client_view(req, tok, t):
    stream.append((req.rid, tok, t))
    dt = t - stream[0][2]
    tag = "ttft" if len(stream) == 1 else f"+{dt:.3f}s"
    print(f"  rid={req.rid} token#{len(stream) - 1} -> {tok:4d} "
          f"at t={t:.3f}s ({tag})")


eng_s = LiveEngine(params, cfg, store, policy="kvfetcher",
                   fetch_mode="async",
                   bandwidth=BandwidthTrace.constant(0.5),
                   on_token=client_view)
sreq = eng_s.submit(prompts[0], reuse_prefix=key, reuse_tokens=PREFIX_LEN,
                    max_new_tokens=4)
eng_s.run()
toks = [tok for _, tok, _ in stream]
assert toks == eng_s.outputs[sreq.rid], "stream must mirror outputs"
assert [t for _, _, t in stream] == sreq.token_times
print(f"  streamed {len(toks)} tokens, ttft={sreq.t_first_token:.3f}s "
      "(virtual); stream == outputs, times == token_times")
print("OK")
