"""Cluster-scale TTFT study (paper Figs. 18/19/21): discrete-event
simulation of full-size models over bandwidth-limited networks, comparing
KVFetcher against full prefill, raw reuse, CacheGen-, llm.265- and
LMCache-style baselines. Compression ratios are measured with the real
codec on real KV tensors before simulating.

Part two exercises the multi-node prefix storage tier
(docs/storage_tier.md): a 3-node capacity-bounded cluster — each node
with its own WAN link — serving a seeded Zipf workload over a prefix
trie, with full hits, partial (ancestor) hits, misses, and evictions.
Part three kills 1 of 3 nodes mid-trace: with replication=2 the ring
heal keeps TTFT near baseline, unreplicated prefixes fall back to full
prefill until re-replication restores them.

    PYTHONPATH=src python examples/simulate_cluster.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.adaptive import H20_TABLE
from repro.core.scheduler import Request
from repro.cluster.network import BandwidthTrace
from repro.cluster.simulator import (
    ServingSimulator, cachegen_spec, full_prefill_spec, kvfetcher_spec,
    llm265_spec, lmcache_raw_spec, raw_spec,
)
from repro.cluster.storage import (StorageCluster, StorageNode,
                                   synthetic_stored_prefix)
from repro.data.workload import (fixed_context_trace, prefix_trie_specs,
                                 zipf_prefix_trace)
from repro.serving.metrics import summarize

CFG = get_config("yi-34b")
# measured in benchmarks/bench_compression.py on real KV (see EXPERIMENTS.md)
RATIOS = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}

METHODS = [
    ("full_prefill", full_prefill_spec()),
    ("lmcache_raw", lmcache_raw_spec()),
    ("raw (mooncake)", raw_spec()),
    ("cachegen", cachegen_spec(3.5)),
    ("llm.265", llm265_spec(5.0)),
    ("kvfetcher", kvfetcher_spec(RATIOS)),
]


def storage_tier_demo() -> None:
    """3-node capacity-bounded storage tier under a Zipf workload."""
    specs = prefix_trie_specs(3, 2, base_tokens=40_000, ext_tokens=20_000)
    entries = [synthetic_stored_prefix(
        s.key, s.n_tokens, raw_bytes_per_token=CFG.kv_bytes_per_token(),
        ratios=RATIOS, parent=s.parent) for s in specs]
    total = sum(e.stored_bytes for e in entries)
    # each node holds ~40% of the library and owns an 8 Gbps link:
    # placement decides which link a fetch rides, eviction decides
    # whether it is a full hit, an ancestor (partial) hit, or a miss
    nodes = [StorageNode(f"n{i}", capacity_bytes=int(total * 0.4),
                         policy="cost",
                         link=BandwidthTrace.constant(8.0))
             for i in range(3)]
    cluster = StorageCluster(nodes, placement="popular",
                             replicate_threshold=3)
    for e in entries:
        cluster.register(e, 0.0)
    sim = ServingSimulator(CFG, kvfetcher_spec(RATIOS), chip="h20",
                           n_chips=2,
                           bandwidth=BandwidthTrace.constant(8.0),
                           storage=cluster, table=H20_TABLE)
    rng = np.random.default_rng(42)
    reqs = zipf_prefix_trace(rng, specs, n_requests=24, alpha=1.1,
                             gap=90.0, max_new_tokens=8)
    sim.run(reqs, max_new_tokens=8)
    print(f"\n3-node storage tier (cost-aware eviction, popularity "
          f"replication), {len(specs)}-prefix trie, Zipf workload:")
    for n in nodes:
        print(f"  {n}")
    evictions = sum(1 for e in cluster.events if e[0] == "evict")
    print(f"  lookups={cluster.lookups} full={cluster.full_hits} "
          f"partial={cluster.partial_hits} miss={cluster.misses} "
          f"evictions={evictions} hit_rate={cluster.hit_rate():.2f}")
    print(f"  mean TTFT {summarize(reqs)['ttft_mean']:.2f}s")


def failover_demo() -> None:
    """Part three: kill 1 of 3 nodes mid-trace.  With replication=2 the
    surviving replica keeps serving (the ring heal streams the lost
    copy over the survivor's link, contending with live fetches); with
    replication=1 the lost prefix pays a full prefill until healed."""
    spec = prefix_trie_specs(1, 1, base_tokens=40_000)[0]
    print("\n1-of-3 node failure at t=300s (40K-token prefix, "
          "8 Gbps links, heal='link'):")
    for repl in (2, 1):
        nodes = [StorageNode(f"n{i}", link=BandwidthTrace.constant(8.0))
                 for i in range(3)]
        cluster = StorageCluster(nodes, replication=repl, heal="link")
        cluster.register(synthetic_stored_prefix(
            spec.key, spec.n_tokens,
            raw_bytes_per_token=CFG.kv_bytes_per_token(),
            ratios=RATIOS), 0.0)
        victim = cluster.primary_node(spec.key).node_id
        reqs = [Request(rid=i, arrival=t, prompt_len=spec.n_tokens + 1_000,
                        reuse_tokens=spec.n_tokens, prefix=spec.key,
                        max_new_tokens=4)
                for i, t in enumerate((10.0, 301.0, 390.0, 480.0))]
        sim = ServingSimulator(CFG, kvfetcher_spec(RATIOS), chip="h20",
                               n_chips=2,
                               bandwidth=BandwidthTrace.constant(8.0),
                               storage=cluster, table=H20_TABLE,
                               fail_at=[(300.0, victim)])
        sim.run(reqs, max_new_tokens=4)
        hits = "/".join(r.storage_hit for r in reqs)
        heals = sum(1 for e in cluster.events if e[0] == "heal")
        print(f"  replication={repl}: kill {victim} -> {hits}, "
              f"{heals} heal(s); TTFT "
              + " ".join(f"{r.ttft:.1f}s" for r in reqs))


def main() -> None:
    print(f"model {CFG.name} on 2x H20, context 100K, 16 Gbps")
    print(f"{'method':>15} {'TTFT(s)':>9} {'poolUtil':>9} {'buf(MB)':>8}")
    base = None
    for name, spec in METHODS:
        sim = ServingSimulator(CFG, spec, chip="h20", n_chips=2,
                               bandwidth=BandwidthTrace.constant(16.0),
                               table=H20_TABLE)
        res = sim.run(fixed_context_trace(100_000, n_requests=3, gap=60.0),
                      max_new_tokens=8)
        reqs = res.fetching() or res.requests
        t = summarize(reqs)["ttft_mean"]
        base = base or t
        print(f"{name:>15} {t:9.2f} {res.decode_pool_utilization:9.2f} "
              f"{res.decompress_buffer_high_water / 1e6:8.1f}")
    storage_tier_demo()
    failover_demo()
    print("OK")


if __name__ == "__main__":
    main()
