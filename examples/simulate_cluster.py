"""Cluster-scale TTFT study (paper Figs. 18/19/21): discrete-event
simulation of full-size models over bandwidth-limited networks, comparing
KVFetcher against full prefill, raw reuse, CacheGen-, llm.265- and
LMCache-style baselines. Compression ratios are measured with the real
codec on real KV tensors before simulating.

    PYTHONPATH=src python examples/simulate_cluster.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.adaptive import H20_TABLE
from repro.cluster.network import BandwidthTrace
from repro.cluster.simulator import (
    ServingSimulator, cachegen_spec, full_prefill_spec, kvfetcher_spec,
    llm265_spec, lmcache_raw_spec, raw_spec,
)
from repro.data.workload import fixed_context_trace
from repro.serving.metrics import summarize

CFG = get_config("yi-34b")
# measured in benchmarks/bench_compression.py on real KV (see EXPERIMENTS.md)
RATIOS = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}

METHODS = [
    ("full_prefill", full_prefill_spec()),
    ("lmcache_raw", lmcache_raw_spec()),
    ("raw (mooncake)", raw_spec()),
    ("cachegen", cachegen_spec(3.5)),
    ("llm.265", llm265_spec(5.0)),
    ("kvfetcher", kvfetcher_spec(RATIOS)),
]


def main() -> None:
    print(f"model {CFG.name} on 2x H20, context 100K, 16 Gbps")
    print(f"{'method':>15} {'TTFT(s)':>9} {'poolUtil':>9} {'buf(MB)':>8}")
    base = None
    for name, spec in METHODS:
        sim = ServingSimulator(CFG, spec, chip="h20", n_chips=2,
                               bandwidth=BandwidthTrace.constant(16.0),
                               table=H20_TABLE)
        res = sim.run(fixed_context_trace(100_000, n_requests=3, gap=60.0),
                      max_new_tokens=8)
        reqs = res.fetching() or res.requests
        t = summarize(reqs)["ttft_mean"]
        base = base or t
        print(f"{name:>15} {t:9.2f} {res.decode_pool_utilization:9.2f} "
              f"{res.decompress_buffer_high_water / 1e6:8.1f}")
    print("OK")


if __name__ == "__main__":
    main()
