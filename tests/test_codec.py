"""Codec round-trip + layout + entropy tests (unit + property)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import entropy
from repro.core.codec import KVCodec
from repro.core.layout import (
    IntraLayout, frame_geometry, intra_candidates, pack_frames,
    unpack_frames, unpack_single_frame, tile_forward, tile_inverse,
)
from repro.core.prediction import predict_decode, predict_encode
from repro.core.quantization import dequantize, quantize


def _kv_like(rng, T, L, H, D):
    """Synthetic KV with token-adjacent similarity (AR(1) along tokens)."""
    base = rng.standard_normal((1, L, H, D)).astype(np.float32)
    noise = rng.standard_normal((T, L, H, D)).astype(np.float32)
    out = np.empty((T, L, H, D), np.float32)
    out[0] = base[0] + 0.1 * noise[0]
    for t in range(1, T):
        out[t] = out[t - 1] * 0.98 + 0.08 * noise[t]
    return out * 3.0


# ---------------------------------------------------------------------------
# entropy
# ---------------------------------------------------------------------------

@given(st.binary(min_size=0, max_size=4096),
       st.sampled_from([1, 2, 64, 256]))
@settings(max_examples=40, deadline=None)
def test_rans_roundtrip_property(data, lanes):
    arr = np.frombuffer(data, np.uint8)
    blob = entropy.encode(arr, lanes=lanes)
    assert np.array_equal(entropy.decode(blob), arr)


def test_rans_streaming_matches_bulk():
    rng = np.random.default_rng(0)
    arr = np.minimum(rng.geometric(0.2, 10_000) - 1, 255).astype(np.uint8)
    blob = entropy.encode(arr)
    dec = entropy.StreamDecoder(blob)
    parts = [dec.read(n) for n in (1, 7, 100, 5000, 10_000)]
    assert np.array_equal(np.concatenate(parts), arr)


def test_rans_near_entropy():
    rng = np.random.default_rng(1)
    arr = np.minimum(rng.geometric(0.3, 200_000) - 1, 255).astype(np.uint8)
    blob = entropy.encode(arr)
    bound = entropy.entropy_bits(arr) / 8
    assert len(blob) < bound * 1.1 + 2048


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(2)
    kv = _kv_like(rng, 64, 3, 8, 32)
    q, scales = quantize(kv)
    deq = dequantize(q, scales)
    # max error <= scale/2 per (layer, head)
    err = np.abs(deq - kv)
    bound = scales[None, :, :, None] * 0.5 + 1e-6
    assert (err <= bound).all()
    # re-quantizing the dequantized tensor is a fixed point (bit-exact)
    q2, _ = quantize(deq)
    mism = (q2.astype(int) - q.astype(int))
    assert np.abs(mism).max() <= 1  # rint boundary wobble at most


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------

@given(st.sampled_from([(8, 32), (16, 64), (4, 16), (32, 128)]),
       st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_tile_roundtrip_property(hd, seed):
    H, D = hd
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, (5, 3, H, D)).astype(np.uint8)
    for lay in intra_candidates(H, D):
        t = tile_forward(x, lay)
        assert t.shape[-2:] == lay.tile
        back = tile_inverse(t, lay)
        assert np.array_equal(back, x)


@pytest.mark.parametrize("T,res", [(7, "240p"), (100, "240p"),
                                   (300, "480p"), (50, "1080p")])
def test_pack_unpack_roundtrip(T, res):
    H, D = 8, 32
    rng = np.random.default_rng(0)
    q = rng.integers(0, 256, (T, 3, H, D)).astype(np.uint8)
    lay = IntraLayout(H, D, 4, 2)
    geom = frame_geometry(T, lay, res)
    video = pack_frames(q, lay, geom)
    assert video.shape == (geom.n_frames,) + geom.frame_shape
    back = unpack_frames(video, lay, geom)
    assert np.array_equal(back, q)
    # frame-wise unpack covers every token exactly once
    seen = np.zeros(T, bool)
    for f in range(geom.n_frames):
        toks, qt = unpack_single_frame(video[f], lay, geom, f)
        assert not seen[toks].any()
        seen[toks] = True
        assert np.array_equal(qt, q[toks])
    assert seen.all()


def test_interframe_layout_adjacent_tokens_same_slot():
    """Tokens t, t+1 occupy the same pixel region in consecutive frames."""
    H, D = 32, 128
    T = 64
    lay = IntraLayout(H, D, 32, 1)  # tile (32, 128) -> 21 slots at 240p
    geom = frame_geometry(T, lay, "240p")
    F = geom.n_frames
    assert F >= 2
    q = np.zeros((T, 3, H, D), np.uint8)
    t0 = 5 * F  # slot 5, frame 0
    q[t0] = 200
    q[t0 + 1] = 201
    video = pack_frames(q, lay, geom)
    pos0 = np.argwhere(video[0] == 200)
    pos1 = np.argwhere(video[1] == 201)
    assert np.array_equal(pos0, pos1)


# ---------------------------------------------------------------------------
# prediction
# ---------------------------------------------------------------------------

@given(st.integers(0, 6))
@settings(max_examples=7, deadline=None)
def test_prediction_roundtrip(seed):
    rng = np.random.default_rng(seed)
    video = rng.integers(0, 256, (4, 16, 24, 3)).astype(np.uint8)
    # make some planes temporally similar to exercise mode decisions
    video[1] = video[0] + rng.integers(-2, 3, video[1].shape).astype(np.uint8)
    zres, modes = predict_encode(video)
    back = predict_decode(zres, modes)
    assert np.array_equal(back, video)


def test_prediction_picks_temporal_for_similar_frames():
    rng = np.random.default_rng(0)
    f0 = rng.integers(0, 256, (16, 24)).astype(np.uint8)
    video = np.stack([np.stack([f0 + np.uint8(i)] * 3, -1)
                      for i in range(4)])
    _, modes = predict_encode(video)
    assert (modes[1:] == 1).all()  # MODE_TEMPORAL


# ---------------------------------------------------------------------------
# codec end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("res", ["240p", "1080p"])
@pytest.mark.parametrize("nl", [1, 2, 3])
def test_codec_chunk_roundtrip_bit_exact(res, nl):
    rng = np.random.default_rng(3)
    H, D = 8, 32
    kv = _kv_like(rng, 96, nl, H, D)
    q, scales = quantize(kv)
    codec = KVCodec(H, D, IntraLayout(H, D, 4, 4))
    blob = codec.encode_chunk(q, res)
    back = codec.decode_chunk(blob)
    assert np.array_equal(back, q)  # lossless after quantization
    # frame-wise decode agrees token-by-token
    got = np.zeros_like(q)
    for toks, qt in codec.iter_decode_frames(blob):
        got[toks] = qt
    assert np.array_equal(got, q)


def test_codec_compresses_correlated_kv():
    rng = np.random.default_rng(4)
    H, D = 8, 64
    # strong token-adjacent correlation (the paper's SSIM-0.87 regime)
    noise = rng.standard_normal((1024, 3, H, D)).astype(np.float32)
    kv = np.empty_like(noise)
    kv[0] = noise[0]
    for t in range(1, kv.shape[0]):
        kv[t] = kv[t - 1] * 0.995 + 0.02 * noise[t]
    q, _ = quantize(kv * 3.0)
    codec = KVCodec(H, D)
    codec.search_layout(q[:256], "240p")
    blob = codec.encode_chunk(q, "240p")
    ratio = q.nbytes / len(blob)
    assert ratio > 2.5, ratio  # prediction+entropy must beat raw int8


def test_layout_search_beats_identity():
    rng = np.random.default_rng(5)
    H, D = 16, 64
    kv = _kv_like(rng, 128, 3, H, D)
    q, _ = quantize(kv)
    codec = KVCodec(H, D)
    log = []
    best = codec.search_layout(q, "1080p", log=log)
    costs = {(hr, dr): c for hr, dr, c in log}
    assert costs[(best.hr, best.dr)] == min(costs.values())
    assert len(log) == len(intra_candidates(H, D))
