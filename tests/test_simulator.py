"""Simulator behaviour tests: the paper's qualitative claims must hold in
the discrete-event harness (relative orderings, not absolute numbers)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adaptive import H20_TABLE
from repro.cluster.network import BandwidthTrace
from repro.cluster.simulator import (
    ServingSimulator, cachegen_spec, full_prefill_spec, kvfetcher_spec,
    llm265_spec, lmcache_raw_spec, raw_spec,
)
from repro.data.workload import fixed_context_trace, poisson_trace
from repro.serving.metrics import summarize

CFG = get_config("yi-34b")
RATIOS = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}


def _run(method, *, gbps=16.0, ctx=100_000, n=3, trace=None, **kw):
    bw = trace or BandwidthTrace.constant(gbps)
    sim = ServingSimulator(CFG, method, chip="h20", n_chips=2,
                           bandwidth=bw, table=H20_TABLE, **kw)
    reqs = fixed_context_trace(ctx, n_requests=n, gap=60.0)
    return sim.run(reqs, max_new_tokens=8)


def test_kvfetcher_beats_raw_and_full_prefill_on_slow_network():
    ours = _run(kvfetcher_spec(RATIOS), gbps=16)
    raw = _run(raw_spec(), gbps=16)
    full = _run(full_prefill_spec(), gbps=16)
    t_ours = summarize(ours.fetching())["ttft_mean"]
    t_raw = summarize(raw.fetching())["ttft_mean"]
    t_full = summarize(full.requests)["ttft_mean"]
    assert t_ours < t_raw < t_full
    # sanity: magnitudes in the paper's regime (seconds, not ms or hours)
    assert 0.05 < t_ours < t_full < 3600


def test_kvfetcher_beats_cachegen_at_low_bandwidth():
    ours = _run(kvfetcher_spec(RATIOS), gbps=8)
    cg = _run(cachegen_spec(ratio=3.5), gbps=8)
    assert summarize(ours.fetching())["ttft_mean"] < \
        summarize(cg.fetching())["ttft_mean"]


def test_blocking_fetch_is_worse_than_pipelined():
    ours = _run(kvfetcher_spec(RATIOS), gbps=8)
    lm = _run(lmcache_raw_spec(), gbps=8)
    assert summarize(ours.fetching())["ttft_mean"] < \
        summarize(lm.fetching())["ttft_mean"]


def test_nonreuse_requests_not_blocked_by_fetches():
    """Fig. 19: mixed workload; fetch-aware scheduling shields non-reuse
    requests from fetching requests (HOL blocking)."""
    rng = np.random.default_rng(0)
    reqs_a = poisson_trace(rng, n_requests=12, rate=0.5,
                           prompt_lens=(2_000, 90_000),
                           reuse_threshold=40_000)
    rng = np.random.default_rng(0)
    reqs_b = poisson_trace(rng, n_requests=12, rate=0.5,
                           prompt_lens=(2_000, 90_000),
                           reuse_threshold=40_000)
    bw = BandwidthTrace.constant(4.0)
    ours = ServingSimulator(CFG, kvfetcher_spec(RATIOS), bandwidth=bw,
                            table=H20_TABLE).run(reqs_a, max_new_tokens=8)
    cg = ServingSimulator(CFG, cachegen_spec(3.5), bandwidth=bw,
                          table=H20_TABLE).run(reqs_b, max_new_tokens=8)
    t_ours = summarize(ours.non_reuse())["ttft_mean"]
    t_cg = summarize(cg.non_reuse())["ttft_mean"]
    assert t_ours < t_cg


def test_adaptive_resolution_helps_under_jitter():
    """Fig. 23: adaptive resolution beats fixed 1080p under jitter."""
    rng = np.random.default_rng(1)
    trace = BandwidthTrace.steps(
        [(0, 6), (5, 3), (15, 4), (25, 2), (35, 6), (45, 3)])
    adaptive = _run(kvfetcher_spec(RATIOS), trace=trace, n=2)
    import dataclasses
    fixed = dataclasses.replace(kvfetcher_spec(RATIOS), adaptive=False,
                                fixed_resolution="1080p", name="fixed")
    fix = _run(fixed, trace=trace, n=2)
    assert summarize(adaptive.fetching())["ttft_mean"] <= \
        summarize(fix.fetching())["ttft_mean"] * 1.05


def test_framewise_restoration_memory():
    """Fig. 24: frame-wise buffer orders of magnitude below chunk-wise."""
    ours = _run(kvfetcher_spec(RATIOS), gbps=16, n=1)
    lm = _run(llm265_spec(5.0), gbps=16, n=1)
    assert ours.decompress_buffer_high_water < 100e6
    assert lm.decompress_buffer_high_water > \
        5 * ours.decompress_buffer_high_water


def test_decode_pool_utilized():
    ours = _run(kvfetcher_spec(RATIOS), gbps=16, n=2)
    assert 0.0 < ours.decode_pool_utilization <= 1.0


def test_ttft_grows_with_context():
    a = _run(kvfetcher_spec(RATIOS), ctx=50_000, n=2)
    b = _run(kvfetcher_spec(RATIOS), ctx=150_000, n=2)
    assert summarize(a.fetching())["ttft_mean"] < \
        summarize(b.fetching())["ttft_mean"]
