"""Per-architecture smoke tests: reduced variant of each assigned family,
one forward/train step on CPU, asserting output shapes and finiteness, plus
prefill->decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.models import transformer as tf

B, S = 2, 32


def _inputs(cfg, key):
    """(tokens, embeds, mask_positions) for the reduced config."""
    kt, ke, km = jax.random.split(key, 3)
    if cfg.frontend == "vision":
        n_text = S
        tokens = jax.random.randint(kt, (B, n_text), 0, cfg.vocab_size)
        embeds = jax.random.normal(ke, (B, cfg.num_patch_tokens, cfg.d_model),
                                   jnp.float32) * 0.02
        return tokens, embeds, None
    if cfg.frontend == "audio":
        embeds = jax.random.normal(ke, (B, S, cfg.d_model), jnp.float32) * .02
        mask = jax.random.bernoulli(km, 0.2, (B, S))
        return None, embeds, mask
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    return tokens, None, None


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    tokens, embeds, mask = _inputs(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(
        lambda p, t, e, m: tf.forward_full(p, cfg, tokens=t, embeds=e,
                                           mask_positions=m)
    )(params, tokens, embeds, mask)
    total_s = (0 if tokens is None else tokens.shape[1]) + \
              (0 if embeds is None else embeds.shape[1])
    assert logits.shape == (B, total_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).supports_decode])
def test_prefill_decode_matches_full(arch):
    """decode_step after prefill must reproduce the full-seq logits."""
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    embeds = None
    if cfg.frontend == "vision":
        embeds = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_patch_tokens, cfg.d_model),
            jnp.float32) * 0.02

    full_logits, _ = tf.forward_full(params, cfg, tokens=tokens,
                                     embeds=embeds)
    n_pre = S // 2
    total_pre = n_pre + (0 if embeds is None else embeds.shape[1])
    total = S + (0 if embeds is None else embeds.shape[1])

    cache = tf.init_cache(cfg, B, total)
    logits, cache = tf.prefill(params, cfg, tokens=tokens[:, :n_pre],
                               embeds=embeds, cache=cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, total_pre - 1]),
                               rtol=2e-4, atol=2e-4)
    step = jax.jit(lambda p, t, pos, c: tf.decode_step(p, cfg, t, pos, c))
    for i in range(n_pre, S):
        pos = i + (0 if embeds is None else embeds.shape[1])
        logits_i, cache = step(params, tokens[:, i], jnp.int32(pos), cache)
        np.testing.assert_allclose(np.asarray(logits_i),
                                   np.asarray(full_logits[:, pos]),
                                   rtol=2e-4, atol=2e-4)


def test_swa_matches_windowed_reference():
    """Sliding-window attention == full attention when window >= seq."""
    cfg = reduce_config(get_config("h2o-danube-3-4b"))
    import dataclasses
    cfg_big = dataclasses.replace(cfg, sliding_window=4 * S)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    a, _ = tf.forward_full(params, cfg_big, tokens=tokens)
    # window = 64 > S=32 so identical either way
    b_, _ = tf.forward_full(params, cfg, tokens=tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5,
                               atol=1e-5)
