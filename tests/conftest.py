"""Shared fixtures for the tier-1 suite.

The tiny-model/params/store setup used to be copy-pasted across
test_live_engine.py and test_system.py (and would have been pasted a
third time for the fetch-controller suite); it lives here once now.
Model fixtures are session-scoped: `tf.init_params` and donor prefills
dominate suite runtime, so every engine test shares one tiny model.
"""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_cfg():
    """Reduced dense GQA config (the paper's model class)."""
    from repro.configs import get_config, reduce_config
    return reduce_config(get_config("lwm-7b"))


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    import jax
    from repro.models import transformer as tf
    return tf.init_params(tiny_cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def donor_kv(tiny_cfg, tiny_params):
    """Factory: run the donor prefill, return [T, L, K, hd] K and V."""
    from repro.serving import paged_model

    def _donor(tokens):
        return paged_model.donor_prefix_kv(tiny_params, tiny_cfg, tokens)

    return _donor


@pytest.fixture
def registered_store(donor_kv):
    """Factory: KVStore with one registered prefix; returns (store, key)."""
    from repro.cluster.storage import KVStore
    from repro.core.chunks import prefix_key

    def _make(prefix_tokens, *, tokens_per_chunk=16,
              resolutions=("240p",)):
        kv_k, kv_v = donor_kv(prefix_tokens)
        store = KVStore()
        store.register_prefix(prefix_tokens, kv_k, kv_v,
                              tokens_per_chunk=tokens_per_chunk,
                              resolutions=resolutions)
        return store, prefix_key(prefix_tokens)

    return _make


@pytest.fixture(scope="session")
def synthetic_kv():
    """Factory: random [T, L, H, D] KV pair + token ids (no model)."""

    def _make(T, L, H, D, seed=0):
        rng = np.random.default_rng(seed)
        kv_k = rng.standard_normal((T, L, H, D)).astype(np.float32)
        kv_v = rng.standard_normal((T, L, H, D)).astype(np.float32)
        toks = rng.integers(0, 1000, T)
        return kv_k, kv_v, toks

    return _make
