"""Event-driven fetch controller: pipeline invariants at the controller
level (pure virtual clock, synthetic plans) plus live-engine integration
of the async path (real model + codec on a virtual clock).

Covers the ISSUE-1 acceptance surface:
  * per-chunk stage ordering transmit <= decode <= restore,
  * layer groups become ready front-to-back,
  * Appx A.3 early admission never stalls compute,
  * async and sync engines emit identical tokens, async TTFT < sync,
  * the fetch_agnostic HOL-blocking baseline is unchanged.
"""
import numpy as np
import pytest

from repro.core.adaptive import GBPS, H20_TABLE, DecodeTable
from repro.core.fetch import synthetic_plan
from repro.core.fetch_controller import (FetchController, FetchHooks,
                                         PipelineConfig)
from repro.core.scheduler import FetchingAwareScheduler, ReqState, Request
from repro.cluster.decodepool import DecodePool
from repro.cluster.network import BandwidthTrace

RES = ("240p", "480p", "640p", "1080p")


class _RecSched(FetchingAwareScheduler):
    """Scheduler recording the first early-admission timestamp."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.t_early = None

    def notify_early_admissible(self, req, now):
        if self.t_early is None:
            self.t_early = now
        super().notify_early_admissible(req, now)


class _Hooks(FetchHooks):
    def __init__(self, nbytes=50e6, comp=None, sized=False):
        self.nbytes = nbytes
        self.comp = comp
        self.sized = sized

    def chunk_bytes(self, fetch, pc, res):
        if self.sized:  # encoded size scales with resolution
            return H20_TABLE.chunk_size_mb[res] * 1e6 * 0.5
        return self.nbytes

    def restore_seconds(self, fetch, pc):
        return 0.002

    def comp_times(self, req):
        return self.comp


def _drive(policy="kvfetcher", *, pipelined=True, adaptive=False,
           comp=None, gbps=1.0, nbytes=50e6, reuse=30_000, n_layers=9,
           sized=False):
    """Submit one fetching request and run its pipeline to completion."""
    sched = _RecSched(policy, max_running=4)
    req = Request(rid=0, arrival=0.0, prompt_len=reuse + 2_000,
                  reuse_tokens=reuse, prefix="p")
    sched.submit(req, 0.0)
    sched.schedule(0.0)
    (fetch_req,) = sched.take_fetches()
    plan = synthetic_plan(0, reuse, n_layers, 10_000)
    ctrl = FetchController(
        sched, BandwidthTrace.constant(gbps),
        table=H20_TABLE, pool=DecodePool(H20_TABLE),
        config=PipelineConfig(adaptive=adaptive,
                              fixed_resolution="1080p",
                              pipelined=pipelined,
                              layerwise_admission=comp is not None,
                              resolutions=RES),
        hooks=_Hooks(nbytes, comp, sized))
    ctrl.start(fetch_req, plan, 0.0)
    ctrl.pump(float("inf"))
    return sched, req, plan, ctrl


# ---------------------------------------------------------------------------
# controller-level invariants
# ---------------------------------------------------------------------------

def test_event_ordering_invariants():
    sched, req, plan, ctrl = _drive()
    assert plan.done and req.fetch_done is not None
    for pc in plan.chunks:
        assert pc.t_transmit_start is not None
        assert pc.t_transmit_start <= pc.t_transmit_done
        assert pc.t_transmit_done <= pc.t_decode_done
        assert pc.t_decode_done <= pc.t_restored
    # the network pipe carries one chunk at a time
    by_start = sorted(plan.chunks, key=lambda pc: pc.t_transmit_start)
    for a, b in zip(by_start, by_start[1:]):
        assert b.t_transmit_start >= a.t_transmit_done - 1e-9
    # layer groups become fully restored front-to-back
    gdone = {}
    for pc in plan.chunks:
        gdone[pc.ref.group] = max(gdone.get(pc.ref.group, 0.0),
                                  pc.t_restored)
    gs = sorted(gdone)
    for g1, g2 in zip(gs, gs[1:]):
        assert gdone[g1] <= gdone[g2] + 1e-9
    assert req.layers_ready == plan.n_layers_total == 9


def test_pipelined_beats_serialized():
    """Stage overlap (paper §3.3) vs the chunk-serial sync baseline."""
    *_, plan_p, _ = _drive(pipelined=True)
    *_, plan_s, _ = _drive(pipelined=False)
    done_p = max(pc.t_restored for pc in plan_p.chunks)
    done_s = max(pc.t_restored for pc in plan_s.chunks)
    assert done_p < done_s


def test_early_admission_never_stalls_compute():
    """When the Appx A.3 condition admits early, every layer's KV is
    restored no later than that layer's compute could start."""
    comp = [10.0] * 9
    sched, req, plan, ctrl = _drive(comp=comp)
    assert req.early_admitted
    t0 = sched.t_early
    assert t0 is not None and t0 < req.fetch_done
    gdone = {}
    for pc in plan.chunks:
        gdone[pc.ref.group] = max(gdone.get(pc.ref.group, 0.0),
                                  pc.t_restored)
    layer_group = {}
    for pc in plan.chunks:
        for lay in pc.ref.layers:
            layer_group[lay] = pc.ref.group
    cum = 0.0
    for layer in range(plan.n_layers_total):
        ready = gdone[layer_group[layer]]
        assert ready <= t0 + cum + 1e-9, \
            f"layer {layer} KV late: ready={ready} start={t0 + cum}"
        cum += comp[layer]


def test_no_early_admission_when_decode_too_slow():
    """Tight compute budget: the condition must refuse early admission
    (the request is only readmitted by fetch completion)."""
    sched, req, plan, ctrl = _drive(comp=[1e-4] * 9)
    assert not req.early_admitted
    assert req.fetch_done is not None


def test_adaptive_resolution_reacts_to_bandwidth():
    def chosen(gbps):
        *_, plan, _ = _drive(adaptive=True, sized=True, gbps=gbps)
        res = [pc.resolution for pc in plan.chunks]
        return max(set(res), key=res.count)

    slow, fast = chosen(1.0), chosen(40.0)
    assert RES.index(slow) <= RES.index(fast)
    assert slow == "240p"


def test_fetch_agnostic_hol_baseline_unchanged():
    """The HOL-blocking baseline must survive the controller refactor:
    a plain request behind a fetching head waits for the whole fetch."""
    for policy in ("fetch_agnostic", "kvfetcher"):
        sched = FetchingAwareScheduler(policy, max_running=4)
        a = Request(rid=0, arrival=0.0, prompt_len=22_000,
                    reuse_tokens=20_000, prefix="p")
        b = Request(rid=1, arrival=0.0, prompt_len=1_000)
        sched.submit(a, 0.0)
        sched.submit(b, 0.0)
        admitted0 = sched.schedule(0.0)
        (fetch_req,) = sched.take_fetches()
        ctrl = FetchController(
            sched, BandwidthTrace.constant(1.0),
            table=H20_TABLE, pool=DecodePool(H20_TABLE),
            config=PipelineConfig(adaptive=False,
                                  fixed_resolution="1080p",
                                  layerwise_admission=False),
            hooks=_Hooks())
        ctrl.start(fetch_req, synthetic_plan(0, 20_000, 9, 10_000), 0.0)
        if policy == "fetch_agnostic":
            assert admitted0 == []  # head blocks everyone
            ctrl.pump(float("inf"))
            admitted = sched.schedule(ctrl.now)
            assert {r.rid for r in admitted} == {0, 1}
            assert b.t_admitted >= a.fetch_done
        else:
            assert [r.rid for r in admitted0] == [1]  # b runs immediately
            assert a.state is ReqState.WAITING_FOR_KV


# ---------------------------------------------------------------------------
# live-engine integration (virtual clock, real model + codec)
# ---------------------------------------------------------------------------

def _live_net(latency=0.04):
    table = DecodeTable(
        name="live-test", n_decoders=2,
        latency={r: (latency, latency * 1.25) for r in RES},
        penalty={"240p": 0.01, "480p": 0.008, "640p": 0.004, "1080p": 0.0},
        chunk_size_mb={r: 0.004 for r in RES})
    return table, BandwidthTrace.constant(0.0006)  # ~75 kB/s


@pytest.mark.slow
def test_async_engine_matches_sync_and_is_faster(tiny_cfg, tiny_params,
                                                 registered_store):
    from repro.serving.engine import LiveEngine

    CFG, PARAMS = tiny_cfg, tiny_params
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, CFG.vocab_size, 48)
    full = np.concatenate([prefix, rng.integers(0, CFG.vocab_size, 8)])
    plain = rng.integers(0, CFG.vocab_size, 12)
    store, key = registered_store(prefix,
                                  resolutions=("240p", "480p", "1080p"))
    table, bw = _live_net()
    results = {}
    for mode in ("async", "sync"):
        eng = LiveEngine(PARAMS, CFG, store, policy="kvfetcher",
                         fetch_mode=mode, bandwidth=bw, decode_table=table)
        r_fetch = eng.submit(full, reuse_prefix=key, reuse_tokens=48,
                             max_new_tokens=3)
        r_plain = eng.submit(plain, max_new_tokens=3)
        eng.run()
        assert eng.stats.restored_tokens == 48 * 2  # k and v restored
        results[mode] = (r_fetch, r_plain,
                         eng.outputs[r_fetch.rid], eng.outputs[r_plain.rid])
    fa, pa, out_fa, out_pa = results["async"]
    fs, ps, out_fs, out_ps = results["sync"]
    # identical generations (lossless at the system level)
    assert out_fa == out_fs
    assert out_pa == out_ps
    # pipelining wins TTFT under a bandwidth-limited trace
    assert fa.ttft < fs.ttft
    # fetch-aware async engine never blocks the plain request
    assert pa.ttft < 0.1 * fa.ttft


@pytest.mark.slow
def test_engine_early_admission_no_stall():
    """Multi-group tiny model with huge modeled compute: early admission
    fires (Appx A.3) and suffix prefill never waits for KV."""
    import jax
    from repro.configs import get_config, reduce_config
    from repro.cluster.costmodel import CHIPS, EngineCostModel
    from repro.cluster.storage import KVStore
    from repro.core.chunks import prefix_key
    from repro.models import transformer as tf
    from repro.serving import paged_model
    from repro.serving.engine import LiveEngine

    cfg = reduce_config(get_config("lwm-7b"), num_layers=6)  # 2 groups
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    prefix = rng.integers(0, cfg.vocab_size, 64)
    full = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, 6)])
    kv_k, kv_v = paged_model.donor_prefix_kv(params, cfg, prefix)
    store = KVStore()
    key = prefix_key(prefix)
    store.register_prefix(prefix, kv_k, kv_v, tokens_per_chunk=16,
                          resolutions=("240p",))
    table, bw = _live_net(latency=0.001)
    # absurdly low MFU -> per-layer compute dwarfs decode -> admit early
    slow_cost = EngineCostModel(cfg, CHIPS["h20"], 1, mfu=1e-12)
    eng = LiveEngine(params, cfg, store, policy="kvfetcher",
                     fetch_mode="async", bandwidth=bw, decode_table=table,
                     cost=slow_cost)
    req = eng.submit(full, reuse_prefix=key, reuse_tokens=64,
                     max_new_tokens=2)
    eng.run()
    assert req.early_admitted
    assert eng.stats.prefill_stall_time == 0.0
    # lossless: same generations as a no-reuse engine on the same model
    ref = LiveEngine(params, cfg, KVStore())
    rr = ref.submit(full, max_new_tokens=2)
    ref.run()
    assert eng.outputs[req.rid] == ref.outputs[rr.rid]
