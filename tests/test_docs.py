"""Docs cannot rot silently: the commands and links quoted in README.md
and docs/*.md are smoke-checked by tools/check_docs.py; this wrapper
makes the check part of tier-1 (CI additionally runs it as a dedicated
job so a docs regression is visible as its own failure)."""
import pathlib
import sys


def test_documented_commands_smoke():
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_docs
        assert check_docs.main() == 0
    finally:
        sys.path.pop(0)


def test_docs_exist_and_are_linked():
    root = pathlib.Path(__file__).resolve().parents[1]
    readme = (root / "README.md").read_text()
    assert "docs/fetch_pipeline.md" in readme
    assert (root / "docs" / "fetch_pipeline.md").exists()
    # ROADMAP points at the pipeline doc too (tentpole satellite)
    assert "docs/fetch_pipeline.md" in (root / "ROADMAP.md").read_text()
