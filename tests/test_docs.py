"""Docs cannot rot silently: the commands and links quoted in README.md
and docs/*.md are smoke-checked by tools/check_docs.py; this wrapper
makes the check part of tier-1 (CI additionally runs it as a dedicated
job so a docs regression is visible as its own failure)."""
import pathlib
import sys


def test_documented_commands_smoke():
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_docs
        assert check_docs.main() == 0
    finally:
        sys.path.pop(0)


def test_docs_exist_and_are_linked():
    root = pathlib.Path(__file__).resolve().parents[1]
    readme = (root / "README.md").read_text()
    assert "docs/fetch_pipeline.md" in readme
    assert (root / "docs" / "fetch_pipeline.md").exists()
    # ROADMAP points at the pipeline doc too (tentpole satellite)
    assert "docs/fetch_pipeline.md" in (root / "ROADMAP.md").read_text()
    # storage tier doc: in the README architecture map and
    # cross-referenced with the pipeline doc (so they cannot drift)
    assert "docs/storage_tier.md" in readme
    assert (root / "docs" / "storage_tier.md").exists()
    assert "storage_tier.md" in \
        (root / "docs" / "fetch_pipeline.md").read_text()
    assert "fetch_pipeline.md" in \
        (root / "docs" / "storage_tier.md").read_text()


def test_checker_fails_on_broken_relative_link(tmp_path, monkeypatch):
    """A doc pointing at a moved/deleted file must fail the docs job —
    not just be skipped (ISSUE 4: only the happy path was asserted)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_docs
        (tmp_path / "docs").mkdir()
        (tmp_path / "README.md").write_text(
            "see [the guide](docs/real.md)\n"
            "```bash\npython -m pytest -q\n```\n")
        (tmp_path / "docs" / "real.md").write_text(
            "[gone](missing_file.md)\n")
        monkeypatch.setattr(check_docs, "ROOT", tmp_path)
        assert check_docs.main() == 1
        assert check_docs.check_links(tmp_path / "docs" / "real.md") == [
            "docs/real.md: broken link -> missing_file.md"]
    finally:
        sys.path.pop(0)


def test_checker_fails_on_command_that_exits_nonzero(tmp_path,
                                                     monkeypatch):
    """A documented command that errors out (e.g. a module that no
    longer exists) must fail the docs job."""
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_docs
        (tmp_path / "README.md").write_text(
            "```bash\npython -m repro.no_such_module_xyz --flag\n```\n")
        monkeypatch.setattr(check_docs, "ROOT", tmp_path)
        assert check_docs.main() == 1
        ok, detail = check_docs.check_command(
            "python -m repro.no_such_module_xyz --flag")
        assert not ok and detail
    finally:
        sys.path.pop(0)


def test_checker_fails_on_unknown_command_shape(tmp_path, monkeypatch):
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_docs
        (tmp_path / "README.md").write_text(
            "```bash\ncurl https://example.com | sh\n```\n")
        monkeypatch.setattr(check_docs, "ROOT", tmp_path)
        assert check_docs.main() == 1
    finally:
        sys.path.pop(0)


def test_checker_fails_on_orphaned_doc(tmp_path, monkeypatch):
    """A docs/*.md not link-reachable from README.md is invisible to
    readers and must fail the docs job (ISSUE 9)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_docs
        (tmp_path / "docs").mkdir()
        (tmp_path / "README.md").write_text(
            "see [the guide](docs/linked.md)\n"
            "```bash\npython -m pytest -q\n```\n")
        (tmp_path / "docs" / "linked.md").write_text("# linked\n")
        (tmp_path / "docs" / "orphan.md").write_text("# nobody links me\n")
        monkeypatch.setattr(check_docs, "ROOT", tmp_path)
        assert check_docs.main() == 1
        assert check_docs.check_orphans() == [
            "orphaned doc (not linked from README.md): docs/orphan.md"]
        # transitively linked docs (README -> linked -> deep) are fine
        (tmp_path / "docs" / "linked.md").write_text(
            "[deep](orphan.md)\n")
        assert check_docs.check_orphans() == []
    finally:
        sys.path.pop(0)


def test_checker_fails_on_doc_referencing_deleted_source(tmp_path,
                                                         monkeypatch):
    """Prose mentioning a repo path that no longer exists must fail —
    module tables rot exactly this way (ISSUE 9)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_docs
        (tmp_path / "src").mkdir()
        (tmp_path / "src" / "real.py").write_text("x = 1\n")
        (tmp_path / "README.md").write_text(
            "`src/real.py` is real but `src/deleted_module.py` is gone\n"
            "```bash\npython -m pytest -q\n```\n")
        monkeypatch.setattr(check_docs, "ROOT", tmp_path)
        assert check_docs.main() == 1
        assert check_docs.check_source_paths(tmp_path / "README.md") == [
            "README.md: references deleted path -> src/deleted_module.py"]
    finally:
        sys.path.pop(0)


def test_checker_scans_docs_subdirectories(tmp_path, monkeypatch):
    """Docs added under docs/<subdir>/ must be scanned, not silently
    skipped (regression: the old glob was a flat docs/*.md)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "tools"))
    try:
        import check_docs
        (tmp_path / "docs" / "ops").mkdir(parents=True)
        (tmp_path / "README.md").write_text("# readme\n")
        (tmp_path / "docs" / "top.md").write_text("# top\n")
        (tmp_path / "docs" / "ops" / "nested.md").write_text("# nested\n")
        monkeypatch.setattr(check_docs, "ROOT", tmp_path)
        names = [p.relative_to(tmp_path).as_posix()
                 for p in check_docs.doc_files()]
        assert names == ["README.md", "docs/ops/nested.md", "docs/top.md"]
    finally:
        sys.path.pop(0)
