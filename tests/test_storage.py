"""Multi-node prefix storage tier (ISSUE 3 acceptance surface).

Node-level tests cover byte-accurate capacity accounting and the three
eviction policies; cluster-level tests cover consistent-hash placement,
popularity replication, longest-prefix-match full/partial/miss
resolution, and determinism of the event log under a seeded Zipf
workload.  Integration tests drive the analytic simulator and the REAL
live engine and assert (a) a partial hit produces tokens identical to a
full recompute and (b) both environments replay the identical
hit/miss/evict event sequence for the same access order.
"""
import numpy as np
import pytest

from repro.core.scheduler import FetchingAwareScheduler, ReqState, Request
from repro.cluster.network import BandwidthTrace
from repro.cluster.storage import (KVStore, StorageCluster, StorageNode,
                                   StoredPrefix, synthetic_stored_prefix)
from repro.data.workload import prefix_trie_specs, zipf_prefix_trace

MB = 1_000_000


def _entry(key, n_tokens=1000, size=10 * MB, parent=None):
    return StoredPrefix(key=key, n_tokens=n_tokens,
                        bytes_by_resolution={"240p": size},
                        raw_kv_bytes=8 * size, parent=parent)


# ---------------------------------------------------------------------------
# StorageNode: capacity accounting + eviction policies
# ---------------------------------------------------------------------------

def test_node_capacity_accounting_per_resolution():
    n = StorageNode("n0", capacity_bytes=100 * MB)
    e = StoredPrefix("a", 100, {"240p": 10 * MB, "1080p": 30 * MB})
    assert n.put(e, 0.0) == (True, [])
    assert n.used_bytes == 40 * MB
    assert n.bytes_by_resolution == {"240p": 10 * MB, "1080p": 30 * MB}
    assert n.stored_bytes() == 40 * MB
    # eviction returns the bytes
    big = StoredPrefix("b", 100, {"240p": 70 * MB})
    ok, evicted = n.put(big, 1.0)
    assert ok and evicted == ["a"]
    assert n.used_bytes == 70 * MB
    assert n.bytes_by_resolution["1080p"] == 0


def test_node_rejects_entry_larger_than_capacity():
    n = StorageNode("n0", capacity_bytes=10 * MB)
    n.put(_entry("a", size=8 * MB), 0.0)
    ok, evicted = n.put(_entry("huge", size=20 * MB), 1.0)
    assert not ok and evicted == []  # never flushes the node for a lost cause
    assert n.contains("a") and n.stats.rejections == 1


def test_node_lru_evicts_least_recently_used():
    n = StorageNode("n0", capacity_bytes=30 * MB, policy="lru")
    for i, k in enumerate(("a", "b", "c")):
        n.put(_entry(k), float(i))
    n.get("a", 10.0)  # refresh a
    _, evicted = n.put(_entry("d"), 11.0)
    assert evicted == ["b"]  # oldest untouched


def test_node_lfu_keeps_frequent():
    n = StorageNode("n0", capacity_bytes=30 * MB, policy="lfu")
    for i, k in enumerate(("a", "b", "c")):
        n.put(_entry(k), float(i))
    for t in range(3):
        n.get("a", 10.0 + t)
    n.get("c", 20.0)  # recent but infrequent
    _, evicted = n.put(_entry("d"), 21.0)
    assert evicted == ["b"]  # 0 hits loses to recency


def test_node_cost_keeps_bytes_saved_per_byte_stored():
    """A proven-hot prefix survives a scan that flushes an LRU node."""
    seq = [("hot", 0.0)] + [(f"scan{i}", float(i + 1)) for i in range(3)]
    results = {}
    for policy in ("lru", "cost"):
        n = StorageNode("n0", capacity_bytes=30 * MB, policy=policy)
        n.put(_entry("hot"), 0.0)
        n.get("hot", 0.5)  # one hit: it has earned bytes-saved credit
        for key, t in seq[1:]:
            n.put(_entry(key), t)
        results[policy] = n.contains("hot")
    assert results["cost"] and not results["lru"]


def test_node_cost_prefers_small_high_value_entries():
    n = StorageNode("n0", capacity_bytes=30 * MB, policy="cost")
    small = StoredPrefix("small", 100, {"240p": 5 * MB},
                         raw_kv_bytes=50 * MB)
    big = StoredPrefix("big", 100, {"240p": 25 * MB}, raw_kv_bytes=50 * MB)
    n.put(small, 0.0)
    n.put(big, 1.0)
    n.get("small", 2.0)
    n.get("big", 3.0)  # equal hits; big saves fewer bytes per byte stored
    _, evicted = n.put(_entry("new", size=10 * MB), 4.0)
    assert evicted == ["big"]


def test_node_reregister_replaces_stale_entry():
    """Re-registering a resident key must swap in the new artifact and
    re-account its bytes (regression: the flat dict overwrote)."""
    n = StorageNode("n0", capacity_bytes=100 * MB)
    n.put(_entry("a", size=10 * MB), 0.0)
    n.get("a", 1.0)
    v2 = StoredPrefix("a", 1000, {"240p": 10 * MB, "480p": 15 * MB})
    ok, evicted = n.put(v2, 2.0)
    assert ok and not evicted
    assert n.residents["a"].entry is v2
    assert n.residents["a"].hits == 1  # same prefix: history kept
    assert n.used_bytes == 25 * MB
    assert n.bytes_by_resolution == {"240p": 10 * MB, "480p": 15 * MB}
    assert n.stats.admissions == 1  # replacement, not a new admission


def test_node_repr_is_human_readable():
    n = StorageNode("n0", capacity_bytes=2e9, policy="cost")
    n.put(_entry("a", size=500 * MB), 0.0)
    r = repr(n)
    assert "0.50/2.00 GB" in r and "cost" in r and "1 prefixes" in r
    assert "unbounded" in repr(StorageNode("n1"))


# ---------------------------------------------------------------------------
# StorageCluster: placement, replication, LPM lookup, determinism
# ---------------------------------------------------------------------------

def _cluster(n_nodes=3, cap=35 * MB, policy="lru", **kw):
    nodes = [StorageNode(f"n{i}", capacity_bytes=cap, policy=policy)
             for i in range(n_nodes)]
    return StorageCluster(nodes, **kw)


def test_consistent_hash_placement_deterministic_and_spread():
    keys = [f"k{i}" for i in range(60)]
    c1, c2 = _cluster(cap=None), _cluster(cap=None)
    assert [c1.primary_node(k).node_id for k in keys] == \
        [c2.primary_node(k).node_id for k in keys]
    used = {c1.primary_node(k).node_id for k in keys}
    assert used == {"n0", "n1", "n2"}  # all nodes take keys


def test_lookup_full_partial_miss_and_ancestor_chain():
    c = _cluster(n_nodes=1, cap=25 * MB)
    c.register(_entry("root", n_tokens=400, size=10 * MB), 0.0)
    c.register(_entry("child", n_tokens=600, size=10 * MB,
                      parent="root"), 1.0)
    full = c.lookup("child", 2.0)
    assert full.kind == "full" and full.covered_tokens == 600
    assert full.node.node_id == "n0"
    # make child the LRU victim, then squeeze it out
    c.lookup("root", 2.5)
    c.register(_entry("x", n_tokens=100, size=10 * MB), 3.0)
    assert not c.nodes[0].contains("child")
    assert c.nodes[0].contains("root")
    partial = c.lookup("child", 5.0)
    assert partial.kind == "partial"
    assert partial.entry.key == "root" and partial.covered_tokens == 400
    assert partial.requested_tokens == 600
    miss = c.lookup("never-registered", 6.0)
    assert miss.kind == "miss" and miss.entry is None


def test_write_on_miss_is_delayed_until_recompute_done():
    """A miss must NOT re-admit at lookup time — the recomputed KV only
    exists once the fallback prefill finishes (notify_recompute_done)."""
    c = _cluster(n_nodes=1, cap=25 * MB)
    c.register(_entry("a", size=10 * MB), 0.0)
    c.register(_entry("b", size=10 * MB), 1.0)
    c.register(_entry("c", size=10 * MB), 2.0)  # evicts a (lru)
    assert not c.nodes[0].contains("a")
    hit = c.lookup("a", 3.0)
    assert hit.kind == "miss" and hit.missed_key == "a"
    assert not c.nodes[0].contains("a")  # not yet: recompute in flight
    c.notify_recompute_done("a", 5.0)
    assert c.nodes[0].contains("a")  # pull-through re-admission
    assert c.lookup("a", 6.0).kind == "full"
    # idempotent: a second notify without a pending miss is a no-op
    n_events = len(c.events)
    c.notify_recompute_done("a", 7.0)
    assert len(c.events) == n_events


def test_popularity_replication_spreads_hot_prefixes():
    c = _cluster(cap=None, placement="popular", replicate_threshold=2)
    c.register(_entry("hot"), 0.0)
    c.register(_entry("cold"), 0.0)
    for t in range(3):
        assert c.lookup("hot", 1.0 + t).kind == "full"
    holders = [n.node_id for n in c.nodes if n.contains("hot")]
    assert len(holders) >= 2
    assert ("replicate", "hot", holders[-1]) in c.events or \
        any(ev[0] == "replicate" and ev[1] == "hot" for ev in c.events)
    assert sum(1 for n in c.nodes if n.contains("cold")) == 1


def test_lookup_tokens_longest_prefix_match():
    c = _cluster(cap=None)
    toks = np.arange(64)
    root = StoredPrefix("root", 32, {"240p": MB},
                        token_ids=toks[:32])
    child = StoredPrefix("child", 48, {"240p": MB}, parent="root",
                         token_ids=toks[:48])
    c.register(root, 0.0)
    c.register(child, 0.0)
    full = c.lookup_tokens(toks[:48], 1.0)
    assert full.kind == "full" and full.entry.key == "child"
    # longer ask than any stored prefix: partial on the deepest ancestor
    part = c.lookup_tokens(toks[:64], 2.0)
    assert part.kind == "partial" and part.entry.key == "child"
    assert part.covered_tokens == 48 and part.requested_tokens == 64
    # diverging tokens match nothing
    other = np.arange(100, 140)
    assert c.lookup_tokens(other, 3.0).kind == "miss"


def test_cluster_event_log_deterministic_under_seeded_zipf():
    """Same seed, same sizes -> byte-identical event logs, with real
    eviction pressure (the determinism the cross-env test relies on)."""
    specs = prefix_trie_specs(3, 2, base_tokens=400, ext_tokens=200)

    def run_once():
        c = _cluster(n_nodes=2, cap=25 * MB, policy="cost")
        for s in specs:
            c.register(_entry(s.key, n_tokens=s.n_tokens, size=10 * MB,
                              parent=s.parent), 0.0)
        rng = np.random.default_rng(42)
        reqs = zipf_prefix_trace(rng, specs, n_requests=30, alpha=1.2,
                                 gap=1.0)
        for r in reqs:
            c.lookup(r.prefix, r.arrival + 1.0,
                     requested_tokens=r.reuse_tokens)
        return list(c.events)

    e1, e2 = run_once(), run_once()
    assert e1 == e2
    assert any(ev[0] == "evict" for ev in e1), "no capacity pressure"
    assert any(ev[0] in ("full", "partial") for ev in e1)


def test_kvstore_facade_keeps_flat_api(synthetic_kv):
    kv_k, kv_v, toks = synthetic_kv(8, 3, 2, 4)
    store = KVStore()
    man = store.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=4,
                                resolutions=("240p",))
    assert store.lookup(man.prefix) is man
    assert store.lookup("nope") is None
    ref = man.refs[0]
    assert store.get_chunk(man.prefix, ref.chunk_id, "240p") == \
        man.blobs[(ref.chunk_id, "240p")]
    assert store.stored_bytes() == sum(len(b) for b in man.blobs.values())


# ---------------------------------------------------------------------------
# fault tolerance: fail/recover, ring heal, TTL/pinning, admission (ISSUE 4)
# ---------------------------------------------------------------------------

def test_node_fail_loses_residents_and_recover_rejoins_empty():
    n = StorageNode("n0", capacity_bytes=100 * MB)
    n.put(_entry("a"), 0.0)
    n.put(_entry("b"), 1.0)
    lost = n.fail()
    assert lost == ["a", "b"] and not n.alive
    assert n.used_bytes == 0 and not n.residents
    assert n.stats.failures == 1
    assert "FAILED" in repr(n)
    n.recover()
    assert n.alive and not n.residents
    ok, _ = n.put(_entry("c"), 2.0)
    assert ok


def test_failed_node_leaves_the_ring():
    c = _cluster(cap=None)
    keys = [f"k{i}" for i in range(40)]
    n0_keys = [k for k in keys if c.primary_node(k).node_id == "n0"]
    assert n0_keys
    c.fail_node("n0", 0.0)
    assert ("fail", "", "n0") in c.events
    for k in n0_keys:  # keys re-route to their ring successors
        assert c.primary_node(k).node_id != "n0"
    c.recover_node("n0", 1.0)
    assert ("recover", "", "n0") in c.events
    assert c.primary_node(n0_keys[0]).node_id == "n0"


def test_ring_heal_restores_replication_from_surviving_replica():
    c = _cluster(cap=None, replication=2)
    c.register(_entry("k"), 0.0)
    holders = [n.node_id for n in c.nodes if n.contains("k")]
    assert len(holders) == 2  # replication=2 at registration
    c.fail_node(holders[0], 1.0)
    # sync heal: a new second replica appears immediately, sourced from
    # the survivor (the catalog is never needed while a replica lives)
    now_holders = [n.node_id for n in c.nodes if n.contains("k")]
    assert len(now_holders) == 2 and holders[0] not in now_holders
    assert ("heal", "k", [h for h in now_holders
                          if h != holders[1]][0]) in c.events
    assert c.lookup("k", 2.0).kind == "full"
    assert c.heals_completed == 1


def test_ring_heal_reseeds_unreplicated_key_from_catalog():
    c = _cluster(cap=None, replication=1)
    c.register(_entry("k"), 0.0)
    holder = next(n.node_id for n in c.nodes if n.contains("k"))
    c.fail_node(holder, 1.0)
    assert sum(1 for n in c.nodes if n.contains("k")) == 1
    assert any(e[0] == "heal" and e[1] == "k" for e in c.events)
    assert c.lookup("k", 2.0).kind == "full"


def test_fail_node_does_not_count_expired_copies_as_survivors():
    """A TTL-stale replica is not a heal source: failing one holder of
    a fully-expired pair must re-seed from the catalog (and log the
    expiry), not under-replicate against a ghost copy."""
    c = _cluster(cap=None, replication=2)
    c.register(StoredPrefix("k", 1000, {"240p": MB}, raw_kv_bytes=8 * MB,
                            ttl=5.0), 0.0)
    holders = [n.node_id for n in c.nodes if n.contains("k")]
    assert len(holders) == 2
    c.fail_node(holders[0], 100.0)  # both copies are long expired
    assert any(e == ("expire", "k", holders[1]) for e in c.events)
    live = [n.node_id for n in c.nodes if n.contains("k")]
    assert len(live) == 2 and holders[0] not in live  # fully re-seeded
    assert c.lookup("k", 101.0).kind == "full"


def test_rejected_heal_is_not_counted_completed():
    """A heal whose target cannot take the entry (pinned-full node)
    logs a reject and must NOT bump heals_completed — the replication
    factor was not restored."""
    c = _cluster(n_nodes=2, cap=15 * MB, replication=1)
    c.register(_entry("k"), 0.0)
    holder = next(n for n in c.nodes if n.contains("k"))
    other = next(n for n in c.nodes if n is not holder)
    other.put(StoredPrefix("pin", 100, {"240p": 10 * MB}, pinned=True),
              0.5)
    c.fail_node(holder.node_id, 1.0)
    assert c.heals_completed == 0
    assert ("reject", "k", other.node_id) in c.events
    assert not other.contains("k")


def test_manual_heal_queues_until_pumped():
    c = _cluster(cap=None, replication=1, heal="manual")
    c.register(_entry("k"), 0.0)
    holder = next(n.node_id for n in c.nodes if n.contains("k"))
    c.fail_node(holder, 1.0)
    assert not any(n.contains("k") for n in c.nodes)
    assert c.lookup("k", 2.0).kind == "miss"  # down until pumped
    assert c.pump_heal(3.0) == 1
    assert c.lookup("k", 4.0).kind == "full"


def test_ttl_expires_lazily_at_lookup():
    c = _cluster(n_nodes=1, cap=None)
    c.register(StoredPrefix("short", 1000, {"240p": MB}, ttl=10.0), 0.0)
    assert c.lookup("short", 5.0).kind == "full"  # inside TTL
    hit = c.lookup("short", 20.0)  # stale: dropped at this lookup
    assert hit.kind == "miss"
    assert ("expire", "short", "n0") in c.events
    assert c.nodes[0].stats.expirations == 1


def test_ttl_swept_eagerly_at_eviction_scan():
    n = StorageNode("n0", capacity_bytes=30 * MB)
    n.put(StoredPrefix("stale", 1000, {"240p": 20 * MB}, ttl=5.0), 0.0)
    n.put(_entry("live"), 1.0)
    # at t=10 "stale" is expired: the scan reclaims it instead of
    # evicting the live entry
    ok, evicted = n.put(_entry("new"), 10.0)
    assert ok and evicted == []
    assert not n.contains("stale") and n.contains("live")
    assert n.stats.expirations == 1 and n.stats.evictions == 0


def test_reput_refreshes_ttl_clock():
    n = StorageNode("n0", capacity_bytes=None)
    e = StoredPrefix("k", 1000, {"240p": MB}, ttl=10.0)
    n.put(e, 0.0)
    n.put(e, 8.0)  # re-admission restarts the clock
    assert not n.is_expired("k", 15.0)
    assert n.is_expired("k", 19.0)


def test_pinned_survives_eviction_and_never_expires():
    n = StorageNode("n0", capacity_bytes=30 * MB, policy="lru")
    n.put(StoredPrefix("pin", 1000, {"240p": 10 * MB}, pinned=True,
                       ttl=1.0), 0.0)
    for i in range(4):  # scan pressure that flushes everything unpinned
        n.put(_entry(f"scan{i}"), 100.0 + i)
    assert n.contains("pin")  # neither evicted nor expired (ttl ignored)
    assert not n.is_expired("pin", 1e9)


def test_pinned_full_node_rejects_instead_of_unpinning():
    n = StorageNode("n0", capacity_bytes=30 * MB)
    n.put(StoredPrefix("p1", 1000, {"240p": 15 * MB}, pinned=True), 0.0)
    n.put(StoredPrefix("p2", 1000, {"240p": 10 * MB}, pinned=True), 1.0)
    ok, evicted = n.put(_entry("x"), 2.0)  # 10 MB cannot fit beside pins
    assert not ok and evicted == []
    assert n.stats.rejections == 1
    assert n.contains("p1") and n.contains("p2")


def test_admission_second_hit_defers_residency():
    c = _cluster(n_nodes=1, cap=None, admission="second_hit",
                 admission_min_asks=2)
    c.register(_entry("a"), 0.0)
    assert ("reject", "a", "") in c.events  # cataloged, not resident
    assert not c.nodes[0].contains("a")
    assert c.lookup("a", 1.0).kind == "miss"  # ask 1
    c.notify_recompute_done("a", 2.0)
    assert not c.nodes[0].contains("a")  # 1 ask < 2: still filtered
    assert c.lookup("a", 3.0).kind == "miss"  # ask 2
    c.notify_recompute_done("a", 4.0)
    assert c.nodes[0].contains("a")  # earned residency
    assert c.lookup("a", 5.0).kind == "full"


def test_admission_cost_threshold_filters_low_value_entries():
    c = _cluster(n_nodes=1, cap=None, admission="cost",
                 admission_min_score=4.0)
    # raw/stored = 8 -> one ask scores 8 >= 4; a no-compression entry
    # (raw == stored) scores 1 per ask and needs 4 asks
    c.register(_entry("dense"), 0.0)
    cheap = StoredPrefix("cheap", 1000, {"240p": 10 * MB},
                         raw_kv_bytes=10 * MB)
    c.register(cheap, 0.0)
    for t in range(2):
        c.lookup("dense", 1.0 + t)
        c.lookup("cheap", 1.5 + t)
    c.notify_recompute_done("dense", 4.0)
    c.notify_recompute_done("cheap", 4.0)
    assert c.nodes[0].contains("dense")
    assert not c.nodes[0].contains("cheap")


def test_heal_bypasses_admission_control():
    c = _cluster(cap=None, replication=1, admission="second_hit",
                 admission_min_asks=2)
    c.register(_entry("k"), 0.0)
    for t in range(2):
        c.lookup("k", 1.0 + t)
    c.notify_recompute_done("k", 3.0)
    holder = next(n.node_id for n in c.nodes if n.contains("k"))
    c.fail_node(holder, 4.0)
    # the heal restores residency even though asks reset nothing —
    # admission gates *new* writes, not recovery of granted ones
    assert any(n.contains("k") for n in c.nodes)


# ---------------------------------------------------------------------------
# scheduler handoff
# ---------------------------------------------------------------------------

def test_notify_fetch_miss_requeues_as_plain_prefill():
    sched = FetchingAwareScheduler("kvfetcher", max_running=4)
    req = Request(rid=0, arrival=0.0, prompt_len=1000, reuse_tokens=900,
                  prefix="p")
    sched.submit(req, 0.0)
    sched.schedule(0.0)
    assert req.state is ReqState.WAITING_FOR_KV
    (fr,) = sched.take_fetches()
    sched.notify_fetch_miss(fr, 1.0)
    assert req.reuse_tokens == 0 and req.requested_reuse_tokens == 900
    assert req.storage_hit == "miss"
    assert req.state is ReqState.WAITING and not req.needs_fetch
    (adm,) = sched.schedule(1.0)
    assert adm is req


def test_notify_fetch_miss_unblocks_fetch_agnostic_head():
    sched = FetchingAwareScheduler("fetch_agnostic", max_running=4)
    head = Request(rid=0, arrival=0.0, prompt_len=1000, reuse_tokens=900,
                   prefix="p")
    tail = Request(rid=1, arrival=0.0, prompt_len=100)
    sched.submit(head, 0.0)
    sched.submit(tail, 0.0)
    assert sched.schedule(0.0) == []  # head blocks (HOL)
    sched.take_fetches()
    sched.notify_fetch_miss(head, 1.0)
    assert sched.schedule(1.0) == [head, tail]


# ---------------------------------------------------------------------------
# simulator integration
# ---------------------------------------------------------------------------

def _sim(storage, requests, **kw):
    from repro.configs import get_config
    from repro.core.adaptive import H20_TABLE
    from repro.cluster.simulator import ServingSimulator, kvfetcher_spec

    cfg = get_config("yi-34b")
    ratios = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}
    sim = ServingSimulator(cfg, kvfetcher_spec(ratios), chip="h20",
                           n_chips=2,
                           bandwidth=BandwidthTrace.constant(8.0),
                           storage=storage, table=H20_TABLE, **kw)
    return sim.run(requests, max_new_tokens=4), cfg


def _sim_cluster(cfg, specs, *, n_nodes=3, cap_fraction=None,
                 policy="lru", gbps=8.0, **kw):
    """Cluster of synthetic entries; each node's capacity is
    ``cap_fraction`` of the library's total bytes (None = unbounded)."""
    ratios = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}
    entries = [synthetic_stored_prefix(
        s.key, s.n_tokens, raw_bytes_per_token=cfg.kv_bytes_per_token(),
        ratios=ratios, parent=s.parent) for s in specs]
    total = sum(e.stored_bytes for e in entries)
    cap = None if cap_fraction is None else int(total * cap_fraction)
    nodes = [StorageNode(f"n{i}", capacity_bytes=cap, policy=policy,
                         link=BandwidthTrace.constant(gbps))
             for i in range(n_nodes)]
    cluster = StorageCluster(nodes, **kw)
    for e in entries:
        cluster.register(e, 0.0)
    return cluster


def test_sim_full_partial_miss_paths_complete():
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(2, 2, base_tokens=40_000, ext_tokens=20_000)
    cluster = _sim_cluster(cfg, specs)
    # evict exactly one child so its request becomes a partial hit
    child = specs[1].key
    node = next(n for n in cluster.nodes if n.contains(child))
    node._drop(child)
    reqs = [
        Request(rid=0, arrival=10.0, prompt_len=41_000,
                reuse_tokens=40_000, prefix=specs[0].key),  # full
        Request(rid=1, arrival=200.0, prompt_len=61_000,
                reuse_tokens=60_000, prefix=child),         # partial
        Request(rid=2, arrival=400.0, prompt_len=61_000,
                reuse_tokens=60_000, prefix="unknown"),     # miss
    ]
    res, _ = _sim(cluster, reqs)
    assert [r.storage_hit for r in reqs] == ["full", "partial", "miss"]
    assert all(r.t_first_token is not None for r in reqs)
    part = reqs[1]
    assert part.reuse_tokens == 40_000  # ancestor coverage
    assert part.requested_reuse_tokens == 60_000
    assert part.storage_node == node.node_id or part.storage_node
    miss = reqs[2]
    assert miss.reuse_tokens == 0 and not miss.needs_fetch
    # a miss pays full prefill: slowest TTFT of the three
    assert miss.ttft > part.ttft > reqs[0].ttft


def test_sim_fetch_routes_over_storage_node_link():
    """Same request, same default link — only the storage node's own
    link differs, so the TTFT gap proves per-node routing."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(1, 1, base_tokens=50_000)
    ttfts = {}
    for gbps in (16.0, 1.0):
        cluster = _sim_cluster(cfg, specs, gbps=gbps)
        req = Request(rid=0, arrival=1.0, prompt_len=51_000,
                      reuse_tokens=50_000, prefix=specs[0].key)
        _sim(cluster, [req])
        ttfts[gbps] = req.ttft
    assert ttfts[1.0] > 2.0 * ttfts[16.0]


def test_sim_eviction_policies_diverge_and_are_deterministic():
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(3, 2, base_tokens=40_000,
                              ext_tokens=20_000)
    hits = {}
    events = {}
    for policy in ("lru", "cost"):
        runs = []
        for _ in range(2):
            cluster = _sim_cluster(cfg, specs, n_nodes=1,
                                   cap_fraction=0.35, policy=policy)
            rng = np.random.default_rng(42)
            reqs = zipf_prefix_trace(rng, specs, n_requests=30,
                                     alpha=1.1, gap=120.0,
                                     max_new_tokens=4)
            _sim(cluster, reqs)
            runs.append(list(cluster.events))
            hits[policy] = cluster.hit_rate()
        assert runs[0] == runs[1], f"{policy} events nondeterministic"
        events[policy] = runs[0]
        assert any(e[0] == "evict" for e in runs[0])
    assert events["lru"] != events["cost"]
    # the cost policy retains proven-hot prefixes the LRU flushes
    assert hits["cost"] > hits["lru"]


def test_sim_scripted_failure_unreplicated_pays_full_prefill():
    """fail_at= kills the only holder mid-trace: the next ask misses
    (full-prefill TTFT), the link heal lands *after* that miss (heal
    traffic is not teleportation), and a later ask hits again."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(2, 1, base_tokens=40_000)
    cluster = _sim_cluster(cfg, specs, n_nodes=3, replication=1,
                           heal="link")
    victim = cluster.primary_node(specs[0].key).node_id
    reqs = [
        Request(rid=0, arrival=10.0, prompt_len=41_000,
                reuse_tokens=40_000, prefix=specs[0].key),  # pre-fail
        Request(rid=1, arrival=301.0, prompt_len=41_000,
                reuse_tokens=40_000, prefix=specs[0].key),  # mid-heal
        Request(rid=2, arrival=900.0, prompt_len=41_000,
                reuse_tokens=40_000, prefix=specs[0].key),  # healed
    ]
    res, _ = _sim(cluster, reqs, fail_at=[(300.0, victim)])
    assert [r.storage_hit for r in reqs] == ["full", "miss", "full"]
    assert reqs[1].ttft > 2.0 * reqs[0].ttft  # miss pays the prefill
    kinds = [e[0] for e in cluster.events]
    assert "fail" in kinds and "heal" in kinds
    # the heal completed over the wire, strictly after rid=1's miss
    miss_i = cluster.events.index(("miss", specs[0].key, ""))
    heal_i = next(i for i, e in enumerate(cluster.events)
                  if e[0] == "heal" and e[1] == specs[0].key)
    assert heal_i > miss_i
    assert res.requests  # completed trace


def test_sim_replicated_cluster_serves_through_failure():
    """With replication=2 the surviving replica absorbs the failure:
    the post-fail ask is still a full hit at near-identical TTFT."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(2, 1, base_tokens=40_000)
    cluster = _sim_cluster(cfg, specs, n_nodes=3, replication=2,
                           heal="link")
    holders = [n.node_id for n in cluster.nodes
               if n.contains(specs[0].key)]
    assert len(holders) == 2
    # rid=1 lands while the heal still streams over the survivor's link
    # (contention, not failure, is its penalty); rid=2/3 land after
    reqs = [Request(rid=i, arrival=t, prompt_len=41_000,
                    reuse_tokens=40_000, prefix=specs[0].key)
            for i, t in enumerate((10.0, 301.0, 450.0, 600.0))]
    _sim(cluster, reqs, fail_at=[(300.0, holders[0])])
    assert [r.storage_hit for r in reqs] == ["full"] * 4
    assert all(r.storage_node != holders[0] for r in reqs[1:])
    post = [r.ttft for r in reqs[1:]]
    assert sum(post) / len(post) < 1.3 * reqs[0].ttft
    # the mid-heal request pays heal contention; the healed ones do not
    assert reqs[1].ttft > reqs[2].ttft
    assert reqs[2].ttft < 1.1 * reqs[0].ttft


def test_churn_schedule_is_seeded_and_replayable():
    from repro.data.workload import churn_schedule
    ids = ["n0", "n1", "n2"]
    s1 = churn_schedule(np.random.default_rng(3), ids, n_failures=3,
                        t_start=100.0, gap=400.0, downtime=200.0)
    s2 = churn_schedule(np.random.default_rng(3), ids, n_failures=3,
                        t_start=100.0, gap=400.0, downtime=200.0)
    assert s1 == s2  # same seed -> same trace in every environment
    fail_at, recover_at = s1
    assert [t for t, _ in fail_at] == [100.0, 500.0, 900.0]
    assert [t for t, _ in recover_at] == [300.0, 700.0, 1100.0]
    assert all(nid in ids for _, nid in fail_at)
    # downtime=None: failed nodes stay down, and the schedule never
    # kills the last alive node (fail_node requires a survivor)
    fails, recs = churn_schedule(np.random.default_rng(3), ["n0", "n1"],
                                 n_failures=5, downtime=None)
    assert recs == [] and len(fails) == 1


def test_sim_churned_node_recovers_and_rejoins_the_ring():
    """A full fail->recover cycle mid-trace: requests keep being served
    (replica during the outage), and after recovery the ring routes the
    key's primary back to the recovered node."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(1, 1, base_tokens=40_000)
    cluster = _sim_cluster(cfg, specs, n_nodes=3, replication=2)
    victim = cluster.primary_node(specs[0].key).node_id
    reqs = [Request(rid=i, arrival=t, prompt_len=41_000,
                    reuse_tokens=40_000, prefix=specs[0].key)
            for i, t in enumerate((10.0, 350.0, 700.0))]
    _sim(cluster, reqs, fail_at=[(300.0, victim)],
         recover_at=[(600.0, victim)])
    assert [r.storage_hit for r in reqs] == ["full"] * 3
    kinds = [e[0] for e in cluster.events]
    assert "fail" in kinds and "recover" in kinds
    assert cluster.by_id[victim].alive
    assert cluster.primary_node(specs[0].key).node_id == victim


def test_sim_churn_scheduled_after_last_arrival_still_executes():
    """fail/recover instants after the final request must still fire —
    the post-run cluster state has to be honest."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(1, 1, base_tokens=40_000)
    cluster = _sim_cluster(cfg, specs, n_nodes=3, replication=2)
    victim = cluster.primary_node(specs[0].key).node_id
    reqs = [Request(rid=0, arrival=10.0, prompt_len=41_000,
                    reuse_tokens=40_000, prefix=specs[0].key)]
    _sim(cluster, reqs, fail_at=[(500.0, victim)],
         recover_at=[(600.0, victim)])
    kinds = [e[0] for e in cluster.events]
    assert "fail" in kinds and "recover" in kinds
    assert cluster.by_id[victim].alive


def test_recovery_rebalance_moves_key_home_and_trims_surplus():
    """Recovery re-balance (ISSUE bugfix): before the fix a recovered
    node rejoined the ring empty and its keys stayed on the heal
    survivor forever; now recovery streams them back (``rebalance``
    events) and trims the surplus copy (``rebalance_drop``), restoring
    replication-factor occupancy."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(1, 1, base_tokens=40_000)
    cluster = _sim_cluster(cfg, specs, n_nodes=2, replication=1,
                           heal="sync")
    key = specs[0].key
    home = cluster.primary_node(key)
    other = next(n for n in cluster.nodes if n is not home)
    assert home.contains(key) and not other.contains(key)
    cluster.fail_node(home.node_id, 10.0)  # sync heal -> other
    assert other.contains(key)
    cluster.recover_node(home.node_id, 20.0)
    assert ("rebalance", key, home.node_id) in cluster.events
    assert ("rebalance_drop", key, other.node_id) in cluster.events
    assert home.contains(key) and not other.contains(key)
    assert cluster.rebalances_completed == 1
    assert cluster.heals_completed == 1  # the fail-time heal, untouched


def test_rtt_aware_replica_rotation_excludes_slow_node():
    """RTT-aware replica selection (ISSUE bugfix): with no RTT samples
    the rotation is the legacy round-robin over all residents; once a
    replica's observed RTT drifts beyond the slack band it drops out of
    the rotation while the near-tied fast replicas keep sharing load."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(1, 1, base_tokens=40_000)
    cluster = _sim_cluster(cfg, specs, n_nodes=3, replication=3)
    key, n_tok = specs[0].key, specs[0].n_tokens

    def served(n_lookups):
        start = len(cluster.events)
        for _ in range(n_lookups):
            hit = cluster.lookup(key, 0.0, requested_tokens=n_tok)
            assert hit.kind == "full"
        return [e[2] for e in cluster.events[start:] if e[0] == "full"]

    all_ids = {n.node_id for n in cluster.nodes}
    assert set(served(3)) == all_ids  # legacy: everyone rotates
    fast = sorted(all_ids)[:2]
    slow = next(iter(all_ids - set(fast)))
    for nid in fast:
        cluster.observe_rtt(nid, 0.010)
    cluster.observe_rtt(slow, 0.200)  # way past the 25% slack band
    got = served(4)
    assert slow not in got, "slow replica still in the rotation"
    assert set(got) == set(fast), "fast replicas must share the load"
    # uniform samples restore the full rotation (slack band keeps
    # near-tied nodes in) — selection stays a pure access-seq function
    cluster.node_rtt = {nid: 0.010 for nid in all_ids}
    assert set(served(3)) == all_ids


def test_rtt_aware_heal_source_prefers_fast_holder():
    """Heal/re-balance source selection (ISSUE bugfix): the source is
    the lowest observed-RTT surviving holder; with no samples it stays
    the legacy first-in-ring-order survivor."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(1, 1, base_tokens=40_000)
    key = specs[0].key

    def queued_source(rtts):
        cluster = _sim_cluster(cfg, specs, n_nodes=4, replication=3,
                               heal="manual")
        for nid, rtt in rtts.items():
            cluster.observe_rtt(nid, rtt)
        ring = cluster._ring_nodes(key)
        cluster.fail_node(ring[0].node_id, 10.0)
        (entry, source_id, target_id, kind), = cluster.heal_queue
        assert kind == "heal" and entry.key == key
        assert target_id == ring[3].node_id  # the non-holder successor
        return source_id, ring

    source_id, ring = queued_source({})
    assert source_id == ring[1].node_id  # legacy: first survivor
    source_id, ring = queued_source({ring[1].node_id: 0.300,
                                     ring[2].node_id: 0.020})
    assert source_id == ring[2].node_id  # RTT overrides ring order


# ---------------------------------------------------------------------------
# live engine integration (real model, real codec)
# ---------------------------------------------------------------------------

def _live_cluster(donor_kv, token_sets, *, cap=None, policy="lru",
                  n_nodes=1, **cluster_kw):
    nodes = [StorageNode(f"n{i}", capacity_bytes=cap, policy=policy)
             for i in range(n_nodes)]
    cluster = StorageCluster(nodes, **cluster_kw)
    for toks in token_sets:
        kv_k, kv_v = donor_kv(toks)
        cluster.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                                resolutions=("240p",))
    return cluster


def test_live_partial_hit_matches_full_recompute(tiny_cfg, tiny_params,
                                                 donor_kv):
    """Acceptance: ancestor fetch + tail recompute emits tokens identical
    to a full recompute of the same prompt."""
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(11)
    prompt = rng.integers(0, tiny_cfg.vocab_size, 72)
    # only the 48-token ancestor of the 64-token ask is registered
    cluster = _live_cluster(donor_kv, [prompt[:48]])
    eng = LiveEngine(tiny_params, tiny_cfg, cluster, resolution="240p")
    req = eng.submit(prompt, reuse_prefix="by-tokens", reuse_tokens=64,
                     max_new_tokens=4)
    eng.run()
    assert req.storage_hit == "partial"
    assert req.reuse_tokens == 48 and req.requested_reuse_tokens == 64
    assert cluster.partial_hits == 1

    ref = LiveEngine(tiny_params, tiny_cfg, KVStore(), resolution="240p")
    ref_req = ref.submit(prompt, max_new_tokens=4)
    ref.run()
    assert eng.outputs[req.rid] == ref.outputs[ref_req.rid]


def test_live_miss_falls_back_to_full_prefill(tiny_cfg, tiny_params,
                                              donor_kv):
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(12)
    prompt = rng.integers(0, tiny_cfg.vocab_size, 40)
    other = rng.integers(0, tiny_cfg.vocab_size, 32)
    cluster = _live_cluster(donor_kv, [other])
    eng = LiveEngine(tiny_params, tiny_cfg, cluster, resolution="240p")
    req = eng.submit(prompt, reuse_prefix="by-tokens", reuse_tokens=32,
                     max_new_tokens=4)
    eng.run()
    assert req.storage_hit == "miss" and req.reuse_tokens == 0
    assert len(eng.outputs[req.rid]) == 4

    ref = LiveEngine(tiny_params, tiny_cfg, KVStore(), resolution="240p")
    ref_req = ref.submit(prompt, max_new_tokens=4)
    ref.run()
    assert eng.outputs[req.rid] == ref.outputs[ref_req.rid]


def test_live_engine_fail_node_miss_heal_cycle(tiny_cfg, tiny_params,
                                               donor_kv):
    """Wall-clock engine + manual heal: a node failure turns the next
    ask into a miss (token-identical full-prefill fallback), the
    delayed write-on-miss restores residency after the recompute, and
    pump_heal() drains the queued re-replication without duplicating
    copies that already came back."""
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(21)
    prefix = rng.integers(0, tiny_cfg.vocab_size, 48)
    suffix = rng.integers(0, tiny_cfg.vocab_size, 8)
    prompt = np.concatenate([prefix, suffix])
    cluster = _live_cluster(donor_kv, [prefix], n_nodes=2,
                            heal="manual")
    eng = LiveEngine(tiny_params, tiny_cfg, cluster, resolution="240p")
    r0 = eng.submit(prompt, reuse_prefix="by-tokens", reuse_tokens=48,
                    max_new_tokens=4)
    eng.run()
    assert r0.storage_hit == "full"
    holder = r0.storage_node
    eng.fail_node(holder)
    assert cluster.heal_queue  # re-replication queued, not teleported
    r1 = eng.submit(prompt, reuse_prefix="by-tokens", reuse_tokens=48,
                    max_new_tokens=4)
    eng.run()
    assert r1.storage_hit == "miss" and r1.reuse_tokens == 0
    ref = LiveEngine(tiny_params, tiny_cfg, KVStore(), resolution="240p")
    ref_req = ref.submit(prompt, max_new_tokens=4)
    ref.run()
    assert eng.outputs[r1.rid] == ref.outputs[ref_req.rid]
    # delayed write-on-miss already restored residency on a live node
    r2 = eng.submit(prompt, reuse_prefix="by-tokens", reuse_tokens=48,
                    max_new_tokens=4)
    eng.run()
    assert r2.storage_hit == "full" and r2.storage_node != holder
    assert eng.outputs[r2.rid] == ref.outputs[ref_req.rid]
    key = next(iter(cluster.catalog))
    cluster.pump_heal(eng.now())  # no-op: the copy is already back
    assert sum(1 for n in cluster.nodes if n.contains(key)) == 1


@pytest.mark.slow
def test_cross_env_hit_miss_evict_sequences_agree(tiny_cfg, tiny_params,
                                                  donor_kv):
    """Simulator and LiveEngine drive identically-configured clusters
    through the same access order and must log the identical
    admit/evict/hit/partial/miss event sequence."""
    from repro.cluster.simulator import MethodSpec, ServingSimulator
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(5)
    base = rng.integers(0, tiny_cfg.vocab_size, 48)
    other = rng.integers(0, tiny_cfg.vocab_size, 32)
    tok_a, tok_b, tok_c = base[:32], base[:48], other

    # live side: real manifests, capacity fits 2 of the 3 entries
    sizes = {}
    probe = _live_cluster(donor_kv, [tok_a, tok_b, tok_c])
    for key, e in probe.catalog.items():
        sizes[key] = e.stored_bytes
    cap = int(sorted(sizes.values())[-1] + sorted(sizes.values())[-2] + 1)
    live = StorageCluster([StorageNode("n0", capacity_bytes=cap,
                                       policy="lru")])
    for toks in (tok_a, tok_b, tok_c):
        kv_k, kv_v = donor_kv(toks)
        live.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                             resolutions=("240p",))
    keys = list(live.catalog)  # registration order: a, b, c
    eng = LiveEngine(tiny_params, tiny_cfg, live, resolution="240p")
    suffix = rng.integers(0, tiny_cfg.vocab_size, 8)
    # access order: c (hit), a (likely evicted), b, c — write-on-miss
    # re-admissions keep the pressure on
    for toks in (tok_c, tok_a, tok_b, tok_c):
        eng.submit(np.concatenate([toks, suffix]),
                   reuse_prefix="by-tokens", reuse_tokens=len(toks),
                   max_new_tokens=2)
        eng.run()

    # simulator side: synthetic entries with the live sizes and parents
    sim_nodes = [StorageNode("n0", capacity_bytes=cap, policy="lru")]
    sim_cluster = StorageCluster(sim_nodes)
    for key in keys:
        src = live.catalog[key]
        sim_cluster.register(StoredPrefix(
            key=key, n_tokens=src.n_tokens,
            bytes_by_resolution={"240p": src.stored_bytes},
            raw_kv_bytes=src.raw_kv_bytes, parent=src.parent), 0.0)
    key_of = {len(tok_a): keys[0], len(tok_b): keys[1]}
    order = [keys[2], keys[0], keys[1], keys[2]]
    lens = [len(tok_c), len(tok_a), len(tok_b), len(tok_c)]
    reqs = [Request(rid=i, arrival=(i + 1) * 50.0,
                    prompt_len=lens[i] + 8, reuse_tokens=lens[i],
                    prefix=order[i], max_new_tokens=2)
            for i in range(4)]
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=False)
    sim = ServingSimulator(tiny_cfg, spec,
                           bandwidth=BandwidthTrace.constant(0.01),
                           storage=sim_cluster, chunk_tokens=16)
    sim.run(reqs, max_new_tokens=2)

    assert live.events == sim_cluster.events
    kinds = [e[0] for e in live.events]
    assert "miss" in kinds and "evict" in kinds, \
        "sequence exercised no pressure; test is vacuous"
    assert key_of  # silence unused (kept for debugging readability)


@pytest.mark.slow
def test_cross_env_churn_fail_heal_expire_reject_agree(tiny_cfg,
                                                       tiny_params,
                                                       donor_kv):
    """ISSUE 4 acceptance: a seeded churn trace — admission rejections,
    TTL expiry, a node failure mid-trace, the sync ring heal, and the
    post-recovery re-balance — must replay the identical
    fail/heal/expire/reject/recover/rebalance event sequence in the
    live engine (real manifests, wall clock) and the analytic simulator
    (synthetic entries, virtual clock)."""
    from repro.cluster.simulator import MethodSpec, ServingSimulator
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(9)
    tok_a = rng.integers(0, tiny_cfg.vocab_size, 32)  # ttl=0: expires
    tok_b = rng.integers(0, tiny_cfg.vocab_size, 40)  # fail/heal target
    suffix = rng.integers(0, tiny_cfg.vocab_size, 8)

    def build_live():
        nodes = [StorageNode(f"n{i}") for i in range(2)]
        c = StorageCluster(nodes, replication=1, heal="sync",
                           admission="second_hit", admission_min_asks=1)
        for toks, ttl in ((tok_a, 0.0), (tok_b, None)):
            kv_k, kv_v = donor_kv(toks)
            c.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                              resolutions=("240p",), ttl=ttl)
        return c

    live = build_live()
    keys = list(live.catalog)  # [key_a, key_b] in registration order
    eng = LiveEngine(tiny_params, tiny_cfg, live, resolution="240p")
    # access script: a (miss->admit), a (expire->miss->admit),
    # b (miss->admit), FAIL b's holder, b (miss or heal-hit), a again
    order = [tok_a, tok_a, tok_b, None, tok_b, tok_a]
    failed = None
    for toks in order:
        if toks is None:
            failed = next(n.node_id for n in live.nodes
                          if n.contains(keys[1]))
            eng.fail_node(failed)
            continue
        eng.submit(np.concatenate([toks, suffix]),
                   reuse_prefix="by-tokens", reuse_tokens=len(toks),
                   max_new_tokens=2)
        eng.run()
    # the failed holder comes back after the trace: recovery must
    # re-balance keys whose ring home it is back onto it (and trim the
    # surplus copy off the heal survivor)
    eng.recover_node(failed)

    # simulator side: synthetic twins under the same churn, same keys
    sim_nodes = [StorageNode(f"n{i}") for i in range(2)]
    sim_cluster = StorageCluster(sim_nodes, replication=1, heal="sync",
                                 admission="second_hit",
                                 admission_min_asks=1)
    for key in keys:
        src = live.catalog[key]
        sim_cluster.register(StoredPrefix(
            key=key, n_tokens=src.n_tokens,
            bytes_by_resolution={"240p": src.stored_bytes},
            raw_kv_bytes=src.raw_kv_bytes, parent=src.parent,
            ttl=src.ttl, pinned=src.pinned), 0.0)
    # nothing is resident at registration under second_hit admission;
    # the recompute admits b onto its ring primary — same ring, same
    # node id in both environments
    sim_holder = sim_cluster.primary_node(keys[1]).node_id
    lens = {id(tok_a): (len(tok_a), keys[0]),
            id(tok_b): (len(tok_b), keys[1])}
    reqs = []
    t_fail = None
    t = 50.0
    for toks in order:
        if toks is None:
            t_fail = t - 25.0  # between the two neighbouring arrivals
            continue
        n_tok, key = lens[id(toks)]
        reqs.append(Request(rid=len(reqs), arrival=t,
                            prompt_len=n_tok + 8, reuse_tokens=n_tok,
                            prefix=key, max_new_tokens=2))
        t += 50.0
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=False)
    sim = ServingSimulator(tiny_cfg, spec,
                           bandwidth=BandwidthTrace.constant(0.01),
                           storage=sim_cluster, chunk_tokens=16,
                           fail_at=[(t_fail, sim_holder)],
                           recover_at=[(t + 25.0, sim_holder)])
    sim.run(reqs, max_new_tokens=2)

    assert live.events == sim_cluster.events
    kinds = [e[0] for e in live.events]
    for needed in ("fail", "heal", "expire", "reject", "miss", "admit",
                   "recover", "rebalance"):
        assert needed in kinds, f"churn trace exercised no {needed!r}"
    # the re-balance pulled b home onto its recovered ring primary and
    # dropped the surplus copy, so replication=1 holds again
    assert ("rebalance", keys[1], sim_holder) in sim_cluster.events
    assert sum(n.contains(keys[1]) for n in live.nodes) == 1
    assert live.primary_node(keys[1]).contains(keys[1])


# ---------------------------------------------------------------------------
# per-resolution eviction (ISSUE 7): a StoredPrefix holds multiple encoded
# resolutions and capacity pressure evicts cold rungs, not whole prefixes
# ---------------------------------------------------------------------------

def _ladder(key, rungs, parent=None):
    return StoredPrefix(key=key, n_tokens=1000, bytes_by_resolution=rungs,
                        raw_kv_bytes=8 * sum(rungs.values()), parent=parent)


def test_resolution_granularity_evicts_cold_rung_keeps_prefix():
    n = StorageNode("n0", capacity_bytes=50 * MB, policy="lru",
                    evict_granularity="resolution")
    n.put(_ladder("a", {"240p": 10 * MB, "1080p": 30 * MB}), 0.0)
    n.note_resolution_use("a", "1080p")  # the rung the fetch path uses
    ok, evicted = n.put(_ladder("b", {"240p": 15 * MB}), 1.0)
    assert ok and evicted == ["a/240p"]  # cold rung goes, prefix stays
    assert n.contains("a")
    assert n.resident_resolutions("a") == ("1080p",)
    assert n.used_bytes == 45 * MB
    assert n.bytes_by_resolution["240p"] == 15 * MB


def test_resolution_granularity_last_rung_drops_whole_prefix():
    n = StorageNode("n0", capacity_bytes=40 * MB,
                    evict_granularity="resolution")
    n.put(_ladder("a", {"1080p": 30 * MB}), 0.0)
    ok, evicted = n.put(_ladder("b", {"240p": 20 * MB}), 1.0)
    assert ok and evicted == ["a"]  # plain key: the whole prefix went
    assert not n.contains("a")
    assert n.resident_resolutions("a") is None


def test_note_resolution_use_steers_lfu_victim():
    """Per-rung frequency from the fetch path decides which rung
    survives: the rung the adaptive selector keeps delivering outlives
    a bigger, barely-used one."""
    n = StorageNode("n0", capacity_bytes=40 * MB, policy="lfu",
                    evict_granularity="resolution")
    n.put(_ladder("a", {"240p": 10 * MB, "1080p": 20 * MB}), 0.0)
    for _ in range(3):
        n.note_resolution_use("a", "240p")
    n.note_resolution_use("a", "1080p")  # more recent but less frequent
    _, evicted = n.put(_ladder("b", {"240p": 15 * MB}), 1.0)
    assert evicted == ["a/1080p"]
    assert n.resident_resolutions("a") == ("240p",)


def test_readmission_restores_full_ladder_and_keeps_rung_history():
    n = StorageNode("n0", capacity_bytes=50 * MB,
                    evict_granularity="resolution")
    e = _ladder("a", {"240p": 10 * MB, "1080p": 30 * MB})
    n.put(e, 0.0)
    n.note_resolution_use("a", "1080p")
    n.put(_ladder("b", {"240p": 15 * MB}), 1.0)  # evicts a/240p
    assert n.resident_resolutions("a") == ("1080p",)
    n.put(_ladder("x", {"240p": 1 * MB}), 1.5)  # headroom stays
    ok, evicted = n.put(e, 2.0)  # re-register: the 240p rung returns
    # cold single-rung "b" (oldest untouched) is the victim, and losing
    # its last rung drops the whole prefix
    assert ok and evicted == ["b"]
    assert n.resident_resolutions("a") == ("240p", "1080p")
    assert n.residents["a"].res_hits == {"1080p": 1}  # history kept


def test_cluster_rung_eviction_narrows_hit_resolutions():
    """The surviving rung set travels on StorageHit.resolutions (the
    fetch controller caps its ladder with it), and rung evictions are
    logged as distinct `evict_res` events."""
    node = StorageNode("n0", capacity_bytes=50 * MB, policy="lru",
                       evict_granularity="resolution")
    c = StorageCluster([node])
    c.register(_ladder("a", {"240p": 10 * MB, "1080p": 30 * MB}), 0.0)
    hit = c.lookup("a", 1.0)
    assert hit.kind == "full"
    assert hit.resolutions == ("240p", "1080p")  # ladder order
    c.note_resolution_use("n0", "a", "1080p")  # res_sink feedback
    c.register(_ladder("b", {"240p": 15 * MB}), 2.0)
    assert ("evict_res", "a/240p", "n0") in c.events
    assert not any(ev[0] == "evict" for ev in c.events)
    hit = c.lookup("a", 3.0)
    assert hit.kind == "full" and hit.resolutions == ("1080p",)
    # dead-node / unknown-key feedback is a safe no-op
    c.note_resolution_use("n9", "a", "1080p")
    c.note_resolution_use("n0", "nope", "1080p")
