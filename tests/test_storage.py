"""Multi-node prefix storage tier (ISSUE 3 acceptance surface).

Node-level tests cover byte-accurate capacity accounting and the three
eviction policies; cluster-level tests cover consistent-hash placement,
popularity replication, longest-prefix-match full/partial/miss
resolution, and determinism of the event log under a seeded Zipf
workload.  Integration tests drive the analytic simulator and the REAL
live engine and assert (a) a partial hit produces tokens identical to a
full recompute and (b) both environments replay the identical
hit/miss/evict event sequence for the same access order.
"""
import numpy as np
import pytest

from repro.core.scheduler import FetchingAwareScheduler, ReqState, Request
from repro.cluster.network import BandwidthTrace
from repro.cluster.storage import (KVStore, StorageCluster, StorageNode,
                                   StoredPrefix, synthetic_stored_prefix)
from repro.data.workload import prefix_trie_specs, zipf_prefix_trace

MB = 1_000_000


def _entry(key, n_tokens=1000, size=10 * MB, parent=None):
    return StoredPrefix(key=key, n_tokens=n_tokens,
                        bytes_by_resolution={"240p": size},
                        raw_kv_bytes=8 * size, parent=parent)


# ---------------------------------------------------------------------------
# StorageNode: capacity accounting + eviction policies
# ---------------------------------------------------------------------------

def test_node_capacity_accounting_per_resolution():
    n = StorageNode("n0", capacity_bytes=100 * MB)
    e = StoredPrefix("a", 100, {"240p": 10 * MB, "1080p": 30 * MB})
    assert n.put(e, 0.0) == (True, [])
    assert n.used_bytes == 40 * MB
    assert n.bytes_by_resolution == {"240p": 10 * MB, "1080p": 30 * MB}
    assert n.stored_bytes() == 40 * MB
    # eviction returns the bytes
    big = StoredPrefix("b", 100, {"240p": 70 * MB})
    ok, evicted = n.put(big, 1.0)
    assert ok and evicted == ["a"]
    assert n.used_bytes == 70 * MB
    assert n.bytes_by_resolution["1080p"] == 0


def test_node_rejects_entry_larger_than_capacity():
    n = StorageNode("n0", capacity_bytes=10 * MB)
    n.put(_entry("a", size=8 * MB), 0.0)
    ok, evicted = n.put(_entry("huge", size=20 * MB), 1.0)
    assert not ok and evicted == []  # never flushes the node for a lost cause
    assert n.contains("a") and n.stats.rejections == 1


def test_node_lru_evicts_least_recently_used():
    n = StorageNode("n0", capacity_bytes=30 * MB, policy="lru")
    for i, k in enumerate(("a", "b", "c")):
        n.put(_entry(k), float(i))
    n.get("a", 10.0)  # refresh a
    _, evicted = n.put(_entry("d"), 11.0)
    assert evicted == ["b"]  # oldest untouched


def test_node_lfu_keeps_frequent():
    n = StorageNode("n0", capacity_bytes=30 * MB, policy="lfu")
    for i, k in enumerate(("a", "b", "c")):
        n.put(_entry(k), float(i))
    for t in range(3):
        n.get("a", 10.0 + t)
    n.get("c", 20.0)  # recent but infrequent
    _, evicted = n.put(_entry("d"), 21.0)
    assert evicted == ["b"]  # 0 hits loses to recency


def test_node_cost_keeps_bytes_saved_per_byte_stored():
    """A proven-hot prefix survives a scan that flushes an LRU node."""
    seq = [("hot", 0.0)] + [(f"scan{i}", float(i + 1)) for i in range(3)]
    results = {}
    for policy in ("lru", "cost"):
        n = StorageNode("n0", capacity_bytes=30 * MB, policy=policy)
        n.put(_entry("hot"), 0.0)
        n.get("hot", 0.5)  # one hit: it has earned bytes-saved credit
        for key, t in seq[1:]:
            n.put(_entry(key), t)
        results[policy] = n.contains("hot")
    assert results["cost"] and not results["lru"]


def test_node_cost_prefers_small_high_value_entries():
    n = StorageNode("n0", capacity_bytes=30 * MB, policy="cost")
    small = StoredPrefix("small", 100, {"240p": 5 * MB},
                         raw_kv_bytes=50 * MB)
    big = StoredPrefix("big", 100, {"240p": 25 * MB}, raw_kv_bytes=50 * MB)
    n.put(small, 0.0)
    n.put(big, 1.0)
    n.get("small", 2.0)
    n.get("big", 3.0)  # equal hits; big saves fewer bytes per byte stored
    _, evicted = n.put(_entry("new", size=10 * MB), 4.0)
    assert evicted == ["big"]


def test_node_reregister_replaces_stale_entry():
    """Re-registering a resident key must swap in the new artifact and
    re-account its bytes (regression: the flat dict overwrote)."""
    n = StorageNode("n0", capacity_bytes=100 * MB)
    n.put(_entry("a", size=10 * MB), 0.0)
    n.get("a", 1.0)
    v2 = StoredPrefix("a", 1000, {"240p": 10 * MB, "480p": 15 * MB})
    ok, evicted = n.put(v2, 2.0)
    assert ok and not evicted
    assert n.residents["a"].entry is v2
    assert n.residents["a"].hits == 1  # same prefix: history kept
    assert n.used_bytes == 25 * MB
    assert n.bytes_by_resolution == {"240p": 10 * MB, "480p": 15 * MB}
    assert n.stats.admissions == 1  # replacement, not a new admission


def test_node_repr_is_human_readable():
    n = StorageNode("n0", capacity_bytes=2e9, policy="cost")
    n.put(_entry("a", size=500 * MB), 0.0)
    r = repr(n)
    assert "0.50/2.00 GB" in r and "cost" in r and "1 prefixes" in r
    assert "unbounded" in repr(StorageNode("n1"))


# ---------------------------------------------------------------------------
# StorageCluster: placement, replication, LPM lookup, determinism
# ---------------------------------------------------------------------------

def _cluster(n_nodes=3, cap=35 * MB, policy="lru", **kw):
    nodes = [StorageNode(f"n{i}", capacity_bytes=cap, policy=policy)
             for i in range(n_nodes)]
    return StorageCluster(nodes, **kw)


def test_consistent_hash_placement_deterministic_and_spread():
    keys = [f"k{i}" for i in range(60)]
    c1, c2 = _cluster(cap=None), _cluster(cap=None)
    assert [c1.primary_node(k).node_id for k in keys] == \
        [c2.primary_node(k).node_id for k in keys]
    used = {c1.primary_node(k).node_id for k in keys}
    assert used == {"n0", "n1", "n2"}  # all nodes take keys


def test_lookup_full_partial_miss_and_ancestor_chain():
    c = _cluster(n_nodes=1, cap=25 * MB)
    c.register(_entry("root", n_tokens=400, size=10 * MB), 0.0)
    c.register(_entry("child", n_tokens=600, size=10 * MB,
                      parent="root"), 1.0)
    full = c.lookup("child", 2.0)
    assert full.kind == "full" and full.covered_tokens == 600
    assert full.node.node_id == "n0"
    # make child the LRU victim, then squeeze it out
    c.lookup("root", 2.5)
    c.register(_entry("x", n_tokens=100, size=10 * MB), 3.0)
    assert not c.nodes[0].contains("child")
    assert c.nodes[0].contains("root")
    partial = c.lookup("child", 5.0)
    assert partial.kind == "partial"
    assert partial.entry.key == "root" and partial.covered_tokens == 400
    assert partial.requested_tokens == 600
    miss = c.lookup("never-registered", 6.0)
    assert miss.kind == "miss" and miss.entry is None


def test_write_on_miss_readmits_from_catalog():
    c = _cluster(n_nodes=1, cap=25 * MB)
    c.register(_entry("a", size=10 * MB), 0.0)
    c.register(_entry("b", size=10 * MB), 1.0)
    c.register(_entry("c", size=10 * MB), 2.0)  # evicts a (lru)
    assert not c.nodes[0].contains("a")
    hit = c.lookup("a", 3.0)
    assert hit.kind == "miss"
    assert c.nodes[0].contains("a")  # pull-through re-admission
    assert c.lookup("a", 4.0).kind == "full"


def test_popularity_replication_spreads_hot_prefixes():
    c = _cluster(cap=None, placement="popular", replicate_threshold=2)
    c.register(_entry("hot"), 0.0)
    c.register(_entry("cold"), 0.0)
    for t in range(3):
        assert c.lookup("hot", 1.0 + t).kind == "full"
    holders = [n.node_id for n in c.nodes if n.contains("hot")]
    assert len(holders) >= 2
    assert ("replicate", "hot", holders[-1]) in c.events or \
        any(ev[0] == "replicate" and ev[1] == "hot" for ev in c.events)
    assert sum(1 for n in c.nodes if n.contains("cold")) == 1


def test_lookup_tokens_longest_prefix_match():
    c = _cluster(cap=None)
    toks = np.arange(64)
    root = StoredPrefix("root", 32, {"240p": MB},
                        token_ids=toks[:32])
    child = StoredPrefix("child", 48, {"240p": MB}, parent="root",
                         token_ids=toks[:48])
    c.register(root, 0.0)
    c.register(child, 0.0)
    full = c.lookup_tokens(toks[:48], 1.0)
    assert full.kind == "full" and full.entry.key == "child"
    # longer ask than any stored prefix: partial on the deepest ancestor
    part = c.lookup_tokens(toks[:64], 2.0)
    assert part.kind == "partial" and part.entry.key == "child"
    assert part.covered_tokens == 48 and part.requested_tokens == 64
    # diverging tokens match nothing
    other = np.arange(100, 140)
    assert c.lookup_tokens(other, 3.0).kind == "miss"


def test_cluster_event_log_deterministic_under_seeded_zipf():
    """Same seed, same sizes -> byte-identical event logs, with real
    eviction pressure (the determinism the cross-env test relies on)."""
    specs = prefix_trie_specs(3, 2, base_tokens=400, ext_tokens=200)

    def run_once():
        c = _cluster(n_nodes=2, cap=25 * MB, policy="cost")
        for s in specs:
            c.register(_entry(s.key, n_tokens=s.n_tokens, size=10 * MB,
                              parent=s.parent), 0.0)
        rng = np.random.default_rng(42)
        reqs = zipf_prefix_trace(rng, specs, n_requests=30, alpha=1.2,
                                 gap=1.0)
        for r in reqs:
            c.lookup(r.prefix, r.arrival + 1.0,
                     requested_tokens=r.reuse_tokens)
        return list(c.events)

    e1, e2 = run_once(), run_once()
    assert e1 == e2
    assert any(ev[0] == "evict" for ev in e1), "no capacity pressure"
    assert any(ev[0] in ("full", "partial") for ev in e1)


def test_kvstore_facade_keeps_flat_api(synthetic_kv):
    kv_k, kv_v, toks = synthetic_kv(8, 3, 2, 4)
    store = KVStore()
    man = store.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=4,
                                resolutions=("240p",))
    assert store.lookup(man.prefix) is man
    assert store.lookup("nope") is None
    ref = man.refs[0]
    assert store.get_chunk(man.prefix, ref.chunk_id, "240p") == \
        man.blobs[(ref.chunk_id, "240p")]
    assert store.stored_bytes() == sum(len(b) for b in man.blobs.values())


# ---------------------------------------------------------------------------
# scheduler handoff
# ---------------------------------------------------------------------------

def test_notify_fetch_miss_requeues_as_plain_prefill():
    sched = FetchingAwareScheduler("kvfetcher", max_running=4)
    req = Request(rid=0, arrival=0.0, prompt_len=1000, reuse_tokens=900,
                  prefix="p")
    sched.submit(req, 0.0)
    sched.schedule(0.0)
    assert req.state is ReqState.WAITING_FOR_KV
    (fr,) = sched.take_fetches()
    sched.notify_fetch_miss(fr, 1.0)
    assert req.reuse_tokens == 0 and req.requested_reuse_tokens == 900
    assert req.storage_hit == "miss"
    assert req.state is ReqState.WAITING and not req.needs_fetch
    (adm,) = sched.schedule(1.0)
    assert adm is req


def test_notify_fetch_miss_unblocks_fetch_agnostic_head():
    sched = FetchingAwareScheduler("fetch_agnostic", max_running=4)
    head = Request(rid=0, arrival=0.0, prompt_len=1000, reuse_tokens=900,
                   prefix="p")
    tail = Request(rid=1, arrival=0.0, prompt_len=100)
    sched.submit(head, 0.0)
    sched.submit(tail, 0.0)
    assert sched.schedule(0.0) == []  # head blocks (HOL)
    sched.take_fetches()
    sched.notify_fetch_miss(head, 1.0)
    assert sched.schedule(1.0) == [head, tail]


# ---------------------------------------------------------------------------
# simulator integration
# ---------------------------------------------------------------------------

def _sim(storage, requests, **kw):
    from repro.configs import get_config
    from repro.core.adaptive import H20_TABLE
    from repro.cluster.simulator import ServingSimulator, kvfetcher_spec

    cfg = get_config("yi-34b")
    ratios = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}
    sim = ServingSimulator(cfg, kvfetcher_spec(ratios), chip="h20",
                           n_chips=2,
                           bandwidth=BandwidthTrace.constant(8.0),
                           storage=storage, table=H20_TABLE, **kw)
    return sim.run(requests, max_new_tokens=4), cfg


def _sim_cluster(cfg, specs, *, n_nodes=3, cap_fraction=None,
                 policy="lru", gbps=8.0, **kw):
    """Cluster of synthetic entries; each node's capacity is
    ``cap_fraction`` of the library's total bytes (None = unbounded)."""
    ratios = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}
    entries = [synthetic_stored_prefix(
        s.key, s.n_tokens, raw_bytes_per_token=cfg.kv_bytes_per_token(),
        ratios=ratios, parent=s.parent) for s in specs]
    total = sum(e.stored_bytes for e in entries)
    cap = None if cap_fraction is None else int(total * cap_fraction)
    nodes = [StorageNode(f"n{i}", capacity_bytes=cap, policy=policy,
                         link=BandwidthTrace.constant(gbps))
             for i in range(n_nodes)]
    cluster = StorageCluster(nodes, **kw)
    for e in entries:
        cluster.register(e, 0.0)
    return cluster


def test_sim_full_partial_miss_paths_complete():
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(2, 2, base_tokens=40_000, ext_tokens=20_000)
    cluster = _sim_cluster(cfg, specs)
    # evict exactly one child so its request becomes a partial hit
    child = specs[1].key
    node = next(n for n in cluster.nodes if n.contains(child))
    node._drop(child)
    reqs = [
        Request(rid=0, arrival=10.0, prompt_len=41_000,
                reuse_tokens=40_000, prefix=specs[0].key),  # full
        Request(rid=1, arrival=200.0, prompt_len=61_000,
                reuse_tokens=60_000, prefix=child),         # partial
        Request(rid=2, arrival=400.0, prompt_len=61_000,
                reuse_tokens=60_000, prefix="unknown"),     # miss
    ]
    res, _ = _sim(cluster, reqs)
    assert [r.storage_hit for r in reqs] == ["full", "partial", "miss"]
    assert all(r.t_first_token is not None for r in reqs)
    part = reqs[1]
    assert part.reuse_tokens == 40_000  # ancestor coverage
    assert part.requested_reuse_tokens == 60_000
    assert part.storage_node == node.node_id or part.storage_node
    miss = reqs[2]
    assert miss.reuse_tokens == 0 and not miss.needs_fetch
    # a miss pays full prefill: slowest TTFT of the three
    assert miss.ttft > part.ttft > reqs[0].ttft


def test_sim_fetch_routes_over_storage_node_link():
    """Same request, same default link — only the storage node's own
    link differs, so the TTFT gap proves per-node routing."""
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(1, 1, base_tokens=50_000)
    ttfts = {}
    for gbps in (16.0, 1.0):
        cluster = _sim_cluster(cfg, specs, gbps=gbps)
        req = Request(rid=0, arrival=1.0, prompt_len=51_000,
                      reuse_tokens=50_000, prefix=specs[0].key)
        _sim(cluster, [req])
        ttfts[gbps] = req.ttft
    assert ttfts[1.0] > 2.0 * ttfts[16.0]


def test_sim_eviction_policies_diverge_and_are_deterministic():
    from repro.configs import get_config
    cfg = get_config("yi-34b")
    specs = prefix_trie_specs(3, 2, base_tokens=40_000,
                              ext_tokens=20_000)
    hits = {}
    events = {}
    for policy in ("lru", "cost"):
        runs = []
        for _ in range(2):
            cluster = _sim_cluster(cfg, specs, n_nodes=1,
                                   cap_fraction=0.35, policy=policy)
            rng = np.random.default_rng(42)
            reqs = zipf_prefix_trace(rng, specs, n_requests=30,
                                     alpha=1.1, gap=120.0,
                                     max_new_tokens=4)
            _sim(cluster, reqs)
            runs.append(list(cluster.events))
            hits[policy] = cluster.hit_rate()
        assert runs[0] == runs[1], f"{policy} events nondeterministic"
        events[policy] = runs[0]
        assert any(e[0] == "evict" for e in runs[0])
    assert events["lru"] != events["cost"]
    # the cost policy retains proven-hot prefixes the LRU flushes
    assert hits["cost"] > hits["lru"]


# ---------------------------------------------------------------------------
# live engine integration (real model, real codec)
# ---------------------------------------------------------------------------

def _live_cluster(donor_kv, token_sets, *, cap=None, policy="lru",
                  n_nodes=1):
    nodes = [StorageNode(f"n{i}", capacity_bytes=cap, policy=policy)
             for i in range(n_nodes)]
    cluster = StorageCluster(nodes)
    for toks in token_sets:
        kv_k, kv_v = donor_kv(toks)
        cluster.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                                resolutions=("240p",))
    return cluster


def test_live_partial_hit_matches_full_recompute(tiny_cfg, tiny_params,
                                                 donor_kv):
    """Acceptance: ancestor fetch + tail recompute emits tokens identical
    to a full recompute of the same prompt."""
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(11)
    prompt = rng.integers(0, tiny_cfg.vocab_size, 72)
    # only the 48-token ancestor of the 64-token ask is registered
    cluster = _live_cluster(donor_kv, [prompt[:48]])
    eng = LiveEngine(tiny_params, tiny_cfg, cluster, resolution="240p")
    req = eng.submit(prompt, reuse_prefix="by-tokens", reuse_tokens=64,
                     max_new_tokens=4)
    eng.run()
    assert req.storage_hit == "partial"
    assert req.reuse_tokens == 48 and req.requested_reuse_tokens == 64
    assert cluster.partial_hits == 1

    ref = LiveEngine(tiny_params, tiny_cfg, KVStore(), resolution="240p")
    ref_req = ref.submit(prompt, max_new_tokens=4)
    ref.run()
    assert eng.outputs[req.rid] == ref.outputs[ref_req.rid]


def test_live_miss_falls_back_to_full_prefill(tiny_cfg, tiny_params,
                                              donor_kv):
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(12)
    prompt = rng.integers(0, tiny_cfg.vocab_size, 40)
    other = rng.integers(0, tiny_cfg.vocab_size, 32)
    cluster = _live_cluster(donor_kv, [other])
    eng = LiveEngine(tiny_params, tiny_cfg, cluster, resolution="240p")
    req = eng.submit(prompt, reuse_prefix="by-tokens", reuse_tokens=32,
                     max_new_tokens=4)
    eng.run()
    assert req.storage_hit == "miss" and req.reuse_tokens == 0
    assert len(eng.outputs[req.rid]) == 4

    ref = LiveEngine(tiny_params, tiny_cfg, KVStore(), resolution="240p")
    ref_req = ref.submit(prompt, max_new_tokens=4)
    ref.run()
    assert eng.outputs[req.rid] == ref.outputs[ref_req.rid]


@pytest.mark.slow
def test_cross_env_hit_miss_evict_sequences_agree(tiny_cfg, tiny_params,
                                                  donor_kv):
    """Simulator and LiveEngine drive identically-configured clusters
    through the same access order and must log the identical
    admit/evict/hit/partial/miss event sequence."""
    from repro.cluster.simulator import MethodSpec, ServingSimulator
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(5)
    base = rng.integers(0, tiny_cfg.vocab_size, 48)
    other = rng.integers(0, tiny_cfg.vocab_size, 32)
    tok_a, tok_b, tok_c = base[:32], base[:48], other

    # live side: real manifests, capacity fits 2 of the 3 entries
    sizes = {}
    probe = _live_cluster(donor_kv, [tok_a, tok_b, tok_c])
    for key, e in probe.catalog.items():
        sizes[key] = e.stored_bytes
    cap = int(sorted(sizes.values())[-1] + sorted(sizes.values())[-2] + 1)
    live = StorageCluster([StorageNode("n0", capacity_bytes=cap,
                                       policy="lru")])
    for toks in (tok_a, tok_b, tok_c):
        kv_k, kv_v = donor_kv(toks)
        live.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                             resolutions=("240p",))
    keys = list(live.catalog)  # registration order: a, b, c
    eng = LiveEngine(tiny_params, tiny_cfg, live, resolution="240p")
    suffix = rng.integers(0, tiny_cfg.vocab_size, 8)
    # access order: c (hit), a (likely evicted), b, c — write-on-miss
    # re-admissions keep the pressure on
    for toks in (tok_c, tok_a, tok_b, tok_c):
        eng.submit(np.concatenate([toks, suffix]),
                   reuse_prefix="by-tokens", reuse_tokens=len(toks),
                   max_new_tokens=2)
        eng.run()

    # simulator side: synthetic entries with the live sizes and parents
    sim_nodes = [StorageNode("n0", capacity_bytes=cap, policy="lru")]
    sim_cluster = StorageCluster(sim_nodes)
    for key in keys:
        src = live.catalog[key]
        sim_cluster.register(StoredPrefix(
            key=key, n_tokens=src.n_tokens,
            bytes_by_resolution={"240p": src.stored_bytes},
            raw_kv_bytes=src.raw_kv_bytes, parent=src.parent), 0.0)
    key_of = {len(tok_a): keys[0], len(tok_b): keys[1]}
    order = [keys[2], keys[0], keys[1], keys[2]]
    lens = [len(tok_c), len(tok_a), len(tok_b), len(tok_c)]
    reqs = [Request(rid=i, arrival=(i + 1) * 50.0,
                    prompt_len=lens[i] + 8, reuse_tokens=lens[i],
                    prefix=order[i], max_new_tokens=2)
            for i in range(4)]
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=False)
    sim = ServingSimulator(tiny_cfg, spec,
                           bandwidth=BandwidthTrace.constant(0.01),
                           storage=sim_cluster, chunk_tokens=16)
    sim.run(reqs, max_new_tokens=2)

    assert live.events == sim_cluster.events
    kinds = [e[0] for e in live.events]
    assert "miss" in kinds and "evict" in kinds, \
        "sequence exercised no pressure; test is vacuous"
    assert key_of  # silence unused (kept for debugging readability)
