"""End-to-end live integration: real model, real codec, real paged memory.

Covers the paper's "lossless accuracy" property at system level: a request
whose prefix KV is fetched+restored from the remote store must produce the
same generations as full prefill (up to the shared int8 quantization step).

Tiny-model fixtures (tiny_cfg / tiny_params / donor_kv / registered_store)
come from conftest.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.storage import KVStore
from repro.models import transformer as tf
from repro.serving import paged_model
from repro.serving.engine import LiveEngine
from repro.paged.cache import PagedKVCache


def test_paged_decode_matches_dense_decode(tiny_cfg, tiny_params):
    """Paged decode path == dense-cache decode path on the same model."""
    CFG, PARAMS = tiny_cfg, tiny_params
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, CFG.vocab_size, 24)
    cache = PagedKVCache(CFG, n_pages=64, page_size=8)
    cache.add_seq(0, 32)
    logits_p, kvs = paged_model.prefill_collect_kv(
        PARAMS, CFG, jnp.asarray(tokens[None]))
    for layer, (k, v) in enumerate(kvs):
        cache.write_prefill(layer, 0, k[0], v[0])
    # dense reference
    dense_cache = tf.init_cache(CFG, 1, 32)
    logits_d, dense_cache = tf.prefill(PARAMS, CFG,
                                       tokens=jnp.asarray(tokens[None]),
                                       cache=dense_cache)
    np.testing.assert_allclose(np.asarray(logits_p[0]),
                               np.asarray(logits_d[0, 0]), rtol=2e-4,
                               atol=2e-4)
    nxt = int(jnp.argmax(logits_p[0]))
    lp = paged_model.decode_paged(PARAMS, CFG, jnp.asarray([nxt]),
                                  jnp.asarray([24]), cache, [0])
    ld, _ = tf.decode_step(PARAMS, CFG, jnp.asarray([nxt]), jnp.int32(24),
                           dense_cache)
    np.testing.assert_allclose(np.asarray(lp[0]), np.asarray(ld[0]),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["kvfetcher", "fetch_agnostic"])
def test_engine_reuse_matches_full_prefill(policy, tiny_cfg, tiny_params,
                                           registered_store):
    CFG, PARAMS = tiny_cfg, tiny_params
    rng = np.random.default_rng(1)
    prefix_tokens = rng.integers(0, CFG.vocab_size, 48)
    suffix_tokens = rng.integers(0, CFG.vocab_size, 8)
    full = np.concatenate([prefix_tokens, suffix_tokens])

    store, key = registered_store(prefix_tokens)

    # engine A: no reuse
    eng_a = LiveEngine(PARAMS, CFG, KVStore(), policy=policy)
    ra = eng_a.submit(full, max_new_tokens=4)
    eng_a.run()
    # engine B: prefix fetched from the store
    eng_b = LiveEngine(PARAMS, CFG, store, policy=policy)
    rb = eng_b.submit(full, reuse_prefix=key, reuse_tokens=48,
                      max_new_tokens=4)
    eng_b.run()

    assert ra.t_first_token is not None and rb.t_first_token is not None
    assert eng_b.stats.restored_tokens == 48 * 2  # k and v
    assert eng_b.stats.fetched_bytes > 0
    # "lossless" at the system level: identical generations
    assert eng_a.outputs[ra.rid] == eng_b.outputs[rb.rid]
    # frame-wise restoration buffer stays tiny (paper Fig. 24)
    assert eng_b.stats.restore_buffer_high_water < 1_000_000


@pytest.mark.slow
def test_engine_mixed_batch_no_interference(tiny_cfg, tiny_params,
                                            registered_store):
    """A fetching request must not delay non-reuse requests (kvfetcher)."""
    CFG, PARAMS = tiny_cfg, tiny_params
    rng = np.random.default_rng(2)
    prefix_tokens = rng.integers(0, CFG.vocab_size, 32)
    store, key = registered_store(prefix_tokens)
    eng = LiveEngine(PARAMS, CFG, store, policy="kvfetcher", max_running=4)
    rng2 = np.random.default_rng(3)
    r_fetch = eng.submit(np.concatenate([prefix_tokens,
                                         rng2.integers(0, CFG.vocab_size,
                                                       4)]),
                         reuse_prefix=key, reuse_tokens=32,
                         max_new_tokens=2)
    r_plain = eng.submit(rng2.integers(0, CFG.vocab_size, 16),
                         max_new_tokens=2)
    eng.run()
    assert r_plain.t_first_token is not None
    assert r_fetch.t_first_token is not None
    assert len(eng.finished) == 2
