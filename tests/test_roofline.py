"""Roofline analysis unit tests: HLO parsers + term math on a small
locally-compiled module."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline.analysis import (
    collective_bytes_from_hlo, hlo_bytes_split, model_flops,
    roofline_report,
)

SAMPLE_HLO = """
HloModule jit_fn

%region_body.1 (arg: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups={}
  %ag = f32[16,128]{1,0} all-gather(%y), dimensions={0}
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128] parameter(0)
  %w = f32[8,128]{1,0} while(%init), body=%region_body.1, condition=%c
  %cp = f32[4,64]{1,0} collective-permute(%a), source_target_pairs={{0,1}}
  ROOT %r = f32[8,128]{1,0} add(%a, %a)
}
"""


def test_collective_parser_splits_loop_membership():
    out = collective_bytes_from_hlo(SAMPLE_HLO)
    ar = 8 * 128 * 4
    ag = 16 * 128 * 4
    cp = 4 * 64 * 4
    assert out["all-reduce"] == ar
    assert out["all-gather"] == ag
    assert out["collective-permute"] == cp
    assert out["in_loop"] == ar + ag  # body collectives
    assert out["outside"] == cp
    assert out["counts"]["all-reduce"] == 1


def test_bytes_split_excludes_free_ops():
    out = hlo_bytes_split(SAMPLE_HLO)
    # in-loop: only the two collectives' results count (parameter is free)
    assert out["bytes_in_loop"] == 2 * (8 * 128 * 4 + 16 * 128 * 4)
    # outside: collective-permute + ROOT add (while/parameter free)
    assert out["bytes_outside"] == 2 * (4 * 64 * 4 + 8 * 128 * 4)


def test_roofline_terms_and_dominance():
    cfg = get_config("yi-9b")
    shape = INPUT_SHAPES["train_4k"]
    cost = {"flops": 1e12, "bytes accessed": 1e12}
    coll = {"total": 1e9, "in_loop": 1e9, "outside": 0.0}
    rep = roofline_report(cfg, shape, cost, coll, 256, scan_trips=10,
                          bytes_split={"bytes_in_loop": 1e11,
                                       "bytes_outside": 5e10})
    # compute term = max(corrected HLO, analytic floor)
    from repro.roofline.analysis import analytic_flops
    expect = max(1e13, analytic_flops(cfg, shape) / 256) / 197e12
    assert rep["compute_s"] == expect
    assert rep["memory_s"] == (1e12 + 5e10) / 819e9
    assert rep["collective_s"] == 1e10 / 50e9
    terms = {k: rep[k] for k in ("compute_s", "memory_s", "collective_s")}
    assert rep["dominant"] == max(terms, key=terms.get)
    assert rep["model_flops_total"] == 6.0 * cfg.param_count(True) * \
        shape.global_batch * shape.seq_len


def test_model_flops_moe_uses_active_params():
    moe = get_config("mixtral-8x22b")
    dense_equiv = moe.param_count(active_only=False)
    active = moe.param_count(active_only=True)
    assert active < 0.4 * dense_equiv
    f = model_flops(moe, INPUT_SHAPES["decode_32k"])
    assert f == 2.0 * active * 128


def test_parser_on_real_compiled_module():
    """End-to-end: parse a genuinely compiled (1-device) module."""
    def f(x, w):
        return jnp.tanh(x @ w)
    x = jnp.ones((128, 256))
    w = jnp.ones((256, 128))
    comp = jax.jit(f).lower(x, w).compile()
    txt = comp.as_text()
    coll = collective_bytes_from_hlo(txt)
    assert coll["total"] == 0.0
    bs = hlo_bytes_split(txt)
    assert bs["bytes_outside"] > 0
    assert bs["bytes_in_loop"] == 0
