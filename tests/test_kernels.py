"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref oracle,
with hypothesis shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.kv_restore.ops import kv_restore
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.token_delta.ops import (
    token_delta_decode_frame, token_delta_encode,
)
from repro.core.prediction import ZIGZAG, UNZIGZAG


# ---------------------------------------------------------------------------
# kv_restore
# ---------------------------------------------------------------------------

@given(st.integers(1, 4), st.sampled_from([(2, 8), (4, 16), (8, 128)]),
       st.sampled_from([jnp.float32, jnp.bfloat16]),
       st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_kv_restore_matches_ref(n, hd_shape, dtype, seed):
    H, D = hd_shape
    rng = np.random.default_rng(seed)
    R = 12
    pages = jnp.asarray(rng.standard_normal((R, H, D)), dtype)
    q = jnp.asarray(rng.integers(0, 256, (n, H, D)), jnp.uint8)
    scales = jnp.asarray(rng.random(H) + 0.05, jnp.float32)
    # distinct slots in rows >= 1; one optional dropped token
    slots = rng.choice(np.arange(1, R), size=n, replace=False)
    if n > 1 and seed % 2:
        slots[-1] = -1
    slots = jnp.asarray(slots, jnp.int32)
    a = kv_restore(pages, q, scales, slots, use_kernel=True)
    b = kv_restore(pages, q, scales, slots, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------

@given(st.sampled_from([(8, 2, 16), (8, 8, 32), (4, 1, 128), (16, 4, 64)]),
       st.sampled_from([4, 8, 16]),
       st.integers(0, 4))
@settings(max_examples=20, deadline=None)
def test_paged_attention_matches_ref(hkd, ps, seed):
    H, K, hd = hkd
    rng = np.random.default_rng(seed)
    B, P, bps = 2, 9, 3
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((P, ps, K, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, ps, K, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, P, (B, bps)), jnp.int32)
    cl = jnp.asarray(rng.integers(1, bps * ps + 1, (B,)), jnp.int32)
    a = paged_attention(q, kp, vp, bt, cl, use_kernel=True)
    b = paged_attention(q, kp, vp, bt, cl, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5,
                               atol=3e-5)


def test_paged_attention_matches_dense_attention():
    """Paged result == plain attention over the logically ordered KV."""
    rng = np.random.default_rng(0)
    B, H, K, hd, ps, bps = 2, 4, 2, 16, 4, 4
    S = ps * bps
    k = rng.standard_normal((B, S, K, hd)).astype(np.float32)
    v = rng.standard_normal((B, S, K, hd)).astype(np.float32)
    q = rng.standard_normal((B, H, hd)).astype(np.float32)
    cl = np.array([S, S - 3], np.int32)
    # scatter into pages: seq b uses pages [b*bps .. b*bps+bps)
    P = B * bps
    kp = k.reshape(B * bps, ps, K, hd)
    vp = v.reshape(B * bps, ps, K, hd)
    bt = np.arange(P, dtype=np.int32).reshape(B, bps)
    out = paged_attention(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                          jnp.asarray(bt), jnp.asarray(cl), use_kernel=True)
    # dense reference
    g = H // K
    qg = q.reshape(B, K, g, hd)
    logits = np.einsum("bkgd,bskd->bkgs", qg, k) / np.sqrt(hd)
    mask = np.arange(S)[None] < cl[:, None]
    logits = np.where(mask[:, None, None], logits, -1e30)
    w = np.exp(logits - logits.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    expect = np.einsum("bkgs,bskd->bkgd", w, v).reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# token_delta
# ---------------------------------------------------------------------------

@given(st.integers(1, 5), st.sampled_from([(8, 128), (16, 256), (5, 77)]),
       st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_token_delta_encode_matches_ref(F, hw, seed):
    H, W = hw
    rng = np.random.default_rng(seed)
    video = jnp.asarray(rng.integers(0, 256, (F, H, W)), jnp.uint8)
    a = token_delta_encode(video, use_kernel=True)
    b = token_delta_encode(video, use_kernel=False)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@given(st.sampled_from([(8, 128), (3, 50)]), st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_token_delta_roundtrip(hw, seed):
    H, W = hw
    rng = np.random.default_rng(seed)
    video = jnp.asarray(rng.integers(0, 256, (4, H, W)), jnp.uint8)
    zres = token_delta_encode(video, use_kernel=True)
    prev = jnp.zeros((H, W), jnp.uint8)
    for f in range(4):
        frame = token_delta_decode_frame(prev, zres[f], use_kernel=True)
        assert np.array_equal(np.asarray(frame), np.asarray(video[f]))
        prev = frame


def test_zigzag_kernel_matches_lut():
    from repro.kernels.token_delta.token_delta import _unzigzag, _zigzag
    allb = jnp.arange(256, dtype=jnp.uint8)
    assert np.array_equal(np.asarray(_zigzag(allb)), ZIGZAG)
    assert np.array_equal(np.asarray(_unzigzag(allb)), UNZIGZAG)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@given(st.sampled_from([(1, 32, 2, 8, 1, 4), (2, 64, 4, 16, 2, 8),
                        (1, 100, 2, 8, 1, 4)]),
       st.integers(0, 3))
@settings(max_examples=12, deadline=None)
def test_ssd_scan_matches_ref(shape, seed):
    b, s, nh, hd, G, S = shape
    rng = np.random.default_rng(seed)
    xdt = jnp.asarray(rng.standard_normal((b, s, nh, hd)) * 0.3, jnp.float32)
    a_log = jnp.asarray(-np.abs(rng.standard_normal((b, s, nh))) * 0.1,
                        jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, s, G, S)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, s, G, S)) * 0.3, jnp.float32)
    y_k, st_k = ssd_scan(xdt, a_log, Bm, Cm, chunk=32, use_kernel=True)
    y_r, st_r = ssd_scan(xdt, a_log, Bm, Cm, chunk=32, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=2e-4, atol=2e-4)
