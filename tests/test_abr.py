"""ABR adaptive resolution selection (ISSUE 7).

Three layers:

  * property tests of Alg. 1 `select_resolution` under the ABR objective
    (minimum total pipelined time) against a brute-force argmin oracle,
    over randomized bandwidth / decode-table / chunk-size inputs — run
    through the offline `_hypothesis_compat` seed bank;
  * controller-level unit tests of the mid-fetch down-switch machinery:
    flow join, slow-start ramp epoch, and confirmed-loss collapse each
    emit a deterministic ``resolution_switch`` event and re-aim only the
    *remaining* chunks (retransmits keep their chosen blob; up-switches
    wait for a chunk boundary);
  * a cross-environment determinism test (slow): a scripted mid-fetch
    bandwidth collapse (flow join + correlated GE loss burst) replays
    the identical ``resolution_switch`` sequence through the analytic
    simulator and the virtual-clock live engine.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.adaptive import (GBPS, H20_TABLE, DecodeTable,
                                 pipelined_time, select_resolution)
from repro.core.fetch import synthetic_plan
from repro.core.fetch_controller import (FetchController, FetchHooks,
                                         PipelineConfig)
from repro.core.layout import RESOLUTION_ORDER
from repro.core.scheduler import FetchingAwareScheduler, Request
from repro.cluster.decodepool import DecodePool
from repro.cluster.network import BandwidthTrace, LossModel, make_link

ORDER = list(RESOLUTION_ORDER)

#: toy ladder over a 75 kB/s link (trace 0.0006 Gbps): at full share
#: 1080p wins (decode-bound, 0.0533s), at half share 240p wins
#: (0.06s + 0.01 switch < 0.1067s) — the knife edge every down-switch
#: test sits on.  n_decoders=1 pins the selector's pool-drain model to
#: the plain serial latencies, so the thresholds above are exact.
TOY = DecodeTable(
    name="abr-toy", n_decoders=1,
    latency={"240p": (0.06,), "480p": (0.055,), "1080p": (0.03,)},
    penalty={"240p": 0.01, "480p": 0.008, "1080p": 0.0},
    chunk_size_mb={"240p": 0.002, "480p": 0.0035, "1080p": 0.004})

TOY_RES = ("240p", "480p", "1080p")
TRACE_GBPS = 0.0006  # 75 kB/s


def _rand_table(lats, pens, sizes_mb):
    return DecodeTable(
        name="rand", n_decoders=8,
        latency={r: (lat,) for r, lat in zip(ORDER, lats)},
        penalty=dict(zip(ORDER, pens)),
        chunk_size_mb=dict(zip(ORDER, sizes_mb)))


# ---------------------------------------------------------------------------
# Alg. 1 property tests: brute-force argmin oracle
# ---------------------------------------------------------------------------

@given(st.floats(0.05, 100.0), st.integers(0, 7),
       st.lists(st.floats(0.01, 2.0), min_size=4, max_size=4),
       st.lists(st.floats(0.0, 0.2), min_size=4, max_size=4),
       st.lists(st.floats(1.0, 400.0), min_size=4, max_size=4))
@settings(max_examples=40, deadline=None)
def test_select_matches_bruteforce_argmin(gbps, load, lats, pens, sizes_mb):
    """The chosen resolution is always the brute-force argmin of total
    pipelined time (first wins on exact ties), with or without an
    active resolution charging switch penalties."""
    table = _rand_table(lats, pens, sizes_mb)
    for active in (None,) + tuple(ORDER):
        res, t = select_resolution(gbps * GBPS, load, table,
                                   active_resolution=active)
        times = [pipelined_time(gbps * GBPS, load, table, r,
                                active_resolution=active) for r in ORDER]
        best_t = min(times)
        brute = ORDER[times.index(best_t)]  # first wins on ties
        assert res == brute, (res, brute, times)
        assert t == pytest.approx(best_t)


@given(st.floats(0.05, 100.0), st.integers(0, 7),
       st.sampled_from(ORDER),
       st.lists(st.floats(0.01, 2.0), min_size=4, max_size=4),
       st.lists(st.floats(0.0, 0.2), min_size=4, max_size=4),
       st.lists(st.floats(1.0, 400.0), min_size=4, max_size=4))
@settings(max_examples=40, deadline=None)
def test_switch_penalty_never_beats_staying(gbps, load, active, lats,
                                            pens, sizes_mb):
    """The sticky selection is sane: its total is never worse than just
    staying on ``active`` (staying is penalty-free and always a
    candidate), and never worse than the penalty-blind oracle's pick
    plus the switch penalty that pick would actually cost."""
    table = _rand_table(lats, pens, sizes_mb)
    res, t = select_resolution(gbps * GBPS, load, table,
                               active_resolution=active)
    stay = pipelined_time(gbps * GBPS, load, table, active,
                          active_resolution=active)
    assert t <= stay + 1e-9
    oracle, t_oracle = select_resolution(gbps * GBPS, load, table)
    pen = table.penalty[oracle] if oracle != active else 0.0
    assert t <= t_oracle + pen + 1e-9


# ---------------------------------------------------------------------------
# controller: mid-fetch down-switching
# ---------------------------------------------------------------------------

def _abr_setup(*, loss=None, ramp=None, reuse=30_000, link=None):
    """One adaptive fetch over the toy ladder on a 75 kB/s link."""
    sched = FetchingAwareScheduler("kvfetcher", max_running=4)
    req = Request(rid=0, arrival=0.0, prompt_len=reuse + 2_000,
                  reuse_tokens=reuse, prefix="p")
    sched.submit(req, 0.0)
    sched.schedule(0.0)
    (fr,) = sched.take_fetches()
    lnk = link if link is not None else make_link(
        BandwidthTrace.constant(TRACE_GBPS), loss=loss, ramp=ramp)
    ctrl = FetchController(
        sched, lnk, table=TOY, pool=DecodePool(TOY),
        config=PipelineConfig(adaptive=True, use_table_sizes=True,
                              resolutions=TOY_RES,
                              layerwise_admission=False))
    plan = synthetic_plan(0, reuse, 9, 10_000)
    return sched, fr, plan, ctrl, lnk


def _down(ev):
    return ORDER.index(ev[3]) < ORDER.index(ev[2])


def test_flow_join_downswitches_remaining_chunks():
    sched, req, plan, ctrl, link = _abr_setup()

    def join(t):
        link.open_flow(-5, t=t)
        # the joiner actually transmits, so it holds its half for the
        # rest of the fetch (an idle flow would leave the wire alone)
        link.submit(-5, 5_000_000, t, lambda tt: None)

    ctrl.push_event(0.3, join)
    ctrl.start(req, plan, 0.0)
    ctrl.pump(float("inf"))
    assert plan.done and req.fetch_done is not None
    joins = [ev for ev in ctrl.resolution_switches if ev[4] == "flow_join"]
    assert joins and all(_down(ev) for ev in joins), \
        ctrl.resolution_switches
    rid, seq, frm, to, _ = joins[0]
    assert (rid, frm, to) == (0, "1080p", "240p")
    # chunks sent before the collapse carry the high rung; everything
    # from the switch point on was re-aimed at the low one
    assert plan.chunks[0].resolution == "1080p"
    assert all(pc.resolution == "240p" for pc in plan.chunks[seq:])
    # the per-fetch log mirrors the controller-global one
    assert ctrl.active == {}
    assert joins[0] in ctrl.resolution_switches


def test_confirmed_loss_downswitches_but_retransmit_keeps_blob():
    """A confirmed drop is a collapse signal: the remaining chunks
    down-switch (reason "loss") while the dropped chunk's retransmit
    resends the blob already chosen — the resolution decision happened
    at first send."""
    loss = LossModel.scripted({(0, 2, 1)})
    sched, req, plan, ctrl, link = _abr_setup(loss=loss, reuse=10_000)
    ctrl.start(req, plan, 0.0)
    ctrl.pump(float("inf"))
    assert plan.done and req.fetch_done is not None
    assert ctrl.retransmits_total == 1
    losses = [ev for ev in ctrl.resolution_switches if ev[4] == "loss"]
    assert losses and all(_down(ev) for ev in losses)
    # the dropped chunk itself was chosen at 1080p and retransmitted
    # at 1080p (attempts=2), never re-encoded mid-flight
    assert plan.chunks[2].attempts == 2
    assert plan.chunks[2].resolution == "1080p"
    # chunks planned after the collapse ride the down-switched rung
    seq = losses[0][1]
    assert plan.chunks[seq].resolution == "240p"


def test_ramp_epoch_downswitches_incumbent():
    """On a slow-start link the incumbent climbs the ladder as its own
    ramp opens ("estimate" up-switch at a chunk boundary), then a
    slow-start joiner's ramp epochs erode its share step by step
    (0.9375 -> 0.875 -> 0.75 -> 0.5 of the link): each epoch that
    crosses a knife edge emits a deterministic "ramp_epoch"
    down-switch, staging the flow back down the ladder."""
    link = make_link(BandwidthTrace.constant(TRACE_GBPS), ramp="slowstart")
    # long fetch: the joiner's ramp doubles every 0.5s, so the fetch
    # must still be in flight when the eroding epochs fire
    sched, req, plan, ctrl, link = _abr_setup(link=link, reuse=150_000)

    def join(t):
        link.open_flow(-5, t=t)
        link.submit(-5, 5_000_000, t, lambda tt: None)

    # join after the incumbent has fully ramped and up-switched
    ctrl.push_event(2.0, join)
    ctrl.start(req, plan, 0.0)
    ctrl.pump(float("inf"))
    assert plan.done and req.fetch_done is not None
    ramps = [ev for ev in ctrl.resolution_switches
             if ev[4] == "ramp_epoch"]
    assert ramps and all(_down(ev) for ev in ramps), \
        ctrl.resolution_switches
    # the join itself only cost ramp_init/2 of the share — not enough
    # to switch; the collapse came from the later ramp epochs
    assert not [ev for ev in ctrl.resolution_switches
                if ev[4] == "flow_join"]
    # the incumbent's own ramp produced a boundary up-switch first
    ups = [ev for ev in ctrl.resolution_switches if not _down(ev)]
    assert ups and all(ev[4] == "estimate" for ev in ups)
    # staged collapse ends on the lowest rung
    assert plan.chunks[-1].resolution == "240p"


def test_upswitch_waits_for_chunk_boundary():
    """Share recovery (the contending flow leaves) never interrupts the
    remaining chunks mid-flight: the up-switch happens at a later chunk
    boundary as a plain "estimate" re-selection, once the smoothed
    service-time view has caught up with the freed link."""
    link = make_link(BandwidthTrace.constant(TRACE_GBPS))
    # controller first: it binds the link's event queue; then the
    # contending flow claims its half before the fetch starts
    sched, req, plan, ctrl, link = _abr_setup(link=link, reuse=60_000)
    link.open_flow(-5, t=0.0)
    link.submit(-5, 7_000, 0.0, lambda t: link.close_flow(-5, t))
    ctrl.start(req, plan, 0.0)
    ctrl.pump(float("inf"))
    assert plan.done and req.fetch_done is not None
    # contended start: the first chunk went out on the low rung
    assert plan.chunks[0].resolution == "240p"
    ups = [ev for ev in ctrl.resolution_switches if not _down(ev)]
    assert ups, ctrl.resolution_switches
    assert all(ev[4] == "estimate" for ev in ups)
    # structural signals only ever produce down-switches
    assert all(_down(ev) for ev in ctrl.resolution_switches
               if ev[4] != "estimate")
    # the fetch ends back on the high rung
    assert plan.chunks[-1].resolution == "1080p"


def test_start_resolutions_restricts_selection():
    """``start(resolutions=...)`` (the storage tier's resident-rung set)
    caps the ladder: with only 240p resident, every chunk ships 240p
    even though the link would carry 1080p."""
    sched, req, plan, ctrl, _ = _abr_setup(reuse=10_000)
    ctrl.start(req, plan, 0.0, resolutions=("240p",))
    ctrl.pump(float("inf"))
    assert plan.done
    assert all(pc.resolution == "240p" for pc in plan.chunks)
    assert not ctrl.resolution_switches


# ---------------------------------------------------------------------------
# cross-environment determinism (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_resolution_switches_identical_in_simulator_and_live_engine(
        tiny_cfg, tiny_params, registered_store):
    """ISSUE 7 acceptance: a scripted mid-fetch bandwidth collapse —
    a flow joining the link at t=0.15 plus a correlated Gilbert-Elliott
    loss burst — produces the *identical* ``resolution_switch`` event
    sequence in the analytic simulator and the virtual-clock live
    engine.  Both model Appx A.2-style table chunk sizes over the same
    link (``use_table_sizes``), making their wire timelines
    byte-identical; every selection input (SRTT service times, link
    share, outstanding losses, pool load) is then a pure function of
    those timings, so the timestamp-free event tuples must match."""
    from repro.cluster.simulator import MethodSpec, ServingSimulator
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(5)
    prefix = rng.integers(0, tiny_cfg.vocab_size, 48)
    full = np.concatenate([prefix, rng.integers(0, tiny_cfg.vocab_size, 8)])
    store, key = registered_store(prefix, tokens_per_chunk=16,
                                  resolutions=TOY_RES)
    table = DecodeTable(
        name="abr-xenv", n_decoders=1,
        latency=TOY.latency, penalty=TOY.penalty,
        chunk_size_mb=TOY.chunk_size_mb)
    trace = BandwidthTrace.constant(TRACE_GBPS)

    def corr():
        return LossModel.correlated(seed=31, slot=0.08, good_to_bad=0.35,
                                    bad_to_good=0.4, p_good=0.0,
                                    p_bad=0.85)

    def scripted_join(ctrl):
        link = ctrl.link

        def join(t):
            link.open_flow(-5, t=t)
            link.submit(-5, 50_000, t, lambda tt: None)

        ctrl.push_event(0.15, join)

    eng = LiveEngine(tiny_params, tiny_cfg, store, policy="kvfetcher",
                     fetch_mode="async", bandwidth=trace, loss=corr(),
                     decode_table=table, use_table_sizes=True,
                     resolution="240p", resolutions=TOY_RES)
    scripted_join(eng.ctrl)
    r = eng.submit(full, reuse_prefix=key, reuse_tokens=48,
                   max_new_tokens=2)
    eng.run()
    assert r.rid == 0 and r.fetch_done is not None

    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=True,
                      uses_decode_pool=True, use_table_sizes=True,
                      layerwise_admission=True, resolutions=TOY_RES)
    sim = ServingSimulator(tiny_cfg, spec, bandwidth=trace, loss=corr(),
                           table=table, chunk_tokens=16)
    scripted_join(sim.ctrl)
    req = Request(rid=0, arrival=0.0, prompt_len=56, reuse_tokens=48,
                  prefix="p")
    res = sim.run([req], max_new_tokens=2)
    assert req.fetch_done is not None

    assert eng.ctrl.resolution_switches, \
        "the collapse never produced a switch; test is vacuous"
    assert eng.ctrl.resolution_switches == sim.ctrl.resolution_switches
    assert res.resolution_switches == sim.ctrl.resolution_switches
    # the scripted collapse shows up as structural down-switches
    structural = [ev for ev in eng.ctrl.resolution_switches
                  if ev[4] in ("flow_join", "loss", "ramp_epoch")]
    assert structural and all(_down(ev) for ev in structural)
