"""Offline fallback for `hypothesis`.

When the real package is installed it is re-exported untouched.  When it
is missing (this repo's tier-1 suite must collect and pass fully
offline) a small shim replays a deterministic, seeded bank of example
cases through the same ``@given(...)`` signatures: the first example of
every test is the minimal one (empty binary, min integer, shortest
list — the classic shrink targets), the rest are drawn from a
``numpy`` generator seeded from the test's name, so failures reproduce
across runs and machines.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in offline CI
    HAVE_HYPOTHESIS = False

    import functools
    import hashlib

    import numpy as np

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, sample, minimal):
            self._sample = sample
            self._minimal = minimal

        def sample(self, rng):
            return self._sample(rng)

        def minimal(self):
            return self._minimal()

    class _Strategies:
        """The subset of `hypothesis.strategies` this repo uses."""

        @staticmethod
        def binary(min_size=0, max_size=64):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return rng.integers(0, 256, n, dtype=np.uint8).tobytes()

            return _Strategy(sample, lambda: b"\x00" * min_size)

        @staticmethod
        def sampled_from(elements):
            opts = list(elements)

            def sample(rng):
                return opts[int(rng.integers(len(opts)))]

            return _Strategy(sample, lambda: opts[0])

        @staticmethod
        def integers(min_value, max_value):
            def sample(rng):
                return int(rng.integers(min_value, max_value + 1))

            return _Strategy(sample, lambda: min_value)

        @staticmethod
        def floats(min_value, max_value):
            def sample(rng):
                return float(rng.uniform(min_value, max_value))

            return _Strategy(sample, lambda: float(min_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(n)]

            def minimal():
                return [elements.minimal() for _ in range(min_size)]

            return _Strategy(sample, minimal)

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _DEFAULT_EXAMPLES))
                digest = hashlib.sha256(fn.__name__.encode()).digest()
                rng = np.random.default_rng(
                    int.from_bytes(digest[:8], "little"))
                fn(*(s.minimal() for s in strategies))
                for _ in range(max(n - 1, 0)):
                    fn(*(s.sample(rng) for s in strategies))

            # pytest's signature introspection follows __wrapped__ and
            # would mistake the example arguments for fixtures
            del wrapper.__wrapped__
            return wrapper

        return deco
