"""Speculative prefix prefetch + host staging tier (ISSUE 6 surface).

Unit tests cover the trie predictor (popularity + session-continuation
heat), mispredict-budget enforcement (``budget_reject``; earned entries
evict free), speculative-flow cancellation under demand pressure
(byte-accurate waste accounting, heal-weight contract), and host-tier
eviction under capacity.  Integration tests drive the analytic
simulator over a session-continuation trace (warm hits resolve from
host DRAM) and replay a prefetch-then-hit trace through the simulator
AND the real live engine, asserting the cluster and prefetcher event
sequences agree.
"""
import heapq

import numpy as np
import pytest

from repro.cluster.network import HEAL_WEIGHT, BandwidthTrace, SharedLink
from repro.cluster.storage import StorageCluster, StorageNode, StoredPrefix
from repro.cluster.staging import (PCIE_H2D_GBPS, PREFETCH_WEIGHT,
                                   HostStagingTier, PrefetchManager)
from repro.core.scheduler import Request
from repro.data.workload import prefix_trie_specs, session_trace

MB = 1_000_000


def _entry(key, n_tokens=1000, size=10 * MB, parent=None):
    return StoredPrefix(key=key, n_tokens=n_tokens,
                        bytes_by_resolution={"240p": size},
                        raw_kv_bytes=8 * size, parent=parent)


def _cluster(entries, *, gbps=None, **kw):
    link = None if gbps is None else BandwidthTrace.constant(gbps)
    cluster = StorageCluster([StorageNode("n0", link=link)], **kw)
    for e in entries:
        cluster.register(e, 0.0)
    return cluster


def _queue():
    """A minimal virtual event queue (heap) shaped like the fetch
    controller's ``push_event``; returns (push, pump)."""
    ev, seq = [], iter(range(1 << 20))

    def push(t, fn):
        heapq.heappush(ev, (t, next(seq), fn))

    def pump(until):
        while ev and ev[0][0] <= until:
            t, _, fn = heapq.heappop(ev)
            fn(t)

    return push, pump


# ---------------------------------------------------------------------------
# predictor
# ---------------------------------------------------------------------------

def test_predictor_heats_children_on_parent_hit():
    """Session-continuation term: one demand hit on P pushes P's
    cataloged children over the threshold before P itself."""
    parent, child = _entry("p"), _entry("p.c", parent="p")
    pf = PrefetchManager(_cluster([parent, child]),
                         HostStagingTier(None), transport="sync")
    assert pf.predictions() == []
    pf.observe("p", 0.0)
    assert pf.heat["p"] == 1.0
    assert pf.heat["p.c"] == pf.continuation_boost
    assert pf.predictions() == ["p.c"]  # child hot, parent not yet
    pf.observe("p", 1.0)
    assert set(pf.predictions()) == {"p", "p.c"}
    assert pf.predictions()[0] == "p.c"  # hottest first


def test_predictions_skip_staged_and_unknown_keys():
    parent, child = _entry("p"), _entry("p.c", parent="p")
    pf = PrefetchManager(_cluster([parent, child]),
                         HostStagingTier(None), transport="sync")
    pf.observe("nonexistent", 0.0)  # heats nothing cataloged
    assert pf.predictions() == []
    pf.observe("p", 0.0)
    assert pf.tick(0.0) is None
    assert pf.staging.contains("p.c")
    assert pf.predictions() == []  # staged keys leave the candidate set
    assert pf.events == [("prefetch_start", "p.c"),
                         ("prefetch_done", "p.c")]


# ---------------------------------------------------------------------------
# mispredict budget
# ---------------------------------------------------------------------------

def test_mispredict_budget_blocks_new_speculation():
    """Unearned evictions charge the budget; once exhausted, new
    speculation is declined with ``budget_reject``."""
    entries = [_entry(k, size=10 * MB) for k in ("a", "b", "c", "d")]
    cluster = _cluster(entries)
    pf = PrefetchManager(cluster, HostStagingTier(10 * MB),
                         transport="sync",
                         mispredict_budget_bytes=15 * MB)
    assert pf.request_prefetch("a", 0.0)   # staged, waste 0
    assert pf.request_prefetch("b", 1.0)   # evicts a: waste 10 MB < 15
    assert pf.wasted_bytes == 10 * MB
    assert ("stage_evict", "a") in pf.events
    assert pf.request_prefetch("c", 2.0)   # evicts b: waste 20 MB >= 15
    assert pf.wasted_bytes == 20 * MB
    assert not pf.request_prefetch("d", 3.0)
    assert pf.events[-1] == ("budget_reject", "d")
    assert not pf.staging.contains("d")


def test_earned_entries_evict_free():
    """An entry that served a host hit is earned: its eviction charges
    nothing, so good predictions never exhaust the budget."""
    entries = [_entry(k, size=10 * MB) for k in ("a", "b", "c")]
    cluster = _cluster(entries)
    pf = PrefetchManager(cluster, HostStagingTier(10 * MB),
                         transport="sync",
                         mispredict_budget_bytes=5 * MB)
    assert pf.request_prefetch("a", 0.0)
    hit = pf.host_lookup("a", entries[0].n_tokens, 1.0)
    assert hit is not None and hit.key == "a"
    assert pf.host_hits == 1 and ("host_hit", "a") in pf.events
    assert pf.request_prefetch("b", 2.0)   # evicts earned a: free
    assert pf.wasted_bytes == 0.0
    assert ("stage_evict", "a") in pf.events
    # b never serves: its eviction exhausts the 5 MB budget
    assert pf.request_prefetch("c", 3.0)
    assert pf.wasted_bytes == 10 * MB
    assert not pf.request_prefetch("a", 4.0)
    assert pf.events[-1] == ("budget_reject", "a")


def test_host_lookup_requires_full_coverage():
    pf = PrefetchManager(_cluster([_entry("a", n_tokens=1000)]),
                         HostStagingTier(None), transport="sync")
    assert pf.request_prefetch("a", 0.0)
    assert pf.host_lookup("a", 2000, 1.0) is None  # staged < asked
    assert pf.host_lookup("missing", 10, 1.0) is None
    assert pf.host_hits == 0 and "a" not in pf._earned
    assert pf.host_lookup("a", 1000, 2.0) is not None


# ---------------------------------------------------------------------------
# link transport: weight contract, deferral, cancellation
# ---------------------------------------------------------------------------

def test_speculation_defers_while_demand_holds_the_link():
    """request_prefetch declines (without burning budget or logging
    noise) while any non-negative demand flow is open on the source."""
    cluster = _cluster([_entry("a")], gbps=0.008)
    push, pump = _queue()
    pf = PrefetchManager(cluster, HostStagingTier(None), transport="link")
    pf.bind(push)
    link = cluster.nodes[0].link
    link.bind(push)
    link.open_flow(7, t=0.0)  # a demand fetch (rid >= 0)
    assert not pf.request_prefetch("a", 0.0)
    assert pf.events == [] and pf.prefetches_started == 0
    link.close_flow(7)
    assert pf.request_prefetch("a", 1.0)
    assert pf.events == [("prefetch_start", "a")]


def test_demand_pressure_cancels_inflight_speculation():
    """A demand fetch arriving mid-speculation cancels the speculative
    flow; bytes already on the wire are charged byte-accurately, the
    flow closes, and the staging tier stays cold."""
    # 0.008 Gbps = 1 MB/s; the sole 10 MB speculation takes 10 s
    cluster = _cluster([_entry("a", size=10 * MB)], gbps=0.008)
    push, pump = _queue()
    pf = PrefetchManager(cluster, HostStagingTier(None), transport="link")
    pf.bind(push)
    assert pf.request_prefetch("a", 0.0)
    spec = pf._inflight["a"]
    link = cluster.nodes[0].link
    # weight contract: speculation joins at the heal weight, under a
    # far-negative flow id that cannot collide with rids or heal flows
    assert PREFETCH_WEIGHT == HEAL_WEIGHT
    assert link._weights[spec.flow] == PREFETCH_WEIGHT
    assert spec.flow < -999_999
    pump(4.0)  # nothing due yet: completion would land at t=10
    req = Request(rid=0, arrival=4.0, prompt_len=1000, reuse_tokens=0)
    pf.demand_started(req, link, 4.0)
    assert pf.events == [("prefetch_start", "a"), ("prefetch_cancel", "a")]
    assert pf.prefetches_cancelled == 1 and pf._inflight == {}
    assert pf.wasted_bytes == pytest.approx(4 * MB)  # 4 s at 1 MB/s
    assert spec.flow not in link._weights  # flow closed
    assert not pf.staging.contains("a")
    pump(20.0)  # the dead completion callback must not commit anything
    assert pf.prefetches_committed == 0
    assert not pf.staging.contains("a")


def test_demand_on_other_links_cancels_nothing():
    """Only the contended link's speculation is cancelled: demand on a
    different node's link — or resolved from the host tier itself —
    leaves speculation running."""
    cluster = _cluster([_entry("a", size=10 * MB)], gbps=0.008)
    push, pump = _queue()
    pf = PrefetchManager(cluster, HostStagingTier(None), transport="link")
    pf.bind(push)
    assert pf.request_prefetch("a", 0.0)
    other = SharedLink(BandwidthTrace.constant(1.0))
    req = Request(rid=0, arrival=1.0, prompt_len=1000, reuse_tokens=0)
    pf.demand_started(req, other, 1.0)        # different link
    pf.demand_started(req, pf.staging.link, 1.0)  # host-resolved fetch
    assert pf.prefetches_cancelled == 0 and "a" in pf._inflight
    pump(10.0)
    assert pf.staging.contains("a") and pf.prefetches_committed == 1


# ---------------------------------------------------------------------------
# host tier eviction
# ---------------------------------------------------------------------------

def test_host_tier_evicts_under_capacity_pressure():
    """The staging tier is a real capacity-bounded StorageNode: filling
    it evicts deterministically (LRU) with ``stage_evict`` events, and
    occupancy never exceeds capacity."""
    entries = [_entry(k, size=10 * MB) for k in ("a", "b", "c")]
    cluster = _cluster(entries)
    staging = HostStagingTier(20 * MB)
    pf = PrefetchManager(cluster, staging, transport="sync")
    for t, k in enumerate(("a", "b")):
        assert pf.request_prefetch(k, float(t))
    assert staging.used_bytes == 20 * MB
    pf.host_lookup("a", 1000, 5.0)  # refresh a: b becomes the LRU
    assert pf.request_prefetch("c", 6.0)
    assert ("stage_evict", "b") in pf.events
    assert staging.contains("a") and staging.contains("c")
    assert not staging.contains("b")
    assert staging.used_bytes <= 20 * MB


def test_oversized_entry_is_rejected_not_committed():
    cluster = _cluster([_entry("big", size=30 * MB)])
    pf = PrefetchManager(cluster, HostStagingTier(20 * MB),
                         transport="sync")
    assert pf.request_prefetch("big", 0.0)
    assert pf.events == [("prefetch_start", "big"),
                         ("stage_reject", "big")]
    assert pf.prefetches_committed == 0
    assert not pf.staging.contains("big")


# ---------------------------------------------------------------------------
# simulator integration: session trace -> warm host hits
# ---------------------------------------------------------------------------

def _sim_with_prefetch(specs, reqs, *, gbps=2.0, budget=None,
                       transport="link"):
    from repro.configs import get_config
    from repro.core.adaptive import H20_TABLE
    from repro.cluster.simulator import ServingSimulator, kvfetcher_spec

    cfg = get_config("yi-34b")
    ratios = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}
    from repro.cluster.storage import synthetic_stored_prefix
    entries = [synthetic_stored_prefix(
        s.key, s.n_tokens, raw_bytes_per_token=cfg.kv_bytes_per_token(),
        ratios=ratios, parent=s.parent) for s in specs]
    nodes = [StorageNode("n0", link=BandwidthTrace.constant(gbps))]
    cluster = StorageCluster(nodes)
    for e in entries:
        cluster.register(e, 0.0)
    pf = PrefetchManager(cluster, HostStagingTier(None),
                         transport=transport,
                         mispredict_budget_bytes=budget)
    sim = ServingSimulator(cfg, kvfetcher_spec(ratios), chip="h20",
                           n_chips=2,
                           bandwidth=BandwidthTrace.constant(gbps),
                           storage=cluster, table=H20_TABLE, prefetch=pf)
    res = sim.run(reqs, max_new_tokens=4)
    return res, cluster, pf


def test_sim_session_trace_serves_continuations_from_host():
    """End-to-end over the session-continuation workload: the parent's
    demand hit heats its child, the speculation lands between turns,
    and the continuation resolves from host DRAM — strictly faster than
    the same ask served cold over the WAN."""
    specs = prefix_trie_specs(2, 2, base_tokens=40_000,
                              ext_tokens=20_000)
    rng = np.random.default_rng(11)
    reqs = session_trace(rng, specs, n_sessions=3, continue_p=1.0,
                         session_gap=60.0, think_time=200.0,
                         max_new_tokens=4)
    assert len(reqs) >= 4
    res, cluster, pf = _sim_with_prefetch(specs, reqs)
    warm = [r for r in reqs if r.storage_hit == "host"]
    assert warm, "no continuation was served from the staging tier"
    assert pf.host_hits == len(warm)
    for r in warm:
        assert r.storage_node == "host"
        assert ("host_hit", r.prefix) in pf.events
    # the same child asked cold (first session turn hits remote)
    cold = [r for r in reqs if r.storage_hit == "full"
            and r.reuse_tokens == warm[0].reuse_tokens]
    if cold:
        assert min(r.ttft for r in warm) < min(r.ttft for r in cold)


def test_sim_prefetch_respects_budget_and_never_breaks_serving():
    """A zero mispredict budget shuts speculation down (budget_reject
    only, no staged entries) without perturbing demand serving."""
    specs = prefix_trie_specs(2, 2, base_tokens=40_000,
                              ext_tokens=20_000)
    rng = np.random.default_rng(11)
    reqs = session_trace(rng, specs, n_sessions=3, continue_p=1.0,
                         session_gap=60.0, think_time=200.0,
                         max_new_tokens=4)
    res, cluster, pf = _sim_with_prefetch(specs, reqs, budget=0)
    assert pf.prefetches_started == 0 and pf.host_hits == 0
    assert all(k == "budget_reject" for k, _ in pf.events)
    assert all(r.t_first_token is not None for r in reqs)
    assert all(r.storage_hit in ("full", "partial", "miss")
               for r in reqs)


# ---------------------------------------------------------------------------
# cross-environment event-sequence agreement
# ---------------------------------------------------------------------------

def test_cross_env_prefetch_then_hit_sequences_agree(tiny_cfg,
                                                     tiny_params,
                                                     donor_kv):
    """A prefetch-then-hit trace — parent demand hit heats the child,
    the sync speculation stages it, the child's ask resolves host-first
    — must replay the identical cluster AND prefetcher event sequences
    in the live engine (real manifests, wall clock) and the analytic
    simulator (synthetic entries, virtual clock)."""
    from repro.cluster.simulator import MethodSpec, ServingSimulator
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(7)
    tok_p = rng.integers(0, tiny_cfg.vocab_size, 32)
    tok_c = np.concatenate([tok_p,
                            rng.integers(0, tiny_cfg.vocab_size, 16)])
    suffix = rng.integers(0, tiny_cfg.vocab_size, 8)

    live = StorageCluster([StorageNode("n0")])
    for toks in (tok_p, tok_c):
        kv_k, kv_v = donor_kv(toks)
        live.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                             resolutions=("240p",))
    keys = list(live.catalog)  # [parent, child]; child extends parent
    assert live.catalog[keys[1]].parent == keys[0]
    live_pf = PrefetchManager(live, HostStagingTier(None),
                              transport="sync")
    eng = LiveEngine(tiny_params, tiny_cfg, live, resolution="240p",
                     prefetch=live_pf)
    for toks in (tok_p, tok_c):
        eng.submit(np.concatenate([toks, suffix]),
                   reuse_prefix="by-tokens", reuse_tokens=len(toks),
                   max_new_tokens=2)
        eng.run()

    sim_cluster = StorageCluster([StorageNode("n0")])
    for key in keys:
        src = live.catalog[key]
        sim_cluster.register(StoredPrefix(
            key=key, n_tokens=src.n_tokens,
            bytes_by_resolution={"240p": src.stored_bytes},
            raw_kv_bytes=src.raw_kv_bytes, parent=src.parent), 0.0)
    sim_pf = PrefetchManager(sim_cluster, HostStagingTier(None),
                             transport="sync")
    reqs = [Request(rid=i, arrival=(i + 1) * 50.0,
                    prompt_len=n_tok + 8, reuse_tokens=n_tok,
                    prefix=key, max_new_tokens=2)
            for i, (key, n_tok) in enumerate(
                zip(keys, (len(tok_p), len(tok_c))))]
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=False)
    sim = ServingSimulator(tiny_cfg, spec,
                           bandwidth=BandwidthTrace.constant(0.01),
                           storage=sim_cluster, chunk_tokens=16,
                           prefetch=sim_pf)
    sim.run(reqs, max_new_tokens=2)

    assert live.events == sim_cluster.events
    assert live_pf.events == sim_pf.events
    assert ("host_hit", keys[1]) in live_pf.events
    assert ("prefetch_done", keys[1]) in live_pf.events
    # the child's demand ask never touched the remote cluster
    assert not any(e[1] == keys[1] and e[0] in ("full", "partial", "miss")
                   for e in live.events)
    assert reqs[1].storage_hit == "host"
    assert live_pf.host_hits == sim_pf.host_hits == 1
