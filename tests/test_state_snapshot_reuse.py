"""Arch-applicability (DESIGN.md): for attention-free SSM architectures,
remote prefix reuse degenerates to recurrent-state snapshot transfer.
This test proves the full path: donor prefill -> snapshot encode (codec)
-> decode -> continuation matches the donor's continuation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.chunks import decode_state_snapshot, encode_state_snapshot
from repro.models import transformer as tf

CFG = reduce_config(get_config("mamba2-2.7b"))


def _flatten_cache(cache):
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    for path, leaf in leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[name] = np.asarray(leaf, np.float32)
    return flat


def test_mamba2_prefix_reuse_via_state_snapshot():
    params = tf.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, CFG.vocab_size, 40)
    nxt_tok = int(rng.integers(0, CFG.vocab_size))

    # donor: prefill the prefix, keep its recurrent state
    logits, cache = tf.prefill(params, CFG,
                               tokens=jnp.asarray(prefix[None]))
    donor_logits, _ = tf.decode_step(params, CFG,
                                     jnp.asarray([nxt_tok]),
                                     jnp.int32(40), cache)

    # remote: snapshot -> encode -> decode -> rebuild cache
    flat = _flatten_cache(cache)
    blob = encode_state_snapshot(flat)
    assert len(blob) < sum(v.nbytes for v in flat.values())  # compresses
    back = decode_state_snapshot(blob)
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    paths = jax.tree_util.tree_flatten_with_path(cache)[0]
    rebuilt_leaves = []
    for (path, leaf) in paths:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        rebuilt_leaves.append(jnp.asarray(back[name], leaf.dtype))
    rebuilt = jax.tree_util.tree_unflatten(treedef, rebuilt_leaves)

    got_logits, _ = tf.decode_step(params, CFG, jnp.asarray([nxt_tok]),
                                   jnp.int32(40), rebuilt)
    # int8 state quantization -> small logit perturbation, same argmax
    assert int(jnp.argmax(got_logits)) == int(jnp.argmax(donor_logits))
    err = float(jnp.abs(got_logits - donor_logits).max())
    scale = float(jnp.abs(donor_logits).max())
    assert err < 0.1 * scale, (err, scale)
