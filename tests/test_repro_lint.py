"""Determinism linter (tools/repro_lint, ISSUE 10 surface).

Three layers:

  * fixture tests — for every shipped rule, a minimal snippet proving
    it fires at the right ``(file, line)`` and that its inline pragma
    (``# repro-lint: allow(<rule>)``) silences exactly that rule;
  * the tier-1 gate — the analyzer over the REAL tree
    (``src tests benchmarks tools``) must exit clean, so a future
    replay-contract violation fails this test before it fails CI;
  * order-stability regressions — the set-typed replay state the
    linter flagged (storage ``_pending_recompute``, fleet dispatch
    rescheduling, fairness ``_served``) is now insertion-ordered, and
    the event sequences those drains feed replay identically across
    runs.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from tools.repro_lint import RULES, run_paths

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MB = 1_000_000


def lint(tmp_path, files, **kw):
    """Write ``{relpath: source}`` fixtures under ``tmp_path`` (posix
    relpaths, auto-dedented) and lint them rooted there."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_paths(sorted(files), root=str(tmp_path), **kw)


def keyed(diags):
    return [(d.path, d.line, d.rule) for d in diags]


# ---------------------------------------------------------------------------
# registry + engine basics
# ---------------------------------------------------------------------------

def test_all_six_rules_registered():
    assert set(RULES) == {
        "no-wall-clock", "seeded-rng", "ordered-iteration",
        "timestamp-free-events", "hypothesis-via-shim",
        "cross-env-parity"}
    for name, rule in RULES.items():
        assert rule.name == name and rule.summary


def test_parse_error_becomes_diagnostic(tmp_path):
    diags = lint(tmp_path, {"src/bad.py": "def f(:\n"})
    assert keyed(diags) == [("src/bad.py", 1, "parse-error")]


def test_diagnostics_are_stably_ordered(tmp_path):
    files = {
        "src/repro/b.py": """\
            import time


            def f():
                return time.time(), time.monotonic()
            """,
        "src/repro/a.py": """\
            import time


            def g():
                return time.perf_counter()
            """,
    }
    d1 = lint(tmp_path, files)
    d2 = run_paths(["src"], root=str(tmp_path))
    assert [str(d) for d in d1] == [str(d) for d in d2]
    assert d1 == sorted(d1, key=lambda d: d.sort_key())
    assert keyed(d1) == [("src/repro/a.py", 5, "no-wall-clock"),
                         ("src/repro/b.py", 5, "no-wall-clock"),
                         ("src/repro/b.py", 5, "no-wall-clock")]


def test_pragma_for_a_different_rule_does_not_suppress(tmp_path):
    diags = lint(tmp_path, {"src/x.py": """\
        import time

        t = time.time()  # repro-lint: allow(seeded-rng)
        """, }, select=["no-wall-clock"])
    assert keyed(diags) == [("src/x.py", 3, "no-wall-clock")]


# ---------------------------------------------------------------------------
# no-wall-clock
# ---------------------------------------------------------------------------

WALL_SRC = """\
    import time
    from time import perf_counter as pc
    import datetime


    def f():
        a = time.time()
        b = pc()
        c = datetime.datetime.now()
        return a, b, c
    """


def test_no_wall_clock_fires_with_alias_resolution(tmp_path):
    diags = lint(tmp_path, {"src/repro/core/clocks.py": WALL_SRC},
                 select=["no-wall-clock"])
    assert keyed(diags) == [
        ("src/repro/core/clocks.py", 7, "no-wall-clock"),
        ("src/repro/core/clocks.py", 8, "no-wall-clock"),
        ("src/repro/core/clocks.py", 9, "no-wall-clock")]
    assert "time.time()" in diags[0].message


def test_no_wall_clock_scoped_to_src_only(tmp_path):
    diags = lint(tmp_path, {"tools/bench.py": WALL_SRC,
                            "tests/test_t.py": WALL_SRC,
                            "benchmarks/b.py": WALL_SRC},
                 select=["no-wall-clock"])
    assert diags == []


def test_no_wall_clock_pragma_inline_and_standalone(tmp_path):
    diags = lint(tmp_path, {"src/m.py": """\
        import time

        t0 = time.time()  # repro-lint: allow(no-wall-clock)
        # repro-lint: allow(no-wall-clock) -- annotates the next line
        t1 = time.time()
        t2 = time.time()
        """, }, select=["no-wall-clock"])
    assert keyed(diags) == [("src/m.py", 6, "no-wall-clock")]


# ---------------------------------------------------------------------------
# seeded-rng
# ---------------------------------------------------------------------------

def test_seeded_rng_fires_on_stdlib_and_legacy_numpy(tmp_path):
    diags = lint(tmp_path, {"src/w.py": """\
        import random
        from random import choice
        import numpy as np


        def f(rng):
            x = np.random.rand(3)
            y = rng.integers(0, 5)
            z = np.random.default_rng(0)
            return x, y, z
        """, }, select=["seeded-rng"])
    assert keyed(diags) == [("src/w.py", 1, "seeded-rng"),
                            ("src/w.py", 2, "seeded-rng"),
                            ("src/w.py", 7, "seeded-rng")]
    # threaded Generator methods and default_rng() construction are the
    # sanctioned idiom — never flagged
    assert all(d.line != 8 and d.line != 9 for d in diags)


def test_seeded_rng_pragma(tmp_path):
    diags = lint(tmp_path, {"tests/test_s.py": """\
        import random  # repro-lint: allow(seeded-rng)
        """, }, select=["seeded-rng"])
    assert diags == []


# ---------------------------------------------------------------------------
# ordered-iteration
# ---------------------------------------------------------------------------

def test_ordered_iteration_fires_on_set_drain_near_log(tmp_path):
    diags = lint(tmp_path, {"src/d.py": """\
        class C:
            def __init__(self):
                self.events = []

            def drain(self, keys):
                pending = set(keys)
                for k in pending:
                    self.events.append(("drain", k))
                for k in sorted(pending):
                    self.events.append(("ok", k))
                missed = {k for k in keys}
                return [k for k in missed]
        """, }, select=["ordered-iteration"])
    # line 7: raw set drain fires; line 9: sorted() drain is fine;
    # line 12: comprehension over the local set fires
    assert keyed(diags) == [("src/d.py", 7, "ordered-iteration"),
                            ("src/d.py", 12, "ordered-iteration")]


def test_ordered_iteration_ignores_functions_without_logs(tmp_path):
    diags = lint(tmp_path, {"src/pure.py": """\
        def union(a, b):
            out = set(a)
            for x in b:
                out.add(x)
            return [x for x in out]
        """, }, select=["ordered-iteration"])
    assert diags == []


def test_ordered_iteration_fires_on_set_typed_state(tmp_path):
    diags = lint(tmp_path, {"src/st.py": """\
        from typing import Dict, Set


        class C:
            def __init__(self):
                self.events = []
                self._pending: Set[str] = set()
                self._done = set()
                self._ok: Dict[str, None] = {}


        class NoLog:
            def __init__(self):
                self._pending = set()
        """, }, select=["ordered-iteration"])
    # only the log-owning class is in scope; the dict replacement and
    # the log-free class never fire
    assert keyed(diags) == [("src/st.py", 7, "ordered-iteration"),
                            ("src/st.py", 8, "ordered-iteration")]
    assert "_pending" in diags[0].message


def test_ordered_iteration_pragma(tmp_path):
    diags = lint(tmp_path, {"src/p.py": """\
        class C:
            def __init__(self):
                self.events = []
                # repro-lint: allow(ordered-iteration) -- drained sorted
                self._pending = set()
        """, }, select=["ordered-iteration"])
    assert diags == []


# ---------------------------------------------------------------------------
# timestamp-free-events
# ---------------------------------------------------------------------------

def test_timestamp_free_events_fires_on_clock_in_tuple(tmp_path):
    diags = lint(tmp_path, {"src/ev.py": """\
        import time


        class C:
            def __init__(self):
                self.events = []

            def log(self, rid, now):
                self.events.append(("served", rid, now))

            def log2(self, rid):
                self.events.append(("served", rid, self._clock))

            def log3(self, rid):
                self.events.append(("served", rid, time.time()))

            def ok(self, rid, kind):
                self.events.append(("served", rid, kind))
        """, }, select=["timestamp-free-events"])
    assert keyed(diags) == [
        ("src/ev.py", 9, "timestamp-free-events"),
        ("src/ev.py", 12, "timestamp-free-events"),
        ("src/ev.py", 15, "timestamp-free-events")]
    assert "'now'" in diags[0].message


def test_timestamp_free_events_pragma(tmp_path):
    diags = lint(tmp_path, {"src/ev.py": """\
        class C:
            def __init__(self):
                self.events = []

            def log(self, rid, now):
                # repro-lint: allow(timestamp-free-events) -- debug log
                self.events.append(("served", rid, now))
        """, }, select=["timestamp-free-events"])
    assert diags == []


# ---------------------------------------------------------------------------
# hypothesis-via-shim
# ---------------------------------------------------------------------------

def test_hypothesis_via_shim_fires_only_in_tests(tmp_path):
    files = {
        "tests/test_p.py": """\
            import hypothesis
            from hypothesis import given
            from _hypothesis_compat import forall
            """,
        "tests/_hypothesis_compat.py": """\
            from hypothesis import given
            """,
        "src/prop.py": """\
            from hypothesis import given
            """,
    }
    diags = lint(tmp_path, files, select=["hypothesis-via-shim"])
    # the shim itself and non-test code are exempt
    assert keyed(diags) == [
        ("tests/test_p.py", 1, "hypothesis-via-shim"),
        ("tests/test_p.py", 2, "hypothesis-via-shim")]


def test_hypothesis_via_shim_pragma(tmp_path):
    diags = lint(tmp_path, {"tests/test_p.py": """\
        import hypothesis  # repro-lint: allow(hypothesis-via-shim)
        """, }, select=["hypothesis-via-shim"])
    assert diags == []


# ---------------------------------------------------------------------------
# cross-env-parity
# ---------------------------------------------------------------------------

SIM_WITH_DRIFT = """\
    class ServingSimulator:
        def __init__(self, cfg, spec, *, bandwidth=None,
                     storage=None,
                     burst_seed=0):
            pass
    """
LIVE_PLAIN = """\
    class LiveEngine:
        def __init__(self, params, cfg, store, *, bandwidth=None):
            pass
    """


def test_cross_env_parity_catches_sim_only_seeded_knob(tmp_path):
    """ISSUE 10 acceptance: a seeded knob added to ServingSimulator but
    not LiveEngine is caught, anchored at the knob's own line."""
    diags = lint(tmp_path, {"src/sim.py": SIM_WITH_DRIFT,
                            "src/live.py": LIVE_PLAIN},
                 select=["cross-env-parity"])
    # bandwidth matches by name, storage via the store alias; only
    # burst_seed (line 4 of sim.py) has no live counterpart
    assert keyed(diags) == [("src/sim.py", 4, "cross-env-parity")]
    assert "burst_seed" in diags[0].message
    assert "LiveEngine" in diags[0].message


def test_cross_env_parity_clean_when_counterpart_exists(tmp_path):
    live = LIVE_PLAIN.replace("bandwidth=None):",
                              "bandwidth=None, burst_seed=0):")
    diags = lint(tmp_path, {"src/sim.py": SIM_WITH_DRIFT,
                            "src/live.py": live},
                 select=["cross-env-parity"])
    assert diags == []


def test_cross_env_parity_fleet_pair_and_pragma(tmp_path):
    files = {
        "src/fleet.py": """\
            class FleetSimulator:
                def __init__(self, cfg, spec, *, n_nodes=1,
                             # repro-lint: allow(cross-env-parity)
                             mfu=0.5,
                             policy="affinity"):
                    pass
            """,
        "src/live_fleet.py": """\
            class LiveFleet:
                def __init__(self, params, cfg, cluster, *, n_nodes=1,
                             policy="affinity"):
                    pass
            """,
    }
    diags = lint(tmp_path, files, select=["cross-env-parity"])
    # mfu is sim-only but pragma'd (standalone comment line annotates
    # the arg below it); n_nodes/policy match
    assert diags == []


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exit_codes_and_output(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "m.py").write_text(
        "import time\n\nt = time.time()\n")
    dirty = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "src",
         "--root", str(tmp_path)],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert dirty.returncode == 1
    assert "src/m.py:3:5: no-wall-clock:" in dirty.stdout
    assert "repro-lint: 1 diagnostic" in dirty.stdout

    (tmp_path / "src" / "m.py").write_text("x = 1\n")
    clean = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "src",
         "--root", str(tmp_path)],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert clean.returncode == 0
    assert "replay contract holds" in clean.stdout


def test_cli_list_rules():
    out = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "--list-rules"],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert out.returncode == 0
    for name in RULES:
        assert name in out.stdout


# ---------------------------------------------------------------------------
# tier-1 gate: the real tree is clean
# ---------------------------------------------------------------------------

def test_real_tree_has_zero_diagnostics():
    """The analyzer over the repo's own code must stay clean — the same
    invocation CI runs (``python -m tools.repro_lint src tests
    benchmarks tools``)."""
    diags = run_paths(["src", "tests", "benchmarks", "tools"],
                      root=REPO_ROOT)
    assert diags == [], "replay-contract violations:\n" + \
        "\n".join(str(d) for d in diags)


# ---------------------------------------------------------------------------
# order-stability regressions (the fixes the linter forced)
# ---------------------------------------------------------------------------

def test_storage_pending_recompute_is_insertion_ordered():
    """``_pending_recompute`` is a dict (not a set), and the write-on-
    miss -> recompute-done event sequence replays identically."""
    from repro.cluster.storage import (StorageCluster, StorageNode,
                                       StoredPrefix)

    def run_once():
        nodes = [StorageNode("n0", capacity_bytes=25 * MB)]
        c = StorageCluster(nodes, write_on_miss=True)
        assert isinstance(c._pending_recompute, dict)
        for i, k in enumerate(("aa", "bb", "cc")):
            c.register(StoredPrefix(k, 1000, {"240p": 10 * MB},
                                    raw_kv_bytes=80 * MB), float(i))
        # "cc" evicted "aa"; miss several keys, then complete their
        # recomputes — the re-admission order must be insertion order
        for t, k in enumerate(("aa", "zz", "aa")):
            c.lookup(k, 10.0 + t)
        for k in list(c._pending_recompute):
            c.notify_recompute_done(k, 20.0)
        return list(c.events)

    e1, e2 = run_once(), run_once()
    assert e1 == e2
    assert [e[0] for e in e1].count("miss") == 3


def test_fleet_dispatch_event_order_is_stable():
    """The fleet's per-round dispatch/rescheduling state is dict-backed:
    two identical runs emit byte-identical router + fairness + storage
    event sequences."""
    from repro.cluster.fairness import FairScheduler
    from repro.cluster.fleet import FleetSimulator
    from repro.cluster.network import BandwidthTrace
    from repro.cluster.simulator import kvfetcher_spec
    from repro.cluster.storage import (StorageCluster, StorageNode,
                                       synthetic_stored_prefix)
    from repro.configs import get_config
    from repro.data.workload import prefix_trie_specs, zipf_prefix_trace

    cfg = get_config("yi-34b")
    ratios = {"240p": 9.0, "1080p": 7.0}
    specs = prefix_trie_specs(3, 2)

    def run_once():
        nodes = [StorageNode(f"n{i}",
                             link=BandwidthTrace.constant(4.0))
                 for i in range(2)]
        cluster = StorageCluster(nodes, replication=1)
        for sp in specs:
            cluster.register(synthetic_stored_prefix(
                sp.key, sp.n_tokens,
                raw_bytes_per_token=cfg.kv_bytes_per_token(),
                ratios=ratios, parent=sp.parent), 0.0)
        fair = FairScheduler(max_inflight=2)
        fleet = FleetSimulator(cfg, kvfetcher_spec(ratios), n_nodes=4,
                               bandwidth=BandwidthTrace.constant(8.0),
                               storage=cluster, policy="affinity",
                               fairness=fair, local_kv_tokens=150_000)
        assert isinstance(fair._served, dict)
        rng = np.random.default_rng(7)
        reqs = zipf_prefix_trace(rng, specs, n_requests=16, alpha=1.2,
                                 gap=2.0, max_new_tokens=2)
        for i, r in enumerate(reqs):
            r.user = f"u{i % 3}"
        res = fleet.run(reqs, max_new_tokens=2)
        return (list(res.router_events), list(fair.events),
                list(cluster.events))

    r1, r2 = run_once(), run_once()
    assert r1 == r2
    assert len(r1[0]) == 16  # every request placed, in order
