"""WAN network model: chunk loss, retransmission, and multi-request
bandwidth fairness (ISSUE 2 acceptance surface).

Controller-level tests run on pure virtual clocks (fast); the
cross-environment determinism test drives the REAL live engine and the
analytic simulator over identically-shaped plans and asserts the seeded
LossModel replays the identical drop schedule in both (slow).
"""
import numpy as np
import pytest

from repro.core.adaptive import H20_TABLE
from repro.core.fetch import synthetic_plan
from repro.core.fetch_controller import (FetchController, FetchHooks,
                                         PipelineConfig)
from repro.core.scheduler import FetchingAwareScheduler, Request
from repro.cluster.decodepool import DecodePool
from repro.cluster.network import BandwidthTrace, LossModel, make_link

RES = ("240p", "480p", "640p", "1080p")


class _RecSched(FetchingAwareScheduler):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.t_early = None

    def notify_early_admissible(self, req, now):
        if self.t_early is None:
            self.t_early = now
        super().notify_early_admissible(req, now)


class _Hooks(FetchHooks):
    def __init__(self, nbytes=50e6, comp=None, restore=0.002):
        self.nbytes = nbytes
        self.comp = comp
        self.restore = restore

    def chunk_bytes(self, fetch, pc, res):
        return self.nbytes

    def restore_seconds(self, fetch, pc):
        return self.restore

    def comp_times(self, req):
        return self.comp


def _controller(sched, *, loss=None, policy="fair", comp=None,
                gbps=1.0, nbytes=50e6, pipelined=True, hooks=None,
                timeout=0.05):
    link = make_link(BandwidthTrace.constant(gbps), policy=policy,
                     loss=loss)
    return FetchController(
        sched, link, table=H20_TABLE, pool=DecodePool(H20_TABLE),
        config=PipelineConfig(adaptive=False, fixed_resolution="1080p",
                              pipelined=pipelined,
                              layerwise_admission=comp is not None,
                              resolutions=RES,
                              retransmit_timeout=timeout),
        hooks=hooks or _Hooks(nbytes, comp))


def _one_fetch(ctrl_kw=None, reuse=30_000, n_layers=9):
    sched = _RecSched("kvfetcher", max_running=4)
    req = Request(rid=0, arrival=0.0, prompt_len=reuse + 2_000,
                  reuse_tokens=reuse, prefix="p")
    sched.submit(req, 0.0)
    sched.schedule(0.0)
    (fr,) = sched.take_fetches()
    plan = synthetic_plan(0, reuse, n_layers, 10_000)
    ctrl = _controller(sched, **(ctrl_kw or {}))
    ctrl.start(fr, plan, 0.0)
    ctrl.pump(float("inf"))
    return sched, req, plan, ctrl


# ---------------------------------------------------------------------------
# loss + retransmission
# ---------------------------------------------------------------------------

def test_lossy_fetch_completes_with_retransmits():
    loss = LossModel.bernoulli(0.3, seed=11)
    sched, req, plan, ctrl = _one_fetch({"loss": loss})
    assert plan.done and req.fetch_done is not None
    assert ctrl.retransmits_total == len(loss.drops) > 0
    by_seq = {}
    for flow, seq, attempt in loss.drops:
        assert flow == 0
        by_seq[seq] = by_seq.get(seq, 0) + 1
    for seq, pc in enumerate(plan.chunks):
        assert pc.t_restored is not None
        assert pc.attempts == 1 + by_seq.get(seq, 0)
        assert pc.t_transmit_start <= pc.t_transmit_done
    # a retransmitted chunk pays at least one timeout + resend
    seq = next(iter(by_seq))
    pc = plan.chunks[seq]
    clean = next(p for i, p in enumerate(plan.chunks) if i not in by_seq)
    assert (pc.t_transmit_done - pc.t_transmit_start) > \
        (clean.t_transmit_done - clean.t_transmit_start)


def test_loss_slows_ttft_but_not_correctness():
    *_, plan_clean, _ = _one_fetch()
    loss = LossModel.bernoulli(0.2, seed=3)
    *_, plan_lossy, _ = _one_fetch({"loss": loss})
    assert loss.drops
    t_clean = max(pc.t_restored for pc in plan_clean.chunks)
    t_lossy = max(pc.t_restored for pc in plan_lossy.chunks)
    assert t_lossy > t_clean
    assert plan_lossy.done  # every chunk eventually restored (lossless)


def test_seeded_loss_schedule_is_event_order_independent():
    """The same seeded Bernoulli model replays the identical drop schedule
    under different hook environments (different restore/decode timing =>
    different event interleavings), the property that keeps simulator and
    live engine in lockstep."""
    drops = []
    for restore in (0.002, 0.5):  # radically different restore costs
        loss = LossModel.bernoulli(0.25, seed=7)
        _one_fetch({"loss": loss,
                    "hooks": _Hooks(50e6, None, restore=restore)})
        drops.append(sorted(loss.drops))
    assert drops[0] == drops[1] and drops[0]


def test_gilbert_elliott_deterministic_and_bursty():
    runs = []
    for _ in range(2):
        loss = LossModel.gilbert_elliott(seed=5, good_to_bad=0.2,
                                         bad_to_good=0.3, p_good=0.0,
                                         p_bad=1.0)
        _one_fetch({"loss": loss})
        runs.append(list(loss.drops))
    assert runs[0] == runs[1] and runs[0]
    # p_good=0, p_bad=1: every drop comes from a bad-state burst, so at
    # least one pair of drops lands on consecutive chain steps
    other = LossModel.gilbert_elliott(seed=6, good_to_bad=0.2,
                                      bad_to_good=0.3, p_good=0.0,
                                      p_bad=1.0)
    _one_fetch({"loss": other})
    assert list(other.drops) != runs[0]  # different seed, different bursts


def test_early_admission_waits_for_outstanding_retransmit():
    """A lost chunk's layer group is not buffered: the Appx A.3 condition
    must not admit while its retransmit is outstanding."""
    comp = [10.0] * 9
    # control: no loss -> early admission fires well before fetch end
    sched0, req0, plan0, _ = _one_fetch({"comp": comp})
    assert req0.early_admitted and sched0.t_early < req0.fetch_done
    # drop the very first chunk (group 0) three times: group 0 stays
    # unbuffered until the 4th attempt lands, long after later chunks
    loss = LossModel.scripted({(0, 0, 1), (0, 0, 2), (0, 0, 3)})
    sched, req, plan, ctrl = _one_fetch({"comp": comp, "loss": loss})
    assert len(loss.drops) == 3
    t_landed = plan.chunks[0].t_transmit_done
    assert plan.chunks[0].attempts == 4
    assert sched.t_early is not None
    assert sched.t_early >= t_landed, \
        "early admission fired while a retransmit was outstanding"
    assert sched.t_early > sched0.t_early
    assert req.layers_ready == plan.n_layers_total


# ---------------------------------------------------------------------------
# shared-link bandwidth arbitration
# ---------------------------------------------------------------------------

def _concurrent(policy, weights, *, gbps=1.0, reuse=30_000):
    sched = _RecSched("kvfetcher", max_running=4)
    reqs = []
    for rid, w in enumerate(weights):
        r = Request(rid=rid, arrival=0.0, prompt_len=reuse + 1_000,
                    reuse_tokens=reuse, prefix=f"p{rid}", weight=w)
        sched.submit(r, 0.0)
        reqs.append(r)
    sched.schedule(0.0)
    fetches = sched.take_fetches()
    ctrl = _controller(sched, policy=policy, gbps=gbps)
    for r in fetches:
        ctrl.start(r, synthetic_plan(r.rid, reuse, 9, 10_000), 0.0)
    ctrl.pump(float("inf"))
    return reqs, ctrl


def test_fair_share_splits_bandwidth():
    (solo,), _ = _concurrent("fair", [1.0])
    pair, _ = _concurrent("fair", [1.0, 1.0])
    t_solo = solo.fetch_done
    for r in pair:
        # equal split: two concurrent fetches each take ~2x the solo time
        assert 1.6 * t_solo < r.fetch_done < 2.4 * t_solo
    assert abs(pair[0].fetch_done - pair[1].fetch_done) < 0.2 * t_solo


def test_weighted_fair_share_prioritizes():
    heavy_light, _ = _concurrent("fair", [3.0, 1.0])
    equal, _ = _concurrent("fair", [1.0, 1.0])
    heavy, light = heavy_light
    assert heavy.fetch_done < light.fetch_done
    # the weight-3 fetch beats the equal-split completion time
    assert heavy.fetch_done < min(r.fetch_done for r in equal)


def test_drr_interleaves_and_respects_weights():
    (solo,), _ = _concurrent("drr", [1.0])
    pair, _ = _concurrent("drr", [1.0, 1.0])
    # serialized wire, round-robin chunks: both finish around 2x solo
    for r in pair:
        assert 1.5 * solo.fetch_done < r.fetch_done < 2.5 * solo.fetch_done
    weighted, _ = _concurrent("drr", [2.0, 1.0])
    assert weighted[0].fetch_done < weighted[1].fetch_done


def test_contention_and_loss_compose():
    loss = LossModel.bernoulli(0.15, seed=2)
    reqs, ctrl = _concurrent("fair", [1.0, 1.0])
    t_clean = max(r.fetch_done for r in reqs)
    sched = _RecSched("kvfetcher", max_running=4)
    rs = []
    for rid in range(2):
        r = Request(rid=rid, arrival=0.0, prompt_len=31_000,
                    reuse_tokens=30_000, prefix=f"p{rid}")
        sched.submit(r, 0.0)
        rs.append(r)
    sched.schedule(0.0)
    ctrl = _controller(sched, loss=loss)
    for r in sched.take_fetches():
        ctrl.start(r, synthetic_plan(r.rid, 30_000, 9, 10_000), 0.0)
    ctrl.pump(float("inf"))
    assert all(r.fetch_done is not None for r in rs)
    assert max(r.fetch_done for r in rs) > t_clean
    assert {f for f, _, _ in loss.drops} <= {0, 1}


# ---------------------------------------------------------------------------
# network.py API contracts
# ---------------------------------------------------------------------------

def test_trace_repr_shows_gbps():
    assert repr(BandwidthTrace.constant(2.0)) == "BandwidthTrace(2 Gbps)"
    r = repr(BandwidthTrace.steps([(0, 6), (5, 3)]))
    assert "Gbps" in r and "6" in r and "1e" not in r  # no raw bytes/sec


def test_make_link_idempotent_and_single_flow_degenerates():
    trace = BandwidthTrace.constant(1.0)
    link = make_link(trace, policy="fair")
    assert make_link(link) is link
    # single flow over a SharedLink matches the bare trace exactly
    done = []
    link.bind(lambda t, fn: done.append((t, fn)))
    link.open_flow(0)
    link.submit(0, 5e8, 0.0, lambda t: None)
    (t_ev, fn), = done
    assert t_ev == pytest.approx(trace.transmit(5e8, 0.0))


def test_drr_close_flow_reclaims_state_under_backlog():
    """Flows that finish while the link is busy serving OTHER flows must
    still be reclaimed from the round-robin state (leak regression)."""
    sched = _RecSched("kvfetcher", max_running=16)
    reqs = []
    for rid in range(6):
        r = Request(rid=rid, arrival=0.0, prompt_len=11_000,
                    reuse_tokens=10_000, prefix=f"p{rid}")
        sched.submit(r, 0.0)
        reqs.append(r)
    sched.schedule(0.0)
    ctrl = _controller(sched, policy="drr")
    for r in sched.take_fetches():
        ctrl.start(r, synthetic_plan(r.rid, 10_000, 9, 10_000), 0.0)
    ctrl.pump(float("inf"))
    assert all(r.fetch_done is not None for r in reqs)
    link = ctrl.link
    assert link._order == [] and link._deficit == {}
    assert link._weights == {} and link.in_flight == 0


def test_mean_loss_rate():
    assert LossModel.bernoulli(0.03).mean_loss_rate() == pytest.approx(0.03)
    ge = LossModel.gilbert_elliott(good_to_bad=0.1, bad_to_good=0.3,
                                   p_good=0.0, p_bad=0.5)
    assert ge.mean_loss_rate() == pytest.approx(0.125)
    assert LossModel.scripted({(0, 0, 1)}).mean_loss_rate() == 0.0


# ---------------------------------------------------------------------------
# cross-environment determinism: simulator vs live engine (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_loss_schedule_identical_in_simulator_and_live_engine(
        tiny_cfg, tiny_params, registered_store):
    """Seeded LossModel replays the identical drop schedule through the
    analytic simulator and the virtual-clock live engine when both walk
    identically-shaped plans (same rid, chunk seq, attempt keys)."""
    import dataclasses as dc

    from repro.cluster.simulator import MethodSpec, ServingSimulator
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(5)
    prefix = rng.integers(0, tiny_cfg.vocab_size, 48)
    full = np.concatenate([prefix, rng.integers(0, tiny_cfg.vocab_size, 8)])
    store, key = registered_store(prefix, tokens_per_chunk=16,
                                  resolutions=("240p",))

    # live engine: async virtual clock, real codec, 2% -> 35% loss to be
    # sure drops occur on this small plan
    loss_eng = LossModel.bernoulli(0.35, seed=21)
    eng = LiveEngine(tiny_params, tiny_cfg, store, policy="kvfetcher",
                     fetch_mode="async",
                     bandwidth=BandwidthTrace.constant(0.0006),
                     loss=loss_eng, resolution="240p")
    r = eng.submit(full, reuse_prefix=key, reuse_tokens=48,
                   max_new_tokens=2)
    eng.run()
    assert r.rid == 0 and r.fetch_done is not None

    # simulator: same cfg geometry (same rid / groups / chunk count)
    loss_sim = LossModel.bernoulli(0.35, seed=21)
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=False)
    sim = ServingSimulator(tiny_cfg, spec,
                           bandwidth=BandwidthTrace.constant(0.0006),
                           loss=loss_sim, chunk_tokens=16)
    req = Request(rid=0, arrival=0.0, prompt_len=56, reuse_tokens=48,
                  prefix="p")
    sim.run([req], max_new_tokens=2)
    assert req.fetch_done is not None

    assert sorted(loss_eng.drops) == sorted(loss_sim.drops)
    assert loss_eng.drops, "loss never fired; test is vacuous"

    # despite retransmits, restoration is lossless: same tokens as a
    # clean (no-loss) run of the same engine
    eng2 = LiveEngine(tiny_params, tiny_cfg, store, policy="kvfetcher",
                      fetch_mode="async",
                      bandwidth=BandwidthTrace.constant(0.0006),
                      resolution="240p")
    r2 = eng2.submit(full, reuse_prefix=key, reuse_tokens=48,
                     max_new_tokens=2)
    eng2.run()
    assert eng.outputs[r.rid] == eng2.outputs[r2.rid]
