"""WAN network model: chunk loss, retransmission, and multi-request
bandwidth fairness (ISSUE 2 acceptance surface).

Controller-level tests run on pure virtual clocks (fast); the
cross-environment determinism test drives the REAL live engine and the
analytic simulator over identically-shaped plans and asserts the seeded
LossModel replays the identical drop schedule in both (slow).
"""
import numpy as np
import pytest

from repro.core.adaptive import H20_TABLE
from repro.core.fetch import synthetic_plan
from repro.core.fetch_controller import (FetchController, FetchHooks,
                                         PipelineConfig)
from repro.core.scheduler import FetchingAwareScheduler, Request
from repro.cluster.decodepool import DecodePool
from repro.cluster.network import BandwidthTrace, LossModel, make_link

RES = ("240p", "480p", "640p", "1080p")


class _RecSched(FetchingAwareScheduler):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.t_early = None

    def notify_early_admissible(self, req, now):
        if self.t_early is None:
            self.t_early = now
        super().notify_early_admissible(req, now)


class _Hooks(FetchHooks):
    def __init__(self, nbytes=50e6, comp=None, restore=0.002):
        self.nbytes = nbytes
        self.comp = comp
        self.restore = restore

    def chunk_bytes(self, fetch, pc, res):
        return self.nbytes

    def restore_seconds(self, fetch, pc):
        return self.restore

    def comp_times(self, req):
        return self.comp


def _controller(sched, *, loss=None, policy="fair", comp=None,
                gbps=1.0, nbytes=50e6, pipelined=True, hooks=None,
                timeout=0.05, trace=None, ramp=None, rto_mode="adaptive",
                max_attempts=64, blocking=False, ack_delay=0.0):
    link = make_link(trace or BandwidthTrace.constant(gbps),
                     policy=policy, loss=loss, ramp=ramp)
    return FetchController(
        sched, link, table=H20_TABLE, pool=DecodePool(H20_TABLE),
        config=PipelineConfig(adaptive=False, fixed_resolution="1080p",
                              pipelined=pipelined,
                              layerwise_admission=comp is not None,
                              resolutions=RES,
                              retransmit_timeout=timeout,
                              rto_mode=rto_mode,
                              max_attempts=max_attempts,
                              blocking_fetch=blocking,
                              ack_delay=ack_delay),
        hooks=hooks or _Hooks(nbytes, comp))


def _one_fetch(ctrl_kw=None, reuse=30_000, n_layers=9):
    sched = _RecSched("kvfetcher", max_running=4)
    req = Request(rid=0, arrival=0.0, prompt_len=reuse + 2_000,
                  reuse_tokens=reuse, prefix="p")
    sched.submit(req, 0.0)
    sched.schedule(0.0)
    (fr,) = sched.take_fetches()
    plan = synthetic_plan(0, reuse, n_layers, 10_000)
    ctrl = _controller(sched, **(ctrl_kw or {}))
    ctrl.start(fr, plan, 0.0)
    ctrl.pump(float("inf"))
    return sched, req, plan, ctrl


def _staggered(arrivals, *, ramp=None, rto_mode="adaptive", policy="fair",
               loss=None, trace=None, reuse=30_000):
    """Start one fetch per arrival time (flows join a live link)."""
    sched = _RecSched("kvfetcher", max_running=len(arrivals) + 1)
    reqs = []
    for rid, t in enumerate(arrivals):
        r = Request(rid=rid, arrival=t, prompt_len=reuse + 1_000,
                    reuse_tokens=reuse, prefix=f"p{rid}")
        sched.submit(r, t)
        reqs.append(r)
    sched.schedule(0.0)
    ctrl = _controller(sched, policy=policy, ramp=ramp,
                       rto_mode=rto_mode, loss=loss, trace=trace)
    for r in sched.take_fetches():
        ctrl.pump(r.arrival)
        ctrl.start(r, synthetic_plan(r.rid, reuse, 9, 10_000), r.arrival)
    ctrl.pump(float("inf"))
    return reqs, ctrl


# ---------------------------------------------------------------------------
# loss + retransmission
# ---------------------------------------------------------------------------

def test_lossy_fetch_completes_with_retransmits():
    loss = LossModel.bernoulli(0.3, seed=11)
    sched, req, plan, ctrl = _one_fetch({"loss": loss})
    assert plan.done and req.fetch_done is not None
    assert ctrl.retransmits_total == len(loss.drops) > 0
    by_seq = {}
    for flow, seq, attempt in loss.drops:
        assert flow == 0
        by_seq[seq] = by_seq.get(seq, 0) + 1
    for seq, pc in enumerate(plan.chunks):
        assert pc.t_restored is not None
        assert pc.attempts == 1 + by_seq.get(seq, 0)
        assert pc.t_transmit_start <= pc.t_transmit_done
    # a retransmitted chunk pays at least one timeout + resend
    seq = next(iter(by_seq))
    pc = plan.chunks[seq]
    clean = next(p for i, p in enumerate(plan.chunks) if i not in by_seq)
    assert (pc.t_transmit_done - pc.t_transmit_start) > \
        (clean.t_transmit_done - clean.t_transmit_start)


def test_ack_delay_shifts_rto_timer_arming():
    """``PipelineConfig.ack_delay`` pushes every retransmit timer out by
    exactly the ACK propagation delay: the sender cannot observe a
    missing ack before the ack itself would have crossed the reverse
    path.  The wire event itself does not move — only the timer — and
    the default 0 keeps the schedule byte-identical."""
    delay = 0.2

    def pending_after_start(ack_delay):
        sched = _RecSched("kvfetcher", max_running=4)
        req = Request(rid=0, arrival=0.0, prompt_len=32_000,
                      reuse_tokens=30_000, prefix="p")
        sched.submit(req, 0.0)
        sched.schedule(0.0)
        (fr,) = sched.take_fetches()
        ctrl = _controller(sched, ack_delay=ack_delay)
        # start() submits chunk 0 and arms its retransmit timer, but
        # nothing is pumped: the queue holds exactly the wire-completion
        # event and the timer.
        ctrl.start(fr, synthetic_plan(0, 30_000, 9, 10_000), 0.0)
        return sorted(t for t, _, _ in ctrl._events)

    base = pending_after_start(0.0)
    shifted = pending_after_start(delay)
    assert pending_after_start(0.0) == base  # deterministic harness
    assert len(base) == len(shifted) == 2
    diffs = sorted(s - b for b, s in zip(base, shifted))
    # the wire event is unmoved; the RTO arming shifts by exactly delay
    assert diffs == pytest.approx([0.0, delay])
    # timers fire later, so a lossy fetch pays the delay per recovery:
    # end-to-end completion under loss is strictly later with the delay
    loss_kw = lambda d: {"loss": LossModel.bernoulli(0.3, seed=11),
                         "ack_delay": d}
    *_, ctrl0 = _one_fetch(loss_kw(0.0))
    *_, ctrl_d = _one_fetch(loss_kw(delay))
    assert ctrl_d.retransmits_total > 0
    assert ctrl_d.now > ctrl0.now


def test_loss_slows_ttft_but_not_correctness():
    *_, plan_clean, _ = _one_fetch()
    loss = LossModel.bernoulli(0.2, seed=3)
    *_, plan_lossy, _ = _one_fetch({"loss": loss})
    assert loss.drops
    t_clean = max(pc.t_restored for pc in plan_clean.chunks)
    t_lossy = max(pc.t_restored for pc in plan_lossy.chunks)
    assert t_lossy > t_clean
    assert plan_lossy.done  # every chunk eventually restored (lossless)


def test_seeded_loss_schedule_is_event_order_independent():
    """The same seeded Bernoulli model replays the identical drop schedule
    under different hook environments (different restore/decode timing =>
    different event interleavings), the property that keeps simulator and
    live engine in lockstep."""
    drops = []
    for restore in (0.002, 0.5):  # radically different restore costs
        loss = LossModel.bernoulli(0.25, seed=7)
        _one_fetch({"loss": loss,
                    "hooks": _Hooks(50e6, None, restore=restore)})
        drops.append(sorted(loss.drops))
    assert drops[0] == drops[1] and drops[0]


def test_gilbert_elliott_deterministic_and_bursty():
    runs = []
    for _ in range(2):
        loss = LossModel.gilbert_elliott(seed=5, good_to_bad=0.2,
                                         bad_to_good=0.3, p_good=0.0,
                                         p_bad=1.0)
        _one_fetch({"loss": loss})
        runs.append(list(loss.drops))
    assert runs[0] == runs[1] and runs[0]
    # p_good=0, p_bad=1: every drop comes from a bad-state burst, so at
    # least one pair of drops lands on consecutive chain steps
    other = LossModel.gilbert_elliott(seed=6, good_to_bad=0.2,
                                      bad_to_good=0.3, p_good=0.0,
                                      p_bad=1.0)
    _one_fetch({"loss": other})
    assert list(other.drops) != runs[0]  # different seed, different bursts


def test_early_admission_waits_for_outstanding_retransmit():
    """A lost chunk's layer group is not buffered: the Appx A.3 condition
    must not admit while its retransmit is outstanding."""
    comp = [10.0] * 9
    # control: no loss -> early admission fires well before fetch end
    sched0, req0, plan0, _ = _one_fetch({"comp": comp})
    assert req0.early_admitted and sched0.t_early < req0.fetch_done
    # drop the very first chunk (group 0) three times: group 0 stays
    # unbuffered until the 4th attempt lands, long after later chunks
    loss = LossModel.scripted({(0, 0, 1), (0, 0, 2), (0, 0, 3)})
    sched, req, plan, ctrl = _one_fetch({"comp": comp, "loss": loss})
    assert len(loss.drops) == 3
    t_landed = plan.chunks[0].t_transmit_done
    assert plan.chunks[0].attempts == 4
    assert sched.t_early is not None
    assert sched.t_early >= t_landed, \
        "early admission fired while a retransmit was outstanding"
    assert sched.t_early > sched0.t_early
    assert req.layers_ready == plan.n_layers_total


# ---------------------------------------------------------------------------
# shared-link bandwidth arbitration
# ---------------------------------------------------------------------------

def _concurrent(policy, weights, *, gbps=1.0, reuse=30_000):
    sched = _RecSched("kvfetcher", max_running=4)
    reqs = []
    for rid, w in enumerate(weights):
        r = Request(rid=rid, arrival=0.0, prompt_len=reuse + 1_000,
                    reuse_tokens=reuse, prefix=f"p{rid}", weight=w)
        sched.submit(r, 0.0)
        reqs.append(r)
    sched.schedule(0.0)
    fetches = sched.take_fetches()
    ctrl = _controller(sched, policy=policy, gbps=gbps)
    for r in fetches:
        ctrl.start(r, synthetic_plan(r.rid, reuse, 9, 10_000), 0.0)
    ctrl.pump(float("inf"))
    return reqs, ctrl


def test_fair_share_splits_bandwidth():
    (solo,), _ = _concurrent("fair", [1.0])
    pair, _ = _concurrent("fair", [1.0, 1.0])
    t_solo = solo.fetch_done
    for r in pair:
        # equal split: two concurrent fetches each take ~2x the solo time
        assert 1.6 * t_solo < r.fetch_done < 2.4 * t_solo
    assert abs(pair[0].fetch_done - pair[1].fetch_done) < 0.2 * t_solo


def test_weighted_fair_share_prioritizes():
    heavy_light, _ = _concurrent("fair", [3.0, 1.0])
    equal, _ = _concurrent("fair", [1.0, 1.0])
    heavy, light = heavy_light
    assert heavy.fetch_done < light.fetch_done
    # the weight-3 fetch beats the equal-split completion time
    assert heavy.fetch_done < min(r.fetch_done for r in equal)


def test_drr_interleaves_and_respects_weights():
    (solo,), _ = _concurrent("drr", [1.0])
    pair, _ = _concurrent("drr", [1.0, 1.0])
    # serialized wire, round-robin chunks: both finish around 2x solo
    for r in pair:
        assert 1.5 * solo.fetch_done < r.fetch_done < 2.5 * solo.fetch_done
    weighted, _ = _concurrent("drr", [2.0, 1.0])
    assert weighted[0].fetch_done < weighted[1].fetch_done


def test_contention_and_loss_compose():
    loss = LossModel.bernoulli(0.15, seed=2)
    reqs, ctrl = _concurrent("fair", [1.0, 1.0])
    t_clean = max(r.fetch_done for r in reqs)
    sched = _RecSched("kvfetcher", max_running=4)
    rs = []
    for rid in range(2):
        r = Request(rid=rid, arrival=0.0, prompt_len=31_000,
                    reuse_tokens=30_000, prefix=f"p{rid}")
        sched.submit(r, 0.0)
        rs.append(r)
    sched.schedule(0.0)
    ctrl = _controller(sched, loss=loss)
    for r in sched.take_fetches():
        ctrl.start(r, synthetic_plan(r.rid, 30_000, 9, 10_000), 0.0)
    ctrl.pump(float("inf"))
    assert all(r.fetch_done is not None for r in rs)
    assert max(r.fetch_done for r in rs) > t_clean
    assert {f for f, _, _ in loss.drops} <= {0, 1}


# ---------------------------------------------------------------------------
# adaptive transport (ISSUE 5): RTO, spurious retransmits, fallback
# ---------------------------------------------------------------------------

def test_rtt_estimator_jacobson_karels():
    from repro.cluster.network import RttEstimator

    est = RttEstimator()
    assert est.rto(0.02, 10.0) is None  # no sample yet
    for _ in range(16):
        est.observe(0.4)
    # constant samples: srtt == sample, rttvar decays -> floor margin
    assert est.srtt == pytest.approx(0.4)
    rto = est.rto(0.02, 10.0)
    assert 0.4 < rto < 0.5
    # a jittery burst inflates rttvar well past the new srtt
    est.observe(1.6)
    assert est.rto(0.02, 10.0) > 1.6
    # clamps
    assert est.rto(5.0, 10.0) >= 5.0
    assert est.rto(0.02, 0.1) == pytest.approx(0.1)


def test_spurious_retransmit_cancelled_and_counted():
    """Satellite regression: a slow (NOT lost) chunk whose timer fires
    must cancel the duplicate once the original lands and count it under
    spurious_retransmits, never retransmits — scripted bandwidth
    collapse, no LossModel at all."""
    # 1 Gbps while the RTO converges, then a 50x collapse mid-plan
    trace = BandwidthTrace.steps([(0, 1.0), (1.0, 0.02)])
    sched, req, plan, ctrl = _one_fetch({"trace": trace}, reuse=10_000)
    assert plan.done and req.fetch_done is not None
    assert ctrl.spurious_retransmits_total > 0
    assert ctrl.retransmits_total == 0  # nothing was ever lost
    assert ctrl.link.in_flight == 0  # every duplicate was cancelled
    slow = [pc for pc in plan.chunks if pc.attempts > 1]
    assert slow, "the collapse never provoked a duplicate"
    for pc in plan.chunks:
        assert pc.t_restored is not None  # duplicates never block restore


def test_adaptive_rto_beats_fixed_on_jittery_link():
    """Jacobson's argument: a fixed grace period fires on every above-
    estimate service time, while SRTT/RTTVAR absorbs the jitter."""
    rng = np.random.default_rng(0)
    trace = BandwidthTrace.jittered(rng, 1.0, duration=120.0,
                                    seg_len=0.3, rel_std=0.45)
    spurious = {}
    for mode in ("fixed", "adaptive"):
        sched, req, plan, ctrl = _one_fetch(
            {"trace": trace, "rto_mode": mode})
        assert plan.done and req.fetch_done is not None
        assert ctrl.retransmits_total == 0  # lossless: only duplicates
        spurious[mode] = ctrl.spurious_retransmits_total
    assert spurious["adaptive"] < spurious["fixed"], spurious
    assert spurious["fixed"] > 0


def test_max_attempts_exhaustion_falls_back_to_full_prefill():
    """Satellite regression: exhausting max_attempts must not stall the
    request forever — the fetch aborts through notify_fetch_miss and the
    fallback full prefill still produces a first token."""
    from repro.configs import get_config
    from repro.cluster.simulator import MethodSpec, ServingSimulator

    cfg = get_config("yi-34b")

    def run(loss, max_attempts=3):
        spec = MethodSpec("kvfetcher", ratios={"stream": 8.0},
                          adaptive=False, fixed_resolution="1080p",
                          uses_decode_pool=False,
                          layerwise_admission=True,
                          max_attempts=max_attempts)
        sim = ServingSimulator(cfg, spec, chip="h20", n_chips=2,
                               bandwidth=BandwidthTrace.constant(8.0),
                               loss=loss)
        req = Request(rid=0, arrival=0.0, prompt_len=22_000,
                      reuse_tokens=20_000, prefix="p",
                      max_new_tokens=4)
        res = sim.run([req], max_new_tokens=4)
        return req, res

    # chunk 0 lost on every allowed attempt -> fetch aborts, falls back
    loss = LossModel.scripted({(0, 0, 1), (0, 0, 2), (0, 0, 3)})
    req, res = run(loss)
    assert req.storage_hit == "miss" and req.reuse_tokens == 0
    assert req.requested_reuse_tokens == 20_000
    assert req.t_first_token is not None, "fallback TTFT not recorded"
    assert res.retransmits == 2  # attempts 2 and 3 were loss-driven
    clean_req, _ = run(None)
    # the fallback recomputes the whole prompt: strictly slower than the
    # clean fetch-reuse run of the same request
    assert req.ttft > clean_req.ttft


def test_max_attempts_fallback_unblocks_fetch_agnostic_hol():
    """The cap must also bind under the fetch_agnostic policy (whose
    fetching requests wait in the FCFS queue, not waiting_for_kv): an
    exhausted fetch falls back instead of head-of-line-blocking the
    queue forever."""
    from repro.configs import get_config
    from repro.cluster.simulator import MethodSpec, ServingSimulator

    cfg = get_config("yi-34b")
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="1080p", uses_decode_pool=False,
                      scheduler_policy="fetch_agnostic", max_attempts=3)
    sim = ServingSimulator(cfg, spec, chip="h20", n_chips=2,
                           bandwidth=BandwidthTrace.constant(8.0),
                           loss=LossModel.scripted(
                               {(0, 0, 1), (0, 0, 2), (0, 0, 3)}))
    head = Request(rid=0, arrival=0.0, prompt_len=22_000,
                   reuse_tokens=20_000, prefix="p", max_new_tokens=4)
    behind = Request(rid=1, arrival=0.0, prompt_len=1_000,
                     max_new_tokens=4)
    sim.run([head, behind], max_new_tokens=4)
    assert head.reuse_tokens == 0 and head.t_first_token is not None, \
        "exhausted fetch_agnostic head must fall back, not stall"
    assert behind.t_first_token is not None, \
        "fallback must unblock the request behind the head"


def test_slowstart_rejects_zero_ramp_init():
    from repro.cluster.network import SharedLink

    with pytest.raises(AssertionError):
        SharedLink(BandwidthTrace.constant(1.0), ramp="slowstart",
                   ramp_init=0.0)


def test_blocking_goodput_haircut_only_with_lossy_link():
    """Satellite regression: the bulk-transfer loss haircut must apply
    only when the flow's own link carries real loss."""
    times = {}
    for name, loss in (("none", None),
                       ("lossless", LossModel.scripted(set())),
                       ("lossy", LossModel.bernoulli(0.2, seed=1))):
        sched, req, plan, _ = _one_fetch(
            {"blocking": True, "loss": loss}, reuse=10_000)
        assert plan.done
        times[name] = req.fetch_done
    assert times["none"] == pytest.approx(times["lossless"]), \
        "a zero-rate LossModel must not inflate the bulk transfer"
    assert times["lossy"] > 1.1 * times["none"]


def test_admission_projection_skips_haircut_on_lossless_link():
    """The decode-table early-admission projection inflates transmit
    time by the expected retransmission rate only on lossy links."""
    def interval(loss):
        sched = _RecSched("kvfetcher", max_running=4)
        req = Request(rid=0, arrival=0.0, prompt_len=32_000,
                      reuse_tokens=30_000, prefix="p")
        sched.submit(req, 0.0)
        sched.schedule(0.0)
        (fr,) = sched.take_fetches()
        ctrl = _controller(sched, loss=loss)
        ctrl.start(fr, synthetic_plan(0, 30_000, 9, 10_000), 0.0)
        return ctrl._projected_chunk_interval(ctrl.active[0], 0.0)

    base = interval(None)
    assert interval(LossModel.scripted(set())) == pytest.approx(base)
    lossy = interval(LossModel.bernoulli(0.2, seed=3))
    assert lossy > base


def test_early_admission_uses_decode_table_projection():
    """The projection is resolution-derived: transmit and decode overlap
    in pipelined mode, so the interval is max(transmit, decode) plus the
    restore event — and it still admits on a clean link."""
    sched, req, plan, ctrl = _one_fetch({"comp": [10.0] * 9})
    assert req.early_admitted  # projection admitted on a clean link
    sched2 = _RecSched("kvfetcher", max_running=4)
    r2 = Request(rid=0, arrival=0.0, prompt_len=32_000,
                 reuse_tokens=30_000, prefix="p")
    sched2.submit(r2, 0.0)
    sched2.schedule(0.0)
    (fr2,) = sched2.take_fetches()
    ctrl2 = _controller(sched2)
    ctrl2.start(fr2, synthetic_plan(0, 30_000, 9, 10_000), 0.0)
    f = ctrl2.active[0]
    proj = ctrl2._projected_chunk_interval(f, 0.0)
    # 50 MB over 1 Gbps is transmit-bound (decode ~0.04s scaled): the
    # interval is the transmit time plus the 0.002s restore hook
    tau_trans = 50e6 / ctrl2.link.bw_at(0.0)
    assert proj == pytest.approx(tau_trans + 0.002, rel=0.05)


# ---------------------------------------------------------------------------
# correlated (shared Gilbert-Elliott) loss
# ---------------------------------------------------------------------------

def _correlated_pair(seed=9):
    loss = LossModel.correlated(seed=seed, slot=0.3, good_to_bad=0.35,
                                bad_to_good=0.35, p_good=0.0, p_bad=1.0)
    sched = _RecSched("kvfetcher", max_running=4)
    rs = []
    for rid in range(2):
        r = Request(rid=rid, arrival=0.0, prompt_len=31_000,
                    reuse_tokens=30_000, prefix=f"p{rid}")
        sched.submit(r, 0.0)
        rs.append(r)
    sched.schedule(0.0)
    ctrl = _controller(sched, loss=loss)
    for r in sched.take_fetches():
        ctrl.start(r, synthetic_plan(r.rid, 30_000, 9, 10_000), 0.0)
    ctrl.pump(float("inf"))
    return rs, ctrl, loss


def test_correlated_loss_hits_concurrent_flows_together():
    rs, ctrl, loss = _correlated_pair()
    assert all(r.fetch_done is not None for r in rs)
    assert loss.drops and len(loss.drop_slots) == len(loss.drops)
    by_flow = {0: set(), 1: set()}
    for (flow, _, _), slot in zip(loss.drops, loss.drop_slots):
        by_flow[flow].add(slot)
    assert by_flow[0] and by_flow[1], "both flows must see the bursts"
    assert by_flow[0] & by_flow[1], \
        "a shared link state must drop concurrent flows in the same slot"


def test_correlated_loss_deterministic_across_runs():
    d1 = _correlated_pair()[2].drops
    d2 = _correlated_pair()[2].drops
    assert d1 == d2 and d1
    other = _correlated_pair(seed=10)[2].drops
    assert other != d1


def test_correlated_mean_loss_rate_matches_ge():
    ge = LossModel.gilbert_elliott(good_to_bad=0.1, bad_to_good=0.3,
                                   p_good=0.0, p_bad=0.5)
    corr = LossModel.correlated(good_to_bad=0.1, bad_to_good=0.3,
                                p_good=0.0, p_bad=0.5)
    assert corr.mean_loss_rate() == pytest.approx(ge.mean_loss_rate())


# ---------------------------------------------------------------------------
# slow-start link ramp
# ---------------------------------------------------------------------------

def test_slowstart_ramp_costs_the_joiner_then_converges():
    (solo_i,), _ = _staggered([0.0])
    (solo_s,), ctrl = _staggered([0.0], ramp="slowstart")
    # ramp-up underutilization: the slow-started flow finishes later...
    assert solo_s.fetch_done > solo_i.fetch_done
    # ...but only by the finite ramp cost (1/8 -> 1 doubling each epoch)
    assert solo_s.fetch_done < solo_i.fetch_done + 2.5
    assert ctrl.link._ramp == {}  # fully ramped state reclaimed


def test_slowstart_ramp_protects_the_incumbent_at_join():
    """A flow joining mid-transfer under slow start takes bandwidth
    gradually: the join hurts the incumbent less (its degradation versus
    a solo run shrinks) and costs the joiner more, relative to the
    instant-convergence model."""
    (solo_i,), _ = _staggered([0.0])
    (solo_s,), _ = _staggered([0.0], ramp="slowstart")
    instant, _ = _staggered([0.0, 2.0])
    slow, _ = _staggered([0.0, 2.0], ramp="slowstart")
    hit_instant = instant[0].fetch_done - solo_i.fetch_done
    hit_slow = slow[0].fetch_done - solo_s.fetch_done
    assert hit_slow < hit_instant, (hit_slow, hit_instant)
    assert slow[1].fetch_done > instant[1].fetch_done


def test_slowstart_ramp_drr_quantum():
    reqs, ctrl = _staggered([0.0, 0.5], policy="drr", ramp="slowstart")
    assert all(r.fetch_done is not None for r in reqs)
    link = ctrl.link
    assert link._order == [] and link._ramp == {} and link.in_flight == 0


def test_reopened_flow_ramp_is_not_double_advanced():
    """Regression (ISSUE 6): flow ids are reused — a flow closed mid-ramp
    and re-opened under the same id must not inherit the stale scheduled
    epoch of the previous open.  Without the per-open generation token the
    old chain's pending epoch double-advances the fresh ramp and forks a
    second doubling chain."""
    import heapq
    from repro.cluster.network import SharedLink

    link = SharedLink(BandwidthTrace.constant(1.0), ramp="slowstart")
    ev, seq = [], iter(range(1 << 20))
    link.bind(lambda t, fn: heapq.heappush(ev, (t, next(seq), fn)))

    def pump(until):
        while ev and ev[0][0] <= until:
            t, _, fn = heapq.heappop(ev)
            fn(t)

    link.open_flow(1, t=0.0)      # first open: epoch chain due at 0.5
    link.close_flow(1)            # closed mid-ramp...
    link.open_flow(1, t=0.3)      # ...reused id: fresh chain due at 0.8

    pump(0.6)  # stale epoch from the first open fires here
    assert link.ramp_factor(1) == link.ramp_init  # buggy: 2x ramp_init
    pump(0.85)  # the reopen's own first epoch
    assert link.ramp_factor(1) == 2 * link.ramp_init
    # one chain only: doublings land at 0.8/1.3/1.8, reaching full share
    pump(1.85)
    assert link.ramp_factor(1) == 1.0 and link._ramp == {}
    assert not ev  # no forked chain left ticking


def test_adaptive_rto_cuts_spurious_under_staggered_contention():
    """Flows joining a contended link shift everyone's service times;
    the adaptive RTO absorbs the shifts where the fixed grace fires."""
    rng = np.random.default_rng(1)
    trace = BandwidthTrace.jittered(rng, 1.0, duration=200.0,
                                    seg_len=0.3, rel_std=0.4)
    counts = {}
    for mode in ("fixed", "adaptive"):
        reqs, ctrl = _staggered([0.0, 0.7, 1.4, 2.1], rto_mode=mode,
                                trace=trace)
        assert all(r.fetch_done is not None for r in reqs)
        counts[mode] = ctrl.spurious_retransmits_total
    assert counts["adaptive"] < counts["fixed"], counts


# ---------------------------------------------------------------------------
# network.py API contracts
# ---------------------------------------------------------------------------

def test_trace_repr_shows_gbps():
    assert repr(BandwidthTrace.constant(2.0)) == "BandwidthTrace(2 Gbps)"
    r = repr(BandwidthTrace.steps([(0, 6), (5, 3)]))
    assert "Gbps" in r and "6" in r and "1e" not in r  # no raw bytes/sec


def test_make_link_idempotent_and_single_flow_degenerates():
    trace = BandwidthTrace.constant(1.0)
    link = make_link(trace, policy="fair")
    assert make_link(link) is link
    # single flow over a SharedLink matches the bare trace exactly
    done = []
    link.bind(lambda t, fn: done.append((t, fn)))
    link.open_flow(0)
    link.submit(0, 5e8, 0.0, lambda t: None)
    (t_ev, fn), = done
    assert t_ev == pytest.approx(trace.transmit(5e8, 0.0))


def test_drr_close_flow_reclaims_state_under_backlog():
    """Flows that finish while the link is busy serving OTHER flows must
    still be reclaimed from the round-robin state (leak regression)."""
    sched = _RecSched("kvfetcher", max_running=16)
    reqs = []
    for rid in range(6):
        r = Request(rid=rid, arrival=0.0, prompt_len=11_000,
                    reuse_tokens=10_000, prefix=f"p{rid}")
        sched.submit(r, 0.0)
        reqs.append(r)
    sched.schedule(0.0)
    ctrl = _controller(sched, policy="drr")
    for r in sched.take_fetches():
        ctrl.start(r, synthetic_plan(r.rid, 10_000, 9, 10_000), 0.0)
    ctrl.pump(float("inf"))
    assert all(r.fetch_done is not None for r in reqs)
    link = ctrl.link
    assert link._order == [] and link._deficit == {}
    assert link._weights == {} and link.in_flight == 0


def test_mean_loss_rate():
    assert LossModel.bernoulli(0.03).mean_loss_rate() == pytest.approx(0.03)
    ge = LossModel.gilbert_elliott(good_to_bad=0.1, bad_to_good=0.3,
                                   p_good=0.0, p_bad=0.5)
    assert ge.mean_loss_rate() == pytest.approx(0.125)
    assert LossModel.scripted({(0, 0, 1)}).mean_loss_rate() == 0.0


# ---------------------------------------------------------------------------
# cross-environment determinism: simulator vs live engine (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_loss_schedule_identical_in_simulator_and_live_engine(
        tiny_cfg, tiny_params, registered_store):
    """Seeded LossModel replays the identical drop schedule through the
    analytic simulator and the virtual-clock live engine when both walk
    identically-shaped plans (same rid, chunk seq, attempt keys)."""
    import dataclasses as dc

    from repro.cluster.simulator import MethodSpec, ServingSimulator
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(5)
    prefix = rng.integers(0, tiny_cfg.vocab_size, 48)
    full = np.concatenate([prefix, rng.integers(0, tiny_cfg.vocab_size, 8)])
    store, key = registered_store(prefix, tokens_per_chunk=16,
                                  resolutions=("240p",))

    # live engine: async virtual clock, real codec, 2% -> 35% loss to be
    # sure drops occur on this small plan
    loss_eng = LossModel.bernoulli(0.35, seed=21)
    eng = LiveEngine(tiny_params, tiny_cfg, store, policy="kvfetcher",
                     fetch_mode="async",
                     bandwidth=BandwidthTrace.constant(0.0006),
                     loss=loss_eng, resolution="240p")
    r = eng.submit(full, reuse_prefix=key, reuse_tokens=48,
                   max_new_tokens=2)
    eng.run()
    assert r.rid == 0 and r.fetch_done is not None

    # simulator: same cfg geometry (same rid / groups / chunk count)
    loss_sim = LossModel.bernoulli(0.35, seed=21)
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=False)
    sim = ServingSimulator(tiny_cfg, spec,
                           bandwidth=BandwidthTrace.constant(0.0006),
                           loss=loss_sim, chunk_tokens=16)
    req = Request(rid=0, arrival=0.0, prompt_len=56, reuse_tokens=48,
                  prefix="p")
    sim.run([req], max_new_tokens=2)
    assert req.fetch_done is not None

    assert sorted(loss_eng.drops) == sorted(loss_sim.drops)
    assert loss_eng.drops, "loss never fired; test is vacuous"

    # despite retransmits, restoration is lossless: same tokens as a
    # clean (no-loss) run of the same engine
    eng2 = LiveEngine(tiny_params, tiny_cfg, store, policy="kvfetcher",
                      fetch_mode="async",
                      bandwidth=BandwidthTrace.constant(0.0006),
                      resolution="240p")
    r2 = eng2.submit(full, reuse_prefix=key, reuse_tokens=48,
                     max_new_tokens=2)
    eng2.run()
    assert eng.outputs[r.rid] == eng2.outputs[r2.rid]


@pytest.mark.slow
def test_correlated_loss_schedule_identical_in_simulator_and_live_engine(
        tiny_cfg, tiny_params, registered_store):
    """ISSUE 5 acceptance: the shared (cross-flow correlated) Gilbert-
    Elliott state is indexed by virtual time, so the determinism contract
    is "identical wire timings -> identical drop/burst schedules".  Both
    environments model Appx A.2 table chunk sizes over the same link
    (``use_table_sizes``), which makes their wire timelines byte-
    identical — the seeded correlated model must then replay the exact
    same drop schedule AND the same burst slots through the real live
    engine and the analytic simulator."""
    from repro.core.adaptive import DecodeTable
    from repro.cluster.simulator import (MethodSpec, RESOLUTIONS,
                                         ServingSimulator)
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(5)
    prefix = rng.integers(0, tiny_cfg.vocab_size, 48)
    full = np.concatenate([prefix, rng.integers(0, tiny_cfg.vocab_size, 8)])
    store, key = registered_store(prefix, tokens_per_chunk=16,
                                  resolutions=("240p",))
    table = DecodeTable(
        name="xenv", n_decoders=2,
        latency={r: (0.04, 0.05) for r in RESOLUTIONS},
        penalty={"240p": 0.01, "480p": 0.008, "640p": 0.004, "1080p": 0.0},
        chunk_size_mb={r: 0.004 for r in RESOLUTIONS})
    trace = BandwidthTrace.constant(0.0006)  # 75 kB/s: ~53 ms per chunk

    def corr():
        return LossModel.correlated(seed=31, slot=0.08, good_to_bad=0.35,
                                    bad_to_good=0.4, p_good=0.0,
                                    p_bad=0.85)

    loss_eng = corr()
    eng = LiveEngine(tiny_params, tiny_cfg, store, policy="kvfetcher",
                     fetch_mode="async", bandwidth=trace, loss=loss_eng,
                     decode_table=table, use_table_sizes=True,
                     resolution="240p")
    r = eng.submit(full, reuse_prefix=key, reuse_tokens=48,
                   max_new_tokens=2)
    eng.run()
    assert r.rid == 0 and r.fetch_done is not None

    loss_sim = corr()
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=True,
                      use_table_sizes=True, layerwise_admission=True)
    sim = ServingSimulator(tiny_cfg, spec, bandwidth=trace, loss=loss_sim,
                           table=table, chunk_tokens=16)
    req = Request(rid=0, arrival=0.0, prompt_len=56, reuse_tokens=48,
                  prefix="p")
    sim.run([req], max_new_tokens=2)
    assert req.fetch_done is not None

    assert loss_eng.drops, "correlated loss never fired; test is vacuous"
    assert sorted(loss_eng.drops) == sorted(loss_sim.drops)
    assert sorted(loss_eng.drop_slots) == sorted(loss_sim.drop_slots), \
        "burst slots must replay identically across environments"
