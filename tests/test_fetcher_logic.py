"""Unit tests: Alg. 1 adaptive resolution, Appx A.3 pipeline condition,
fetching-aware scheduler queue behaviour, fetch plans and manifests."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.adaptive import (
    GBPS, H20_TABLE, L20_TABLE, BandwidthEstimator, pipelined_time,
    select_resolution,
)
from repro.core.chunks import (
    decode_chunk_tokens, decode_state_snapshot, encode_prefix,
    encode_state_snapshot,
)
from repro.core.fetch import build_plan
from repro.core.pipelining import max_admission_buffer, non_blocking_ok
from repro.core.scheduler import FetchingAwareScheduler, ReqState, Request


# ---------------------------------------------------------------------------
# Alg. 1
# ---------------------------------------------------------------------------

def test_adaptive_prefers_low_res_on_slow_network():
    r_slow, _ = select_resolution(1 * GBPS, 0, H20_TABLE)
    r_fast, _ = select_resolution(40 * GBPS, 0, H20_TABLE)
    order = ["240p", "480p", "640p", "1080p"]
    assert order.index(r_slow) <= order.index(r_fast)
    assert r_slow == "240p"


def test_adaptive_paper_example_fig17():
    """Paper Fig.17: at ~3 Gbps with the H20 table the adapter picks 240p;
    when bandwidth recovers it moves to a higher resolution.  (Under the
    ABR objective with the pool-drain decode model the recovery point is
    ~24 Gbps — below that the 7-decoder pool drains any rung faster than
    the wire delivers it, so transmit binds and 240p's smaller chunks
    stay cheapest; 40 Gbps recovers to 1080p with margin.)"""
    r3, _ = select_resolution(3 * GBPS, 0, H20_TABLE,
                              active_resolution="1080p")
    r40, _ = select_resolution(40 * GBPS, 0, H20_TABLE,
                               active_resolution=r3)
    order = ["240p", "480p", "640p", "1080p"]
    assert order.index(r3) < order.index(r40)


def test_adaptive_accounts_for_pool_load():
    # under heavy pool load decode gets slower -> larger chunks tolerated
    r_idle, b_idle = select_resolution(8 * GBPS, 0, H20_TABLE)
    r_busy, b_busy = select_resolution(8 * GBPS, 6, H20_TABLE)
    order = ["240p", "480p", "640p", "1080p"]
    assert order.index(r_busy) >= order.index(r_idle)


@given(st.floats(0.5, 100), st.integers(0, 6))
@settings(max_examples=50, deadline=None)
def test_adaptive_returns_min_total_time(gbps, load):
    """ABR objective (ISSUE 7): the winner's total pipelined time
    max(transmit, decode) is minimal over the whole ladder."""
    res, t_best = select_resolution(gbps * GBPS, load, H20_TABLE)
    assert t_best == pytest.approx(
        pipelined_time(gbps * GBPS, load, H20_TABLE, res))
    for r in H20_TABLE.latency:
        assert t_best <= pipelined_time(gbps * GBPS, load,
                                        H20_TABLE, r) + 1e-9


def test_bandwidth_estimator():
    est = BandwidthEstimator(10 * GBPS)
    est.observe(int(1 * GBPS), 1.0)  # 1 Gbps observed
    assert est.est == pytest.approx(1 * GBPS)


# ---------------------------------------------------------------------------
# Appx A.3 layer-wise pipeline condition
# ---------------------------------------------------------------------------

def test_non_blocking_condition():
    # decode each layer 1s, compute each layer 2s: after 1 buffered layer
    # decode always stays ahead
    dec = [1.0] * 8
    comp = [2.0] * 8
    assert not non_blocking_ok(dec, comp, 0)  # layer 1 would stall
    assert non_blocking_ok(dec, comp, 1)
    assert max_admission_buffer(dec, comp) == 1
    # slow decode: must buffer everything
    dec2 = [5.0] * 8
    assert max_admission_buffer(dec2, comp) == 8


@given(st.lists(st.floats(0.01, 5), min_size=1, max_size=12),
       st.lists(st.floats(0.01, 5), min_size=1, max_size=12))
@settings(max_examples=50, deadline=None)
def test_max_admission_buffer_is_minimal(dec, comp):
    n = min(len(dec), len(comp))
    dec, comp = dec[:n], comp[:n]
    lb = max_admission_buffer(dec, comp)
    assert non_blocking_ok(dec, comp, lb)
    if lb > 0:
        assert not non_blocking_ok(dec, comp, lb - 1)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def _reqs():
    a = Request(rid=1, arrival=0.0, prompt_len=50_000, reuse_tokens=40_000,
                prefix="p1")
    b = Request(rid=2, arrival=0.1, prompt_len=1_000)
    c = Request(rid=3, arrival=0.2, prompt_len=2_000)
    return a, b, c


def test_kvfetcher_scheduler_no_hol_blocking():
    s = FetchingAwareScheduler("kvfetcher", max_running=2)
    a, b, c = _reqs()
    for r in (a, b, c):
        s.submit(r, r.arrival)
    admitted = s.schedule(0.3)
    # fetching request A is isolated; B and C run immediately
    assert [r.rid for r in admitted] == [2, 3]
    assert a.state is ReqState.WAITING_FOR_KV
    assert [r.rid for r in s.take_fetches()] == [1]
    # fetch completes -> A readmitted at queue head
    s.finish(b, 1.0)
    a.fetch_started = 0.3
    s.notify_fetch_done(a, 2.0)
    admitted = s.schedule(2.0)
    assert [r.rid for r in admitted] == [1]


def test_fetch_agnostic_scheduler_hol_blocks():
    s = FetchingAwareScheduler("fetch_agnostic", max_running=2)
    a, b, c = _reqs()
    for r in (a, b, c):
        s.submit(r, r.arrival)
    admitted = s.schedule(0.3)
    assert admitted == []  # A blocks the head of the FCFS queue
    a.fetch_started = 0.3
    s.notify_fetch_done(a, 5.0)
    admitted = s.schedule(5.0)
    assert [r.rid for r in admitted] == [1, 2]


def test_early_admission_via_layerwise_condition():
    s = FetchingAwareScheduler("kvfetcher", max_running=2)
    a, _, _ = _reqs()
    s.submit(a, 0.0)
    s.schedule(0.0)
    assert a.state is ReqState.WAITING_FOR_KV
    s.notify_early_admissible(a, 1.0)
    admitted = s.schedule(1.0)
    assert admitted == [a] and a.early_admitted


# ---------------------------------------------------------------------------
# Manifests / fetch plans / state snapshots
# ---------------------------------------------------------------------------

def _manifest(T=64, L=5, H=4, D=16):
    rng = np.random.default_rng(0)
    kv_k = rng.standard_normal((T, L, H, D)).astype(np.float32)
    kv_v = rng.standard_normal((T, L, H, D)).astype(np.float32)
    return encode_prefix(kv_k, kv_v, prefix="p", tokens_per_chunk=32,
                         resolutions=("240p", "1080p")), kv_k, kv_v


def test_manifest_roundtrip_and_plan_order():
    man, kv_k, kv_v = _manifest()
    assert man.layer_groups == [(0, 1, 2), (3, 4)]
    plan = build_plan(1, man)
    # layer-group-major ordering
    groups = [pc.ref.group for pc in plan.chunks]
    assert groups == sorted(groups)
    assert plan.n_layers_total == 5
    # decode one chunk and compare with quantization-only error bound
    ref = plan.chunks[0].ref
    deq = decode_chunk_tokens(man, ref.chunk_id, "240p", 4, 16)
    orig = kv_k[ref.token_start:ref.token_end][:, list(ref.layers)]
    sc = man.scales["k"][list(ref.layers)]
    assert (np.abs(deq - orig) <= sc[None, :, :, None] * 0.5 + 1e-6).all()
    # layer readiness tracks restored chunks front-to-back
    assert plan.layers_ready() == 0
    for pc in plan.chunks:
        if pc.ref.group == 0:
            pc.t_restored = 1.0
    assert plan.layers_ready() == 3
    for pc in plan.chunks:
        pc.t_restored = 1.0
    assert plan.layers_ready() == 5 and plan.done


def test_state_snapshot_roundtrip():
    rng = np.random.default_rng(1)
    states = {"layer0.state": rng.standard_normal((8, 16, 4)).astype(
        np.float32), "layer0.conv": rng.standard_normal((3, 32)).astype(
        np.float32)}
    blob = encode_state_snapshot(states)
    back = decode_state_snapshot(blob)
    for k in states:
        absmax = np.abs(states[k]).max()
        assert back[k].shape == states[k].shape
        assert np.abs(back[k] - states[k]).max() <= absmax / 127 * 0.51
