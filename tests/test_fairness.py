"""User-level fair scheduling (ISSUE 8).

Four layers:

  * property tests of the VTC fair scheduler through the offline
    `_hypothesis_compat` seed bank: work conservation (the link never
    idles while any user has queued fetches), bounded unfairness (the
    counter gap between continuously backlogged users never exceeds one
    request-cost), and weight monotonicity (doubling a tier's weight
    never lowers that tier's dispatch share, at every prefix of the
    dispatch order);
  * unit tests of the fairness levers: the idle-rejoin counter lift
    (no banked credit), deterministic tie-breaking, the storage-tier
    pin/admission-seed mapping, and the per-user prefetch mispredict
    budget split;
  * seeded tests of the `workload.zipf_user_population` generator
    (determinism, Zipf rank-frequency shape, scripted-abuser
    placement);
  * a fast fair-vs-FCFS simulator run under an abusive flood, and a
    cross-environment determinism test (slow): the analytic simulator
    and the virtual-clock live engine replay the *identical* fairness
    event log under an abusive-user flood with a storage-node failure
    mid-trace.
"""
from collections import deque

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.cluster.fairness import COUNTER_QUANT, FairScheduler
from repro.cluster.network import BandwidthTrace, make_link
from repro.cluster.staging import HostStagingTier, PrefetchManager
from repro.cluster.storage import StorageCluster, StorageNode, StoredPrefix
from repro.core.adaptive import DecodeTable
from repro.core.fetch import synthetic_plan
from repro.core.fetch_controller import FetchController, PipelineConfig
from repro.core.scheduler import FetchingAwareScheduler, Request

#: single-rung toy ladder: 2 kB chunks over a 75 kB/s link, so one
#: chunk's wire time is exactly 2000/75000 s and makespans close-form
FAIR_TABLE = DecodeTable(
    name="fair-toy", n_decoders=1,
    latency={"240p": (0.06,)}, penalty={"240p": 0.0},
    chunk_size_mb={"240p": 0.002})

TRACE_GBPS = 0.0006  # 75 kB/s
RATE_BPS = 75_000.0
CHUNK_BYTES = 2_000.0

TIER_NAMES = ("free", "standard", "premium")


def _req(rid, user, tier, *, chunks=2, arrival=0.0, max_new=4):
    reuse = chunks * 1_000
    return Request(rid=rid, arrival=arrival, prompt_len=reuse + 100,
                   reuse_tokens=reuse, prefix=f"pfx.{rid}",
                   max_new_tokens=max_new, user=user, slo_tier=tier)


# ---------------------------------------------------------------------------
# property: work conservation
# ---------------------------------------------------------------------------

def _drain(reqs, fair):
    """Drive a controller-level fetch pipeline to completion: schedule ->
    take_fetches -> start, then pump events one at a time; returns the
    makespan (time of the last pipeline event)."""
    sched = FetchingAwareScheduler("kvfetcher", max_running=64,
                                   fairness=fair)
    link = make_link(BandwidthTrace.constant(TRACE_GBPS))
    ctrl = FetchController(
        sched, link, table=FAIR_TABLE, pool=None,
        config=PipelineConfig(adaptive=False, fixed_resolution="240p",
                              pipelined=False, layerwise_admission=False,
                              use_table_sizes=True, resolutions=("240p",)))
    plans = {r.rid: synthetic_plan(r.rid, r.reuse_tokens, 3, 1_000)
             for r in reqs}
    for r in reqs:
        sched.submit(r, 0.0)
    now, guard = 0.0, 0
    while True:
        guard += 1
        assert guard < 100_000, "fetch pipeline never drained"
        sched.schedule(now)
        started = sched.take_fetches()
        for r in started:
            ctrl.start(r, plans[r.rid], now)
        # work conservation at the dispatch level: after draining free
        # slots, backlog may remain only because every slot is taken
        if fair.backlog_size() > 0:
            assert fair.inflight_size() == fair.max_inflight, \
                "a free slot idled while users had queued fetches"
        if started:
            continue
        t = ctrl.pump_next()
        if t is None:
            break
        now = max(now, t)
    # makespan = last delivery; later pump events are only the cancelled
    # retransmit timers of already-delivered chunks firing as no-ops
    return max(r.fetch_done for r in reqs), plans


@given(st.lists(st.integers(0, 2), min_size=1, max_size=10),
       st.lists(st.integers(1, 4), min_size=10, max_size=10))
@settings(max_examples=25, deadline=None)
def test_work_conservation_link_never_idles(owners, sizes):
    """With a serial dispatch slot (max_inflight=1) over a chunk-serial
    pipeline with zero decode/restore cost, a work-conserving scheduler
    keeps the wire busy 100% of the makespan: total time must equal
    total wire bytes / link rate exactly, for any mix of users, tiers,
    and fetch sizes.  Any idle gap (a slot left open while a user had
    backlog) would show up as makespan > wire time."""
    fair = FairScheduler(max_inflight=1)
    reqs = [_req(i, f"u{o}", TIER_NAMES[o], chunks=sizes[i])
            for i, o in enumerate(owners)]
    makespan, plans = _drain(reqs, fair)
    assert all(r.fetch_done is not None for r in reqs)
    total_chunks = sum(len(p.chunks) for p in plans.values())
    assert makespan == pytest.approx(total_chunks * CHUNK_BYTES / RATE_BPS,
                                     rel=1e-9)
    # every fetch passed through exactly one dispatch and one completion
    kinds = {}
    for user, rid, kind, _ in fair.events:
        kinds.setdefault(rid, []).append(kind)
    for r in reqs:
        assert kinds[r.rid].count("dispatch") == 1
        assert kinds[r.rid].count("fetched") == 1


# ---------------------------------------------------------------------------
# property: bounded unfairness
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(0, 2), min_size=4, max_size=20),
       st.lists(st.floats(0.1, 5.0), min_size=26, max_size=26),
       st.lists(st.integers(0, 9), min_size=0, max_size=6))
@settings(max_examples=30, deadline=None)
def test_bounded_unfairness_counter_gap(owners, costs, late_steps):
    """VTC's fairness bound: among users that still have backlog, the
    counter gap after any completion never exceeds the largest single
    weighted request cost — one request is the granularity of
    unfairness.  Holds under mid-run arrivals too (the idle-rejoin lift
    keeps joiners inside the window)."""
    fair = FairScheduler(max_inflight=1, byte_unit=1.0)
    users = [f"u{i}" for i in range(3)]
    reqs = [_req(i, users[o], TIER_NAMES[o]) for i, o in enumerate(owners)]
    # hypothesis-chosen injection steps for a tail of late arrivals
    late = deque(sorted(
        ((step, _req(len(owners) + j, users[j % 3], TIER_NAMES[j % 3]))
         for j, step in enumerate(late_steps)), key=lambda p: p[0]))
    for r in reqs:
        fair.on_arrival(r)
        fair.enqueue(r)
    w_min = min(fair.tiers.values())
    bound = max(costs) / w_min + 1e-9
    step = 0
    while fair.backlog_size() or late:
        while late and late[0][0] <= step:
            _, r = late.popleft()
            fair.on_arrival(r)
            fair.enqueue(r)
        out = fair.take()
        if not out:
            step += 1
            continue
        (r,) = out
        fair.on_fetch_done(r, costs[r.rid])
        backlogged = [u for u in users if fair.backlog_size(u)]
        if len(backlogged) >= 2:
            cs = [fair.counters[u] for u in backlogged]
            assert max(cs) - min(cs) <= bound, \
                (backlogged, cs, bound, fair.events)
        step += 1
    assert fair.inflight_size() == 0 and fair.backlog_size() == 0


# ---------------------------------------------------------------------------
# property: weight monotonicity
# ---------------------------------------------------------------------------

@given(st.integers(2, 8), st.lists(st.integers(1, 6), min_size=2,
                                   max_size=3),
       st.floats(0.5, 4.0), st.lists(st.floats(0.2, 3.0), min_size=4,
                                     max_size=4))
@settings(max_examples=30, deadline=None)
def test_weight_monotonicity_doubling_never_lowers_share(
        n_gold, n_iron, gold_w, user_costs):
    """Doubling a tier's weight never lowers its users' dispatch count
    within ANY prefix of the dispatch order: serial min-counter
    scheduling equals a global sort of each user's virtual start
    values, and halving gold's counter growth only moves its entries
    earlier in that order."""
    def run(w):
        fair = FairScheduler(max_inflight=1, byte_unit=1.0,
                             tiers={"gold": w, "iron": 1.0})
        reqs = [_req(i, "gold", "gold") for i in range(n_gold)]
        for j, cnt in enumerate(n_iron):
            base = len(reqs)
            reqs += [_req(base + i, f"iron{j}", "iron")
                     for i in range(cnt)]
        for r in reqs:
            fair.on_arrival(r)
            fair.enqueue(r)
        order = []
        while True:
            out = fair.take()
            if not out:
                break
            (r,) = out
            order.append(r.user)
            # per-user constant cost, fixed across both runs
            cost = user_costs[0] if r.user == "gold" else \
                user_costs[1 + int(r.user[4:]) % 3]
            fair.on_fetch_done(r, cost)
        return order
    lo, hi = run(gold_w), run(2.0 * gold_w)
    assert len(lo) == len(hi) == n_gold + sum(n_iron)
    for d in range(1, len(lo) + 1):
        assert hi[:d].count("gold") >= lo[:d].count("gold"), \
            (d, lo, hi)


# ---------------------------------------------------------------------------
# unit: counter lift, tie-breaks, idempotent charges
# ---------------------------------------------------------------------------

def test_idle_rejoin_lifts_counter_to_active_minimum():
    """A user that idles while others are served re-enters at the
    minimum active counter — idling banks no credit (VTC no-gaming)."""
    fair = FairScheduler(max_inflight=1, byte_unit=1.0,
                         tiers={"flat": 1.0})
    r0, r1 = (_req(0, "busy", "flat"), _req(1, "busy", "flat"))
    for r in (r0, r1):
        fair.on_arrival(r)
        fair.enqueue(r)
    (d0,) = fair.take()
    fair.on_fetch_done(d0, 5.0)
    assert fair.counters["busy"] == pytest.approx(5.0)
    # joiner arrives while busy still has backlog: lifted to min(active)
    r2 = _req(2, "joiner", "flat")
    fair.on_arrival(r2)
    assert fair.counters["joiner"] == pytest.approx(5.0)
    assert fair.events[-1] == ("joiner", 2, "arrive",
                               int(round(5.0 * COUNTER_QUANT)))
    # ...so the incumbent's queued request is not starved by the joiner
    fair.enqueue(r2)
    (d1,) = fair.take()
    assert fair.user_of(d1) == "busy"


def test_take_tiebreaks_heavier_tier_then_name():
    fair = FairScheduler(max_inflight=None, byte_unit=1.0)
    reqs = [_req(0, "zed", "standard"), _req(1, "amy", "standard"),
            _req(2, "pri", "premium")]
    for r in reqs:
        fair.on_arrival(r)
        fair.enqueue(r)
    order = [fair.user_of(r) for r in fair.take()]
    # equal counters: heavier tier first, then lexicographic
    assert order == ["pri", "amy", "zed"]


def test_serve_and_fetch_charges_are_idempotent_per_rid():
    fair = FairScheduler(max_inflight=1, byte_unit=1.0, token_unit=1.0,
                         output_token_weight=2.0)
    r = _req(0, "u", "standard", chunks=1, max_new=4)
    fair.on_arrival(r)
    fair.enqueue(r)
    fair.take()
    fair.on_fetch_done(r, 3.0)
    fair.on_fetch_done(r, 3.0)  # wall-clock fallback double-notify
    fair.on_fetch_miss(r)  # slot already released: no-op
    fair.on_admit(r)
    fair.on_admit(r)
    w = fair.weight_of("u")
    expect = (3.0 + (r.prompt_len - r.reuse_tokens) + 2.0 * 4) / w
    assert fair.counters["u"] == pytest.approx(expect)
    assert [k for _, _, k, _ in fair.events] == \
        ["arrive", "dispatch", "fetched", "serve"]


# ---------------------------------------------------------------------------
# unit: storage-tier priority mapping
# ---------------------------------------------------------------------------

def test_apply_storage_priority_pins_and_seeds_admission():
    cluster = StorageCluster(
        [StorageNode("n0"), StorageNode("n1")],
        admission="second_hit", admission_min_asks=2)
    for key in ("k.p", "k.s", "k.f"):
        cluster.register(StoredPrefix(key=key, n_tokens=1_000,
                                      bytes_by_resolution={"240p": 1_000},
                                      raw_kv_bytes=64_000), 0.0)
    fair = FairScheduler()
    for user, tier in (("prem", "premium"), ("std", "standard"),
                       ("free", "free")):
        fair.register(user, tier)
    # top tier: pinned + admission seeded
    assert fair.apply_storage_priority(cluster, "prem", "k.p")
    assert cluster.catalog["k.p"].pinned
    assert cluster.asks_by_key["k.p"] == cluster.admission_min_asks
    # middle tier: seeded, not pinned
    assert fair.apply_storage_priority(cluster, "std", "k.s")
    assert not cluster.catalog["k.s"].pinned
    assert cluster.asks_by_key["k.s"] == cluster.admission_min_asks
    # bottom tier: earns residency like everyone else
    assert fair.apply_storage_priority(cluster, "free", "k.f")
    assert not cluster.catalog["k.f"].pinned
    assert cluster.asks_by_key.get("k.f", 0) < cluster.admission_min_asks
    # unknown key: nothing to attach to
    assert not fair.apply_storage_priority(cluster, "prem", "k.none")


# ---------------------------------------------------------------------------
# unit: per-user prefetch budget shares
# ---------------------------------------------------------------------------

def test_prefetch_budget_split_by_tier_weight():
    cluster = StorageCluster([StorageNode("n0")])
    for key in ("p.a", "p.b"):
        cluster.register(StoredPrefix(key=key, n_tokens=1_000,
                                      bytes_by_resolution={"240p": 1_000},
                                      raw_kv_bytes=64_000), 0.0)
    fair = FairScheduler()
    # demand traffic attributes each prefix to its user
    fair.on_arrival(Request(rid=0, arrival=0.0, prompt_len=1_100,
                            reuse_tokens=1_000, prefix="p.a",
                            user="alice", slo_tier="premium"))
    fair.on_arrival(Request(rid=1, arrival=0.0, prompt_len=1_100,
                            reuse_tokens=1_000, prefix="p.b",
                            user="bob", slo_tier="free"))
    assert fair.prefetch_share("alice") == pytest.approx(0.8)  # 4/(4+1)
    assert fair.prefetch_share("bob") == pytest.approx(0.2)
    pm = PrefetchManager(cluster, HostStagingTier(1e9),
                         mispredict_budget_bytes=1_000.0,
                         transport="sync", fairness=fair)
    # bob burns past his 200-byte share: HIS speculation is declined,
    # alice's 800-byte share is untouched
    pm._account_waste("p.b", 250.0)
    assert pm._over_budget("p.b") and not pm._over_budget("p.a")
    assert pm.request_prefetch("p.b", 0.0) is False
    assert pm.events[-1] == ("budget_reject", "p.b")
    assert pm.wasted_by_user == {"bob": 250.0}
    # alice under her cap: still allowed; over it: declined too
    pm._account_waste("p.a", 700.0)
    assert not pm._over_budget("p.a")
    pm._account_waste("p.a", 200.0)
    assert pm._over_budget("p.a")
    # without fairness the same waste would have tripped the global cap
    pm_flat = PrefetchManager(cluster, HostStagingTier(1e9),
                              mispredict_budget_bytes=1_000.0,
                              transport="sync")
    pm_flat._account_waste("p.b", 250.0)
    assert not pm_flat._over_budget("p.b")


# ---------------------------------------------------------------------------
# workload: zipf_user_population
# ---------------------------------------------------------------------------

def _population(seed=11, **kw):
    from repro.data.workload import prefix_trie_specs, zipf_user_population
    specs = prefix_trie_specs(3, 1, base_tokens=4_000)
    rng = np.random.default_rng(seed)
    return zipf_user_population(rng, specs, **kw), specs


def test_zipf_population_seeded_determinism():
    a, _ = _population(n_users=8, n_requests=30, n_abusers=2)
    b, _ = _population(n_users=8, n_requests=30, n_abusers=2)
    key = [(r.rid, r.arrival, r.prompt_len, r.reuse_tokens, r.prefix,
            r.user, r.slo_tier) for r in a]
    assert key == [(r.rid, r.arrival, r.prompt_len, r.reuse_tokens,
                    r.prefix, r.user, r.slo_tier) for r in b]
    assert [r.rid for r in a] == list(range(len(a)))
    assert all(t0.arrival <= t1.arrival for t0, t1 in zip(a, a[1:]))


def test_zipf_population_rank_frequency_shape():
    reqs, _ = _population(n_users=6, n_requests=400, alpha=1.4,
                          n_abusers=0, abuse_burst=0)
    counts = {f"user{i:03d}": 0 for i in range(6)}
    for r in reqs:
        counts[r.user] += 1
    # Zipf over rank: the head user dominates, the tail is light
    assert counts["user000"] == max(counts.values())
    assert counts["user000"] > 2 * counts["user005"]
    # tiers stripe by rank
    assert {r.slo_tier for r in reqs if r.user == "user000"} == {"premium"}
    assert {r.slo_tier for r in reqs if r.user == "user001"} == {"standard"}


def test_zipf_population_scripted_abuser_placement():
    n_bg, burst = 24, 5
    reqs, specs = _population(n_users=4, n_requests=n_bg, n_abusers=2,
                              abuse_burst=burst, abuse_at=7)
    flood = [r for r in reqs if r.user.startswith("abuser")]
    assert len(flood) == 2 * burst
    assert len(reqs) == n_bg + len(flood)
    # the flood sits contiguously right after its trigger request and
    # shares its arrival instant
    idx = [i for i, r in enumerate(reqs) if r.user.startswith("abuser")]
    assert idx == list(range(idx[0], idx[0] + len(flood)))
    trigger = reqs[idx[0] - 1]
    assert all(r.arrival == trigger.arrival for r in flood)
    # abusers ride the lowest tier and hammer the hottest prefix
    assert {r.slo_tier for r in flood} == {"free"}
    assert {r.prefix for r in flood} == {specs[0].key}


# ---------------------------------------------------------------------------
# integration: fair scheduling beats FCFS for well-behaved users
# ---------------------------------------------------------------------------

def test_fair_dispatch_beats_fcfs_under_abusive_flood():
    """An abusive flood starves well-behaved TTFT under plain FCFS
    fetch dispatch; VTC fair dispatch restores it (the bench's
    ttft.fairness.* rows gate the measured ratio — this is the fast
    structural version)."""
    from repro.cluster.simulator import ServingSimulator, kvfetcher_spec
    from repro.configs import get_config
    from repro.core.adaptive import H20_TABLE
    from repro.data.workload import prefix_trie_specs, zipf_user_population

    cfg = get_config("yi-34b")
    ratios = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}
    specs = prefix_trie_specs(2, 1, base_tokens=40_000)

    def run(fair):
        rng = np.random.default_rng(7)
        reqs = zipf_user_population(rng, specs, n_users=6, n_requests=12,
                                    abuse_burst=10, gap=6.0)
        sim = ServingSimulator(
            cfg, kvfetcher_spec(ratios),
            bandwidth=BandwidthTrace.constant(8.0), table=H20_TABLE,
            fairness=FairScheduler(max_inflight=2) if fair else None)
        res = sim.run(reqs, max_new_tokens=8)
        good = [r.ttft for r in res.requests
                if r.user.startswith("user")]
        assert all(t is not None for t in good)
        return max(good), res

    t_fcfs, _ = run(False)
    t_fair, res = run(True)
    assert t_fair < t_fcfs, (t_fair, t_fcfs)
    kinds = {k for _, _, k, _ in res.fairness_events}
    assert {"arrive", "dispatch", "fetched", "serve"} <= kinds
    # abusive fetches really were held in the backlog at some point
    assert any(u.startswith("abuser") for u, _, k, _ in res.fairness_events
               if k == "dispatch")


# ---------------------------------------------------------------------------
# cross-environment determinism (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fairness_event_log_identical_in_simulator_and_live_engine(
        tiny_cfg, tiny_params, donor_kv):
    """ISSUE 8 acceptance: an abusive-user flood with a storage-node
    failure mid-trace replays the byte-identical fairness event log
    ``(user, rid, kind, counter)`` in the analytic simulator and the
    virtual-clock live engine.  Every charge is a pure function of
    env-identical quantities (table chunk sizes, token counts) and the
    serial dispatch slot makes the event order loop-structural, so the
    logs must match tuple for tuple."""
    from repro.cluster.costmodel import CHIPS, EngineCostModel
    from repro.cluster.simulator import MethodSpec, ServingSimulator
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(12)
    tok_a = rng.integers(0, tiny_cfg.vocab_size, 48)  # victims' prefix
    tok_b = [rng.integers(0, tiny_cfg.vocab_size, 48)
             for _ in range(4)]  # abuser floods distinct prefixes
    suffix = rng.integers(0, tiny_cfg.vocab_size, 8)
    trace = BandwidthTrace.constant(TRACE_GBPS)
    t_fail = 0.05  # mid first fetch: every later lookup sees the churn

    def build_cluster(live):
        nodes = [StorageNode("n0"), StorageNode("n1")]
        # heal="manual" and nobody pumps: the failed node's keys stay
        # lost for the rest of the trace (clock-free, replay-exact)
        c = StorageCluster(nodes, replication=1, heal="manual")
        if live:
            for toks in [tok_a] + tok_b:
                kv_k, kv_v = donor_kv(toks)
                c.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                                  resolutions=("240p",))
        return c

    live = build_cluster(True)
    keys = list(live.catalog)  # [a, b0..b3] in registration order
    # the node NOT holding the victims' prefix dies mid-trace: victims
    # keep hitting, the abuser's prefixes on it miss from then on
    doomed = next(n.node_id for n in live.nodes
                  if n.node_id != live.primary_node(keys[0]).node_id)
    doomed_keys = [k for k in keys[1:]
                   if live.primary_node(k).node_id == doomed]
    assert doomed_keys, "churn would be invisible; pick another seed"

    # (user, tier, prompt tokens, prefix key) in submit order
    script = ([("alice", "premium", tok_a, keys[0]),
               ("bob", "standard", tok_a, keys[0]),
               ("alice", "premium", tok_a, keys[0]),
               ("bob", "standard", tok_a, keys[0])]
              + [("mallory", "free", tok_b[i], keys[1 + i])
                 for i in range(4)])

    # -- live engine (virtual clock, serialized fetch pipeline) ----------
    fair_e = FairScheduler(max_inflight=1)
    eng = LiveEngine(tiny_params, tiny_cfg, live, policy="kvfetcher",
                     max_running=16, fetch_mode="sync", bandwidth=trace,
                     decode_table=FAIR_TABLE, use_table_sizes=True,
                     adaptive=False, resolution="240p",
                     resolutions=("240p",),
                     cost=EngineCostModel(tiny_cfg, CHIPS["h20"], 2),
                     fairness=fair_e)
    eng.ctrl.push_event(t_fail, lambda t: live.fail_node(doomed, t))
    for user, tier, toks, _key in script:
        eng.submit(np.concatenate([toks, suffix]),
                   reuse_prefix="by-tokens", reuse_tokens=48,
                   max_new_tokens=2, user=user, slo_tier=tier)
    eng.run()

    # -- analytic simulator (synthetic twins, same virtual network) ------
    sim_cluster = build_cluster(False)
    for key in keys:
        src = live.catalog[key]
        sim_cluster.register(StoredPrefix(
            key=key, n_tokens=src.n_tokens,
            bytes_by_resolution={"240p": src.stored_bytes},
            raw_kv_bytes=src.raw_kv_bytes, parent=src.parent), 0.0)
    fair_s = FairScheduler(max_inflight=1)
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=True,
                      use_table_sizes=True, pipelined=False,
                      layerwise_admission=False, resolutions=("240p",))
    sim = ServingSimulator(tiny_cfg, spec, bandwidth=trace,
                           storage=sim_cluster, table=FAIR_TABLE,
                           chunk_tokens=16, max_running=16,
                           fairness=fair_s)
    sim.ctrl.push_event(t_fail, lambda t: sim_cluster.fail_node(doomed, t))
    reqs = [Request(rid=i, arrival=0.0, prompt_len=56, reuse_tokens=48,
                    prefix=key, max_new_tokens=2, user=user,
                    slo_tier=tier)
            for i, (user, tier, _toks, key) in enumerate(script)]
    res = sim.run(reqs, max_new_tokens=2)

    assert fair_e.events == fair_s.events
    assert res.fairness_events == fair_s.events
    kinds = {k for _, _, k, _ in fair_e.events}
    assert "miss" in kinds, "the failure starved no fetch; vacuous"
    assert {"arrive", "dispatch", "fetched", "serve"} <= kinds
    # every request was served exactly once in both environments
    serves = [rid for _, rid, k, _ in fair_e.events if k == "serve"]
    assert sorted(serves) == list(range(len(script)))
    # the doomed prefixes really resolved as misses post-failure
    # (sorted drain: repro-lint ordered-iteration bans set iteration
    # in functions that touch the replay machinery)
    missed = sorted({rid for _, rid, k, _ in fair_e.events
                     if k == "miss"})
    assert missed and all(script[rid][0] == "mallory" for rid in missed)
