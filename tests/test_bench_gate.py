"""The CI perf-regression gate (ISSUE 4 satellites): benchmark-module
selection must be exact (``--only ttft`` can never also match a future
``bench_ttft_decode``), and tools/check_bench.py must go red exactly
when a gated derived ratio regresses >tolerance against
benchmarks/baselines.json."""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

CSV_OK = """name,us_per_call,derived
ttft.kvfetcher.bw2.ctx50k,123.0,0.000123
ttft.live.speedup_async_vs_sync,0.0,1.60
ttft.storage.speedup_cost_vs_lru,0.0,1.17
# bench_ttft done in 1.0s
"""


def _baselines(tmp_path, rows, tolerance=0.25):
    p = tmp_path / "baselines.json"
    p.write_text(json.dumps({"tolerance": tolerance, "rows": rows}))
    return p


def _check_bench():
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import check_bench
    finally:
        sys.path.pop(0)
    return check_bench


# ---------------------------------------------------------------------------
# benchmarks.run --only: exact-name selection
# ---------------------------------------------------------------------------

def test_only_matches_exact_module_name_not_substring():
    from benchmarks.run import MODULES, selected
    assert selected("ttft") == ["bench_ttft"]
    assert selected("bench_ttft") == ["bench_ttft"]
    # substring semantics would also catch a hypothetical
    # bench_ttft_decode; exact semantics must not
    assert "bench_ttft_decode" not in MODULES  # precondition
    assert selected("ttf") == []  # no prefix/substring matching
    assert selected("kernels") == ["bench_kernels"]
    assert selected(None) == MODULES


def test_only_unknown_name_exits_nonzero(capsys, monkeypatch):
    import pytest

    from benchmarks import run as bench_run
    monkeypatch.setattr(sys, "argv", ["run", "--only", "ttft_decode"])
    with pytest.raises(SystemExit) as e:
        bench_run.main()
    assert "matches no module" in str(e.value)


def test_list_prints_module_names(capsys, monkeypatch):
    from benchmarks import run as bench_run
    monkeypatch.setattr(sys, "argv", ["run", "--list"])
    bench_run.main()
    out = capsys.readouterr().out.splitlines()
    assert out == bench_run.MODULES


# ---------------------------------------------------------------------------
# tools/check_bench.py: the regression gate itself
# ---------------------------------------------------------------------------

def test_gate_passes_within_tolerance(tmp_path):
    cb = _check_bench()
    csv = tmp_path / "t.csv"
    csv.write_text(CSV_OK)
    base = _baselines(tmp_path, {
        "ttft.live.speedup_async_vs_sync": 1.70,   # -6%: inside 25%
        "ttft.storage.speedup_cost_vs_lru": 1.17,
    })
    assert cb.main([str(csv), "--baselines", str(base)]) == 0


def test_gate_fails_on_over_25pct_regression(tmp_path, capsys):
    cb = _check_bench()
    csv = tmp_path / "t.csv"
    csv.write_text(CSV_OK)
    base = _baselines(tmp_path, {
        "ttft.live.speedup_async_vs_sync": 2.20,   # 1.60 < 2.20*0.75
        "ttft.storage.speedup_cost_vs_lru": 1.17,
    })
    assert cb.main([str(csv), "--baselines", str(base)]) == 1
    assert "REGRESSED" in capsys.readouterr().out


def test_gate_fails_when_baseline_row_vanishes_from_csv(tmp_path):
    cb = _check_bench()
    csv = tmp_path / "t.csv"
    csv.write_text(CSV_OK)
    base = _baselines(tmp_path, {"ttft.gone.speedup_x_vs_y": 2.0,
                                 "ttft.live.speedup_async_vs_sync": 1.6,
                                 "ttft.storage.speedup_cost_vs_lru": 1.1})
    assert cb.main([str(csv), "--baselines", str(base)]) == 1


def test_gate_fails_on_new_gated_row_without_baseline(tmp_path, capsys):
    cb = _check_bench()
    csv = tmp_path / "t.csv"
    csv.write_text(CSV_OK + "ttft.newthing.speedup_a_vs_b,0.0,3.0\n")
    base = _baselines(tmp_path, {
        "ttft.live.speedup_async_vs_sync": 1.60,
        "ttft.storage.speedup_cost_vs_lru": 1.17,
    })
    assert cb.main([str(csv), "--baselines", str(base)]) == 1
    assert "--update" in capsys.readouterr().err


def test_gate_fails_on_failed_module_row(tmp_path):
    cb = _check_bench()
    csv = tmp_path / "t.csv"
    csv.write_text(CSV_OK + "bench_ttft.FAILED,0,0  # RuntimeError()\n")
    base = _baselines(tmp_path, {
        "ttft.live.speedup_async_vs_sync": 1.60,
        "ttft.storage.speedup_cost_vs_lru": 1.17,
    })
    assert cb.main([str(csv), "--baselines", str(base)]) == 1


def test_update_writes_gated_rows_only(tmp_path):
    cb = _check_bench()
    csv = tmp_path / "t.csv"
    csv.write_text(CSV_OK)
    base = tmp_path / "fresh.json"
    assert cb.main([str(csv), "--baselines", str(base),
                    "--update"]) == 0
    data = json.loads(base.read_text())
    assert set(data["rows"]) == {"ttft.live.speedup_async_vs_sync",
                                 "ttft.storage.speedup_cost_vs_lru"}
    assert data["tolerance"] == 0.25
    # raw-seconds rows are machine-dependent and must not be gated
    assert "ttft.kvfetcher.bw2.ctx50k" not in data["rows"]
    # and the freshly-written baselines gate the same CSV green
    assert cb.main([str(csv), "--baselines", str(base)]) == 0


def test_committed_baselines_cover_current_bench_rows():
    """The committed baselines file parses and its tolerance is the
    documented 25%; row membership is checked end-to-end by the
    bench-gate CI job (running the bench here would be minutes)."""
    data = json.loads((ROOT / "benchmarks" /
                       "baselines.json").read_text())
    assert data["tolerance"] == 0.25
    cb = _check_bench()
    assert all(any(m in k for m in cb.GATE_MARKERS)
               for k in data["rows"])
    assert any("failover" in k for k in data["rows"]), \
        "failover ratios must be gated"


# ---------------------------------------------------------------------------
# ISSUE 7 regression: missing/malformed gated rows must fail loudly
# ---------------------------------------------------------------------------

def test_malformed_gated_row_no_longer_silently_ungates(tmp_path, capsys):
    """Failing-before regression: a truncated data row (comma present,
    derived column missing) used to be skipped by parse_csv, so a gated
    ``ttft.abr.*`` ratio could vanish from the gate and the job stayed
    green (exit 0).  It must fail and name the row."""
    cb = _check_bench()
    csv = tmp_path / "t.csv"
    csv.write_text(CSV_OK + "ttft.abr.speedup_adaptive_vs_best_fixed,3.0\n")
    base = _baselines(tmp_path, {
        "ttft.live.speedup_async_vs_sync": 1.60,
        "ttft.storage.speedup_cost_vs_lru": 1.17,
    })
    assert cb.main([str(csv), "--baselines", str(base)]) == 1
    err = capsys.readouterr().err
    assert "ttft.abr.speedup_adaptive_vs_best_fixed" in err
    assert "malformed" in err
    # the old silent path really was silent: parse_csv alone shows it
    rows, failed = cb.parse_csv(csv)
    assert "ttft.abr.speedup_adaptive_vs_best_fixed" not in rows
    assert any("malformed" in f for f in failed)
    # prose lines without a comma are still not data rows
    (tmp_path / "p.csv").write_text("bench done\n" + CSV_OK)
    rows2, failed2 = cb.parse_csv(tmp_path / "p.csv")
    assert not failed2 and rows2 == rows


def test_missing_baseline_message_names_rows_and_update_command(
        tmp_path, capsys):
    """New gated rows without baselines fail with ONE aggregated,
    actionable message: every missing ``ttft.abr.*`` row by name plus
    the exact --update command — distinct from a [REGRESSED] verdict."""
    cb = _check_bench()
    csv = tmp_path / "t.csv"
    csv.write_text(
        CSV_OK
        + "ttft.abr.speedup_adaptive_vs_best_fixed,0.0,1.08\n"
        + "ttft.abr.speedup_adaptive_vs_worst_fixed,0.0,1.90\n")
    base = _baselines(tmp_path, {
        "ttft.live.speedup_async_vs_sync": 1.60,
        "ttft.storage.speedup_cost_vs_lru": 1.17,
    })
    assert cb.main([str(csv), "--baselines", str(base)]) == 1
    out, err = capsys.readouterr()
    assert "REGRESSED" not in out and "REGRESSED" not in err
    assert "2 gated row(s) have no baseline" in err
    assert "ttft.abr.speedup_adaptive_vs_best_fixed" in err
    assert "ttft.abr.speedup_adaptive_vs_worst_fixed" in err
    assert f"python tools/check_bench.py {csv} --update" in err
    # refusing to --update over a malformed CSV still holds
    csv.write_text(CSV_OK + "ttft.abr.speedup_adaptive_vs_best_fixed,1\n")
    assert cb.main([str(csv), "--baselines", str(base),
                    "--update"]) == 1
