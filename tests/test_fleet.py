"""Fleet-scale serving (ISSUE 9 surface).

Layers:

  * unit tests of the `FleetRouter` policies — affinity stickiness,
    ancestor-chain collapse, the load-pressure spill escape hatch,
    least-loaded balance, seeded random, and the deterministic
    ``("place", rid, node, reason)`` event log;
  * unit tests of the node-local KV model (`_LocalKV` token-LRU) and of
    the per-node prefetch mispredict-budget split
    (``PrefetchManager(n_nodes=)`` + ``note_node``);
  * an analytic `FleetSimulator` run showing prefix-affinity routing
    beating random placement on mean TTFT at 8 nodes under a Zipf
    prefix-trie workload (the bench acceptance gate, in miniature);
  * a mesh-sharded live engine run: per-shard fetch plans through the
    one controller, restored pages bit-identical to the unsharded
    engine, page arrays carrying a `NamedSharding`;
  * cross-environment replay (slow): `FleetSimulator` and the
    virtual-clock `LiveFleet` produce byte-identical router placement,
    fairness, and storage-cluster event logs over an 8-node Zipf-skewed
    script with a storage-node failure mid-trace (churn scripted by
    dispatch index, the env-invariant clock).
"""
import dataclasses

import numpy as np
import pytest

from repro.cluster.fairness import FairScheduler
from repro.cluster.fleet import (FLEET_POLICIES, FleetRouter,
                                 FleetSimulator, _LocalKV)
from repro.cluster.network import BandwidthTrace
from repro.cluster.simulator import MethodSpec, kvfetcher_spec
from repro.cluster.staging import HostStagingTier, PrefetchManager
from repro.cluster.storage import (StorageCluster, StorageNode,
                                   StoredPrefix, synthetic_stored_prefix)
from repro.core.scheduler import Request
from repro.data.workload import prefix_trie_specs, zipf_prefix_trace

MB = 1_000_000


def _req(rid, prefix=None, reuse=1_000, user=None, tier=None):
    return Request(rid=rid, arrival=0.0, prompt_len=reuse + 100,
                   reuse_tokens=reuse, prefix=prefix,
                   max_new_tokens=4, user=user, slo_tier=tier)


# ---------------------------------------------------------------------------
# router policies
# ---------------------------------------------------------------------------

def test_router_rejects_unknown_policy():
    with pytest.raises(AssertionError):
        FleetRouter(4, policy="round_robin")
    assert set(FLEET_POLICIES) == {"affinity", "least_loaded", "random"}


def test_affinity_is_sticky_and_logged():
    r = FleetRouter(8, policy="affinity")
    first = r.place(_req(0, prefix="p.hot"))
    for rid in range(1, 5):
        assert r.place(_req(rid, prefix="p.hot")) == first
    kinds = [reason for _, _, _, reason in r.events]
    assert kinds[0] == "hash" and all(k == "sticky" for k in kinds[1:])
    assert r.events[0] == ("place", 0, f"s{first}", "hash")


def test_affinity_replays_identically():
    def run():
        r = FleetRouter(8, policy="affinity")
        for rid, key in enumerate(["a", "b", "a", "c", "a", None, "b"]):
            r.place(_req(rid, prefix=key,
                         reuse=1_000 if key else 0))
        return r.events

    assert run() == run()


def test_affinity_collapses_ancestor_chains():
    """Every extension of a session chain routes to the chain root's
    node: the child's KV extends the parent's, so locality follows the
    trie, not the leaf key."""
    parents = {"root": None, "root.c": "root", "root.c.g": "root.c"}
    r = FleetRouter(8, policy="affinity", parent_of=parents.get)
    k_root = r.place(_req(0, prefix="root"))
    assert r.place(_req(1, prefix="root.c")) == k_root
    assert r.place(_req(2, prefix="root.c.g")) == k_root
    assert len(r.sticky) == 1  # one sticky entry for the whole chain


def test_affinity_no_prefix_falls_back_to_least_loaded():
    r = FleetRouter(4, policy="affinity")
    r.place(_req(0, prefix="p", reuse=1_000))
    k = r.place(_req(1, prefix=None, reuse=0))
    assert r.events[-1][3] == "least_loaded"
    assert r.assigned[k] == 1


def test_affinity_spills_under_load_pressure():
    """A single hot chain cannot pin the whole fleet's load on one
    node: once the sticky target runs past spill_factor x fair share
    (+ slack), the chain spills to the least-loaded node and re-sticks
    there."""
    r = FleetRouter(4, policy="affinity", spill_factor=1.0, spill_slack=2)
    k0 = r.place(_req(0, prefix="p.hot"))
    reasons = []
    for rid in range(1, 12):
        r.place(_req(rid, prefix="p.hot"))
        reasons.append(r.events[-1][3])
    assert "spill" in reasons
    first_spill = reasons.index("spill") + 1
    k1 = int(r.events[first_spill][2][1:])
    assert k1 != k0
    assert r.sticky["p.hot"] == int(r.events[-1][2][1:])
    # load never concentrates: max node share stays near the cap
    assert max(r.assigned) <= 1.0 * (sum(r.assigned) / 4) + 2 + 1


def test_least_loaded_balances_exactly():
    r = FleetRouter(4, policy="least_loaded")
    for rid in range(8):
        r.place(_req(rid, prefix="p.hot"))
    assert r.assigned == [2, 2, 2, 2]
    assert all(reason == "least_loaded" for *_, reason in r.events)


def test_random_is_seeded_by_rid_not_order():
    a = FleetRouter(8, policy="random")
    b = FleetRouter(8, policy="random")
    pa = [a.place(_req(rid)) for rid in range(16)]
    pb = [b.place(_req(rid)) for rid in reversed(range(16))]
    assert pa == list(reversed(pb))  # pure function of rid
    assert len(set(pa)) > 1  # actually spreads


# ---------------------------------------------------------------------------
# node-local KV model
# ---------------------------------------------------------------------------

def test_local_kv_lru_evicts_by_token_capacity():
    kv = _LocalKV(100)
    kv.put("a", 40)
    kv.put("b", 40)
    assert kv.hit("a", 40) and kv.hit("b", 40)
    assert not kv.hit("a", 41)  # insufficient coverage is a miss
    kv.hit("a", 40)  # touch: b becomes LRU
    kv.put("c", 40)  # over capacity -> evicts b
    assert kv.hit("a", 40) and kv.hit("c", 40) and not kv.hit("b", 1)
    assert kv.resident_tokens == 80
    kv.put("huge", 1_000)  # larger than capacity: never admitted
    assert not kv.hit("huge", 1)


# ---------------------------------------------------------------------------
# per-node prefetch budget split
# ---------------------------------------------------------------------------

def test_prefetch_budget_splits_per_node():
    """With n_nodes=4 each serving node may burn budget/4: one node's
    cold working set cannot exhaust speculation for the whole fleet."""
    entries = [StoredPrefix(key=k, n_tokens=1_000,
                            bytes_by_resolution={"240p": 10 * MB},
                            raw_kv_bytes=80 * MB)
               for k in ("p.a", "p.b")]
    cluster = StorageCluster([StorageNode("n0")])
    for e in entries:
        cluster.register(e, 0.0)
    pm = PrefetchManager(cluster, HostStagingTier(None),
                         mispredict_budget_bytes=40 * MB,
                         transport="sync", n_nodes=4)
    pm.note_node("p.a", "s0")
    pm.note_node("p.b", "s1")
    # s0 burns past its 10 MB share: p.a declined, s1's p.b untouched
    pm._account_waste("p.a", 12 * MB)
    assert pm.wasted_by_node == {"s0": 12 * MB}
    assert pm._over_budget("p.a") and not pm._over_budget("p.b")
    assert pm.request_prefetch("p.a", 0.0) is False
    assert pm.events[-1] == ("budget_reject", "p.a")
    # single-node fleets keep the flat global budget semantics
    pm_flat = PrefetchManager(cluster, HostStagingTier(None),
                              mispredict_budget_bytes=40 * MB,
                              transport="sync")
    pm_flat.note_node("p.a", "s0")
    pm_flat._account_waste("p.a", 12 * MB)
    assert not pm_flat._over_budget("p.a")


# ---------------------------------------------------------------------------
# analytic fleet: affinity beats random under Zipf (bench gate, small)
# ---------------------------------------------------------------------------

def _fleet_run(cfg, policy, specs, ratios):
    nodes = [StorageNode(f"n{i}", link=BandwidthTrace.constant(4.0))
             for i in range(3)]
    cluster = StorageCluster(nodes, replication=2)
    for sp in specs:
        cluster.register(synthetic_stored_prefix(
            sp.key, sp.n_tokens,
            raw_bytes_per_token=cfg.kv_bytes_per_token(),
            ratios=ratios, parent=sp.parent), 0.0)
    rng = np.random.default_rng(42)
    reqs = zipf_prefix_trace(rng, specs, n_requests=24, alpha=1.1,
                             gap=5.0, max_new_tokens=4)
    fleet = FleetSimulator(cfg, kvfetcher_spec(ratios), n_nodes=8,
                           bandwidth=BandwidthTrace.constant(8.0),
                           storage=cluster, policy=policy,
                           local_kv_tokens=150_000)
    return fleet.run(reqs, max_new_tokens=4)


def test_fleet_affinity_beats_random_on_mean_ttft():
    from repro.configs import get_config

    cfg = get_config("yi-34b")
    ratios = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}
    specs = prefix_trie_specs(4, 2)
    out = {}
    for policy in ("affinity", "random"):
        res = _fleet_run(cfg, policy, specs, ratios)
        tt = [r.ttft for r in res.requests]
        assert all(t is not None for t in tt)
        out[policy] = (float(np.mean(tt)), res)
    t_aff, res_aff = out["affinity"]
    t_rand, res_rand = out["random"]
    assert t_aff < t_rand, (t_aff, t_rand)
    assert res_aff.local_hits > res_rand.local_hits
    # the placement log covers every request, in arrival order
    assert [rid for _, rid, _, _ in res_aff.router_events] == \
        [r.rid for r in res_aff.requests]
    assert all(ev[0] == "place" and ev[2].startswith("s")
               for ev in res_aff.router_events)
    # every placed request was dispatched on its placed node
    assert set(res_aff.placements) == {r.rid for r in res_aff.requests}


# ---------------------------------------------------------------------------
# mesh-sharded live engine
# ---------------------------------------------------------------------------

def test_mesh_sharded_engine_matches_unsharded(tiny_cfg, tiny_params,
                                               donor_kv):
    """Per-shard fetch plans through the ONE controller: the sharded
    engine restores bit-identical pages and emits the same tokens as
    the unsharded engine, and its page arrays carry a NamedSharding
    laid out by the logical-axis rules."""
    from jax.sharding import NamedSharding

    from repro.cluster.costmodel import CHIPS, EngineCostModel
    from repro.launch.mesh import make_debug_mesh
    from repro.serving.engine import LiveEngine

    rng = np.random.default_rng(7)
    toks = rng.integers(0, tiny_cfg.vocab_size, 48)
    suffix = rng.integers(0, tiny_cfg.vocab_size, 8)
    kv_k, kv_v = donor_kv(toks)
    trace = BandwidthTrace.constant(0.01)

    def build():
        cluster = StorageCluster([StorageNode("n0")])
        cluster.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                                resolutions=("240p",))
        return cluster, list(cluster.catalog)[0]

    def run(mesh, mesh_shards):
        cluster, key = build()
        eng = LiveEngine(tiny_params, tiny_cfg, cluster,
                         policy="kvfetcher", fetch_mode="sync",
                         bandwidth=trace, adaptive=False,
                         resolution="240p", resolutions=("240p",),
                         cost=EngineCostModel(tiny_cfg, CHIPS["h20"], 2),
                         mesh=mesh, mesh_shards=mesh_shards)
        req = eng.submit(np.concatenate([toks, suffix]),
                         reuse_prefix=key, reuse_tokens=48,
                         max_new_tokens=4)
        eng.run()
        return eng, req

    base_eng, base_req = run(None, None)
    mesh = make_debug_mesh(shape=(1, 1))
    shard_eng, shard_req = run(mesh, 3)
    assert shard_eng.n_shards == 3
    assert shard_req.fetch_done is not None and shard_req.storage_hit == \
        base_req.storage_hit == "full"
    assert shard_eng.outputs[shard_req.rid] == base_eng.outputs[
        base_req.rid]
    assert not shard_eng._sharded  # all shards completed and untracked
    assert isinstance(shard_eng.cache.k_pages.sharding, NamedSharding)
    assert isinstance(shard_eng.cache.v_pages.sharding, NamedSharding)


# ---------------------------------------------------------------------------
# cross-environment replay determinism (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_replay_identical_in_simulator_and_live_fleet(
        tiny_cfg, tiny_params, donor_kv):
    """ISSUE 9 acceptance: an 8-node fleet over a Zipf-skewed script
    with one storage node failing mid-trace replays byte-identical
    router placement, fairness, and storage-cluster lookup logs in the
    analytic `FleetSimulator` and the virtual-clock `LiveFleet`.
    Placement, local-KV residency, fair dispatch, and churn (scripted
    by dispatch index) are all pure functions of the request sequence,
    so the logs must match tuple for tuple.

    Script discipline (same as the ISSUE 8 cross-env test): a key that
    misses is never asked again — delayed write-on-miss re-admission
    fires at the fallback prefill's first token, a *clock*-dependent
    instant, so a later re-ask would race the re-admission differently
    in each environment.  The hot key's storage node dies right after
    its first fetch instead: every later ask serves from the serving
    node's LOCAL copy (no storage lookup at all), which is exactly the
    affinity-survives-churn win the router is for."""
    from repro.cluster.costmodel import CHIPS, EngineCostModel
    from repro.cluster.fleet import LiveFleet
    from repro.core.adaptive import DecodeTable

    TABLE = DecodeTable(name="fleet-toy", n_decoders=1,
                        latency={"240p": (0.06,)}, penalty={"240p": 0.0},
                        chunk_size_mb={"240p": 0.002})
    trace = BandwidthTrace.constant(0.0006)  # 75 kB/s
    N_NODES = 8
    LOCAL_TOKENS = 128
    # admission events ride on recompute_done (a clock), so only the
    # dispatch-ordered kinds are replay-comparable
    LOOKUP_KINDS = ("full", "partial", "miss", "fail", "recover",
                    "replicate")

    rng = np.random.default_rng(12)
    tok = {"a": rng.integers(0, tiny_cfg.vocab_size, 48),
           "b": rng.integers(0, tiny_cfg.vocab_size, 48),
           "c": rng.integers(0, tiny_cfg.vocab_size, 64)}
    suffix = rng.integers(0, tiny_cfg.vocab_size, 8)
    # drawn after suffix: lands on the same storage node as "a" for
    # this seed (asserted below — the churn must be visible)
    tok["d"] = rng.integers(0, tiny_cfg.vocab_size, 48)

    def build_cluster(live):
        nodes = [StorageNode("n0"), StorageNode("n1")]
        c = StorageCluster(nodes, replication=1, heal="manual")
        if live:
            for toks in tok.values():
                kv_k, kv_v = donor_kv(toks)
                c.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                                  resolutions=("240p",))
        return c

    live_cluster = build_cluster(True)
    keys = list(live_cluster.catalog)  # [a, b, c, d] registration order
    by_name = dict(zip(tok, keys))
    # the HOT key's storage node dies after the very first dispatch:
    # every later "a" ask must serve from the serving node's local copy
    doomed = live_cluster.primary_node(by_name["a"]).node_id
    assert live_cluster.primary_node(by_name["d"]).node_id == doomed, \
        "d must share a's node or the churn is invisible; re-pick seed"
    assert all(live_cluster.primary_node(by_name[n]).node_id != doomed
               for n in ("b", "c")), "b/c must survive; re-pick seed"
    churn = [(1, "fail", doomed)]

    # (user, tier, name) in submit order — Zipf-skewed toward "a";
    # "d" is asked exactly once (it misses) and never again
    script = [("alice", "premium", "a"), ("bob", "standard", "b"),
              ("alice", "premium", "a"), ("mallory", "free", "c"),
              ("bob", "standard", "a"), ("alice", "premium", "b"),
              ("mallory", "free", "a"), ("bob", "standard", "c"),
              ("alice", "premium", "a"), ("mallory", "free", "d")]

    # -- live fleet (virtual clock, real engines) ------------------------
    fair_e = FairScheduler(max_inflight=1)
    fleet_e = LiveFleet(
        tiny_params, tiny_cfg, live_cluster, n_nodes=N_NODES,
        bandwidth=trace, policy="affinity", fairness=fair_e,
        local_kv_tokens=LOCAL_TOKENS, churn_at_dispatch=churn,
        engine_kw=dict(policy="kvfetcher", max_running=16,
                       decode_table=TABLE, use_table_sizes=True,
                       adaptive=False, resolution="240p",
                       resolutions=("240p",),
                       cost=EngineCostModel(tiny_cfg, CHIPS["h20"], 2)))
    for user, tier, name in script:
        fleet_e.submit(np.concatenate([tok[name], suffix]),
                       prefix_key=by_name[name],
                       reuse_tokens=len(tok[name]), max_new_tokens=2,
                       user=user, slo_tier=tier)
    fleet_e.run()

    # -- analytic simulator (synthetic twins, same virtual network) ------
    sim_cluster = build_cluster(False)
    for key in keys:
        src = live_cluster.catalog[key]
        sim_cluster.register(StoredPrefix(
            key=key, n_tokens=src.n_tokens,
            bytes_by_resolution={"240p": src.stored_bytes},
            raw_kv_bytes=src.raw_kv_bytes, parent=src.parent), 0.0)
    fair_s = FairScheduler(max_inflight=1)
    spec = MethodSpec("kvfetcher", ratios={"stream": 8.0}, adaptive=False,
                      fixed_resolution="240p", uses_decode_pool=True,
                      use_table_sizes=True, pipelined=False,
                      layerwise_admission=False, resolutions=("240p",))
    fleet_s = FleetSimulator(
        tiny_cfg, spec, n_nodes=N_NODES, bandwidth=trace,
        storage=sim_cluster, table=TABLE, fairness=fair_s,
        policy="affinity", local_kv_tokens=LOCAL_TOKENS,
        churn_at_dispatch=churn, chunk_tokens=16, max_running=16)
    reqs = [Request(rid=i, arrival=0.0,
                    prompt_len=len(tok[name]) + len(suffix),
                    reuse_tokens=len(tok[name]), prefix=by_name[name],
                    max_new_tokens=2, user=user, slo_tier=tier)
            for i, (user, tier, name) in enumerate(script)]
    res = fleet_s.run(reqs, max_new_tokens=2)

    # router placement replayed identically
    assert fleet_e.router.events == fleet_s.router.events
    assert res.router_events == fleet_s.router.events
    assert fleet_e.placement == fleet_s.placement
    # fairness decision log byte-identical
    assert fair_e.events == fair_s.events
    assert res.fairness_events == fair_s.events
    # storage tier saw the same dispatch-ordered churn/lookup sequence
    def lookups(cluster):
        return [e for e in cluster.events if e[0] in LOOKUP_KINDS]

    assert lookups(live_cluster) == lookups(sim_cluster)
    assert ("fail", "", doomed) in lookups(live_cluster)
    # every request served exactly once in both environments
    serves = [rid for _, rid, k, _ in fair_e.events if k == "serve"]
    assert sorted(serves) == list(range(len(script)))
    # the affinity win actually materialized: post-churn asks of the
    # hot key served from the serving node's local copy even though
    # its only storage replica is DEAD (identical count in both envs)
    live_locals = [r for e in fleet_e.engines for r in e.finished
                   if r.storage_hit == "local"]
    assert len(live_locals) == res.local_hits > 0
    assert any(r.prefix == by_name["a"] for r in live_locals)
    # ...and the storage failure really bit: the doomed-node key missed
    kinds = {k for _, _, k, _ in fair_e.events}
    assert "miss" in kinds
    assert {"arrive", "dispatch", "fetched", "serve"} <= kinds
    missed = {rid for _, rid, k, _ in fair_e.events if k == "miss"}
    assert missed == {9}  # the single "d" ask, and only it
    # real tokens came out of every live request
    for eng in fleet_e.engines:
        for r in eng.finished:
            assert len(fleet_e.engines[fleet_e.placement[r.rid]]
                       .outputs[r.rid]) == r.tokens_out
