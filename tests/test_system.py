"""System-level behaviour tests: public API surface, config registry
completeness, end-to-end codec->storage->plan flow, and the per-arch
shape-support matrix that the dry-run relies on."""
import numpy as np
import pytest

from repro.configs import (
    ASSIGNED_ARCHS, INPUT_SHAPES, PAPER_ARCHS, get_config, list_configs,
    reduce_config,
)


def test_all_assigned_archs_registered_with_citations():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS + PAPER_ARCHS:
        cfg = get_config(a)
        assert cfg.source, a
        assert cfg.param_count() > 0


def test_shape_support_matrix():
    """The 40-pair matrix: every pair is either supported or has a
    documented reason (encoder decode / non-sub-quadratic 500k)."""
    n_ok = n_skip = 0
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        for s in INPUT_SHAPES.values():
            ok, why = cfg.shape_supported(s)
            if ok:
                n_ok += 1
            else:
                assert why
                n_skip += 1
    assert n_ok + n_skip == 40
    assert n_skip == 7  # 2 (encoder) + 5 (full-attn long_500k)


def test_smoke_reduction_constraints():
    for a in ASSIGNED_ARCHS:
        r = reduce_config(get_config(a))
        assert r.num_layers <= 3
        assert r.d_model <= 512
        assert r.num_experts <= 4


def test_public_api_imports():
    from repro.core import (  # noqa: F401
        KVCodec, KVManifest, FetchingAwareScheduler, Request,
        encode_prefix, select_resolution, non_blocking_ok, build_plan,
        FetchController, FetchHooks, PipelineConfig, synthetic_plan,
    )
    from repro.models import transformer  # noqa: F401
    from repro.serving.engine import LiveEngine  # noqa: F401
    from repro.cluster.simulator import ServingSimulator  # noqa: F401
    from repro.paged.cache import PagedKVCache  # noqa: F401
    from repro.kernels.kv_restore.ops import kv_restore  # noqa: F401
    from repro.launch.mesh import make_production_mesh  # noqa: F401


def test_codec_storage_plan_flow(synthetic_kv):
    """Offline registration -> manifest -> fetch plan -> chunk decode."""
    from repro.cluster.storage import KVStore
    from repro.core.chunks import decode_chunk_tokens, prefix_key
    from repro.core.fetch import build_plan
    T, L, H, D = 48, 4, 4, 16
    kv_k, kv_v, toks = synthetic_kv(T, L, H, D)
    store = KVStore()
    man = store.register_prefix(toks, kv_k, kv_v, tokens_per_chunk=16,
                                resolutions=("240p",))
    assert store.lookup(prefix_key(toks)) is man
    assert store.stored_bytes() > 0
    plan = build_plan(0, man)
    assert plan.n_layers_total == L
    # every chunk decodes within the quantization error bound
    for pc in plan.chunks[:4]:
        deq = decode_chunk_tokens(man, pc.ref.chunk_id, "240p", H, D)
        kv = kv_k if pc.ref.kind == "k" else kv_v
        orig = kv[pc.ref.token_start:pc.ref.token_end][:, list(
            pc.ref.layers)]
        sc = man.scales[pc.ref.kind][list(pc.ref.layers)]
        assert (np.abs(deq - orig) <= sc[None, :, :, None] * 0.5
                + 1e-6).all()


def test_dryrun_results_complete():
    """If the dry-run sweep has been run, its artifact set must be the
    full 80-combination matrix with no errors."""
    import glob
    import json
    import os
    d = os.path.join(os.path.dirname(__file__), "..", "dryrun_results")
    files = glob.glob(os.path.join(d, "*.json"))
    if len(files) < 80:
        pytest.skip("dry-run sweep not executed in this environment")
    status = {}
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        status[(r["arch"], r["shape"], r["mesh"])] = r["status"]
    assert len(status) == 80
    assert all(s in ("ok", "skipped") for s in status.values())
    assert sum(s == "ok" for s in status.values()) == 66
