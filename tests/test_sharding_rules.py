"""Sharding rule engine tests (pure logic; uses a fake mesh shape via
jax's single CPU device + synthetic Mesh objects is not possible, so we
test the resolver against a mesh built from 1 device where applicable and
the pspec logic with monkeypatched state)."""
import numpy as np
import pytest

from repro.sharding import rules
from repro.sharding.axes import param_axes, cache_axes, batch_axes
from repro.configs import get_config, reduce_config


class FakeMesh:
    """Duck-typed mesh: rules only uses .shape (a dict)."""
    def __init__(self, shape):
        self.shape = shape


def _resolve(axes, dims, mesh_shape, overlay=None):
    mesh = FakeMesh(mesh_shape)
    prev = (rules._STATE.mesh, rules._STATE.rules)
    merged = dict(rules.DEFAULT_RULES)
    if overlay:
        merged.update(overlay)
    rules._STATE.mesh, rules._STATE.rules = mesh, merged
    try:
        return tuple(rules.logical_to_pspec(axes, dims, mesh))
    finally:
        rules._STATE.mesh, rules._STATE.rules = prev


def test_divisibility_fallback():
    # kv_heads=8 cannot shard over model=16 -> replicated
    spec = _resolve(("batch", "cache_seq", "kv_heads", None),
                    (128, 32768, 8, 128), {"data": 16, "model": 16})
    assert spec == ("data", None, None, None)


def test_round_based_priority_gives_model_to_kv_first():
    overlay = {"cache_seq": [None, "model"]}
    # kv divisible: kv_heads wins the model axis in round 0
    spec = _resolve(("batch", "cache_seq", "kv_heads", None),
                    (128, 32768, 16, 128), {"data": 16, "model": 16},
                    overlay)
    assert spec == ("data", None, "model", None)
    # kv NOT divisible: cache_seq picks model up in round 1
    spec = _resolve(("batch", "cache_seq", "kv_heads", None),
                    (128, 32768, 8, 128), {"data": 16, "model": 16},
                    overlay)
    assert spec == ("data", "model", None, None)


def test_multipod_fsdp_tuple_axis():
    spec = _resolve(("vocab", "embed"), (256000, 18432),
                    {"pod": 2, "data": 16, "model": 16})
    assert spec == ("model", ("pod", "data"))


def test_axis_taken_once():
    # two dims wanting "model": only the first (per round order) gets it
    spec = _resolve(("heads", "mlp"), (64, 49152),
                    {"data": 16, "model": 16})
    assert spec.count("model") == 1


def test_small_dims_never_crash():
    spec = _resolve(("batch", "seq", "embed_act"), (2, 8, 64),
                    {"data": 16, "model": 16})
    assert spec == (None, None, None)  # 2 % 16 != 0 -> replicated


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x22b", "mamba2-2.7b",
                                  "recurrentgemma-9b", "deepseek-moe-16b",
                                  "hubert-xlarge", "qwen1.5-110b"])
def test_param_axes_cover_every_leaf(arch):
    """Every parameter leaf must get a logical-axes tuple of its rank."""
    import jax
    from repro.models import transformer as tf
    cfg = reduce_config(get_config(arch))
    shapes = jax.eval_shape(
        lambda k: tf.init_params(cfg, k), jax.random.PRNGKey(0))
    axes = param_axes(shapes)
    pairs = zip(jax.tree.leaves(axes,
                                is_leaf=lambda x: isinstance(x, tuple)
                                and all(isinstance(e, (str, type(None)))
                                        for e in x)),
                jax.tree.leaves(shapes))
    n = 0
    for a, s in pairs:
        assert len(a) == len(s.shape), (a, s.shape)
        n += 1
    assert n > 0


@pytest.mark.parametrize("arch", ["yi-9b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_cache_axes_cover_every_leaf(arch):
    import jax
    from repro.models import transformer as tf
    cfg = reduce_config(get_config(arch))
    shapes = jax.eval_shape(lambda: tf.init_cache(cfg, 2, 64))
    axes = cache_axes(shapes)
    for a, s in zip(jax.tree.leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)),
            jax.tree.leaves(shapes)):
        assert len(a) == len(s.shape), (a, s.shape)
