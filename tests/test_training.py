"""Training substrate: optimizer math, loss decreases, grad-accum
equivalence, checkpoint round-trip, per-arch train-step smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.data.pipeline import DataConfig, batches
from repro.training import checkpoint
from repro.training.loop import train
from repro.training.optimizer import AdamW, constant_schedule
from repro.training.steps import init_state, loss_fn, make_train_step


def test_adamw_descends_quadratic():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_loss_decreases_small_lm():
    cfg = reduce_config(get_config("yi-9b"), num_layers=2, d_model=128,
                        vocab=256)
    hist = train(cfg, steps=12, batch_size=4, seq_len=32, lr=2e-3,
                 log_every=0)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    """One jitted train step per assigned architecture (reduced config)."""
    cfg = reduce_config(get_config(arch))
    opt = AdamW(lr=constant_schedule(1e-3))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    batch = next(batches(cfg, DataConfig(batch_size=2, seq_len=32)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.step) == 1
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


def test_grad_accum_matches_full_batch():
    cfg = reduce_config(get_config("yi-9b"), num_layers=2, d_model=64,
                        vocab=128)
    opt = AdamW(lr=constant_schedule(1e-3), grad_clip=0.0)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    batch = next(batches(cfg, DataConfig(batch_size=4, seq_len=16)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    s1, m1 = make_train_step(cfg, opt, accum_steps=1)(state, batch)
    s2, m2 = make_train_step(cfg, opt, accum_steps=2)(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-4)
    # Adam amplifies fp32 summation-order noise to ~2*lr at sign flips of
    # near-zero grads, so params only match within that envelope.
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=0)


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduce_config(get_config("yi-9b"), num_layers=2, d_model=64,
                        vocab=128)
    opt = AdamW(lr=constant_schedule(1e-3))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    p = str(tmp_path / "ckpt")
    checkpoint.save(p, state.params)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state.params)
    back = checkpoint.restore(p, like)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
