"""Paper Fig. 20/22: compression-ratio breakdown (quantization /
+inter-frame layout / +intra-frame layout) on real KV of the paper's three
model families, plus lossless verification."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, real_kv
from repro.core.codec import CodecOptions, KVCodec
from repro.core.quantization import quantize


def run() -> List[Row]:
    rows: List[Row] = []
    for arch in ("lwm-7b", "yi-34b", "llama3-70b"):
        cfg, kv_k, _ = real_kv(arch, T=512)
        q, _ = quantize(kv_k[:, :3])
        fp16_bytes = 2 * q.nbytes
        H, D = cfg.num_kv_heads, cfg.head_dim

        # stage 1: quantization only (ratio 2.0 by construction)
        rows.append((f"compression.{arch}.quant_only", 0.0, 2.0))

        # stage 2: inter-frame layout (token slicing, temporal prediction,
        # identity intra layout)
        t0 = time.perf_counter()
        codec = KVCodec(H, D)  # identity-ish intra layout
        blob = codec.encode_chunk(q, "240p")
        us = (time.perf_counter() - t0) * 1e6
        assert np.array_equal(codec.decode_chunk(blob), q)
        rows.append((f"compression.{arch}.inter_frame", us,
                     fp16_bytes / len(blob)))

        # stage 3: + intra-frame layout search
        t0 = time.perf_counter()
        codec.search_layout(q[:256], "240p")
        blob2 = codec.encode_chunk(q, "240p")
        us2 = (time.perf_counter() - t0) * 1e6
        assert np.array_equal(codec.decode_chunk(blob2), q)
        rows.append((f"compression.{arch}.intra_search", us2,
                     fp16_bytes / len(blob2)))

        # baseline: no temporal prediction (llm.265-style, Fig. 7)
        codec_nt = KVCodec(H, D, codec.layout,
                           CodecOptions(allow_temporal=False))
        blob3 = codec_nt.encode_chunk(q, "240p")
        rows.append((f"compression.{arch}.no_interframe_pred", 0.0,
                     fp16_bytes / len(blob3)))
    return rows
