"""Paper Fig. 25: decode throughput (tokens/s) per platform from the pool
tables, plus this repo's real host-CPU rANS decode throughput."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, real_kv
from repro.core import entropy
from repro.core.adaptive import TABLES
from repro.core.codec import KVCodec
from repro.core.quantization import quantize


def run() -> List[Row]:
    rows: List[Row] = []
    # table-driven NVDEC pools: tokens/s = pool capacity / tokens per chunk
    tokens_per_chunk = 10_000
    for name in ("l20", "h20", "a100"):
        t = TABLES[name]
        lat = t.decode_latency("1080p", t.n_decoders)
        tok_s = t.n_decoders * tokens_per_chunk / lat / 40  # 40 chunks/ctx
        rows.append((f"decode_tput.{name}.tokens_per_s", lat * 1e6, tok_s))

    # measured: this repo's real decode path (rANS + inverse prediction)
    cfg, kv_k, _ = real_kv("lwm-7b", T=512)
    q, _ = quantize(kv_k[:, :3])
    codec = KVCodec(cfg.num_kv_heads, cfg.head_dim)
    codec.search_layout(q[:128], "240p")
    blob = codec.encode_chunk(q, "240p")
    t0 = time.perf_counter()
    codec.decode_chunk(blob)
    dt = time.perf_counter() - t0
    rows.append(("decode_tput.host_rans.tokens_per_s", dt * 1e6,
                 512 / dt))
    rows.append(("decode_tput.host_rans.bytes_per_s", dt * 1e6,
                 q.nbytes / dt))
    return rows
