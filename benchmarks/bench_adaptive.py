"""Paper Fig. 17/23: adaptive-resolution fetching under bandwidth jitter
vs fixed-resolution baselines."""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.adaptive import H20_TABLE
from repro.cluster.network import BandwidthTrace
from repro.cluster.simulator import ServingSimulator, kvfetcher_spec
from repro.data.workload import fixed_context_trace
from repro.serving.metrics import summarize

CFG = get_config("yi-34b")
RATIOS = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}


def _run(spec, trace, ctx=100_000, n=2) -> float:
    sim = ServingSimulator(CFG, spec, chip="h20", n_chips=2,
                           bandwidth=trace, table=H20_TABLE)
    res = sim.run(fixed_context_trace(ctx, n_requests=n, gap=60.0),
                  max_new_tokens=8)
    return summarize(res.fetching())["ttft_mean"]


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    traces = {
        "fig17_steps": BandwidthTrace.steps(
            [(0, 6), (5, 3), (15, 4), (25, 2), (35, 6), (45, 3)]),
        "jitter": BandwidthTrace.jittered(rng, 4.0, 600.0),
    }
    # paper's operating point: table-sized chunks (180-256 MB), where
    # decode latency is comparable to transmission and the bubble
    # trade-off is real (Fig. 17/23)
    base = dataclasses.replace(kvfetcher_spec(RATIOS),
                               use_table_sizes=True)
    for tname, trace in traces.items():
        adaptive = _run(base, trace)
        rows.append((f"adaptive.{tname}.adaptive_ttft", 0.0, adaptive))
        for res_name in ("240p", "1080p"):
            fixed = dataclasses.replace(
                base, adaptive=False, fixed_resolution=res_name,
                name=f"fixed_{res_name}")
            t = _run(fixed, trace)
            rows.append((f"adaptive.{tname}.fixed_{res_name}_ttft", 0.0, t))
            rows.append((f"adaptive.{tname}.saving_vs_{res_name}", 0.0,
                         (t - adaptive) / t))
    # our small-chunk regime (measured ratios): decode never binds, so
    # adaptive degenerates to lowest-resolution — reported honestly
    small_ad = _run(kvfetcher_spec(RATIOS), traces["fig17_steps"])
    small_fix = _run(dataclasses.replace(kvfetcher_spec(RATIOS),
                                         adaptive=False,
                                         fixed_resolution="240p",
                                         name="fixed_240p"),
                     traces["fig17_steps"])
    rows.append(("adaptive.small_chunks.adaptive_vs_240p", 0.0,
                 small_fix / small_ad))
    return rows
