"""Paper Fig. 18/21: TTFT across bandwidth x context for all methods.

Compression ratios fed to the simulator are measured by
bench_compression on real KV (conservative defaults used here so the
bench stays fast; see EXPERIMENTS.md for the measured values).

The ``ttft.live.*`` rows run the REAL engine (real model, real codec,
real paged memory) on a virtual clock over a bandwidth-limited trace,
comparing the event-driven async fetch pipeline against the serialized
sync baseline and the fetch-agnostic (HOL-blocking) scheduler."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.adaptive import H20_TABLE, DecodeTable
from repro.cluster.network import BandwidthTrace
from repro.cluster.simulator import (
    ServingSimulator, cachegen_spec, full_prefill_spec, kvfetcher_spec,
    llm265_spec, lmcache_raw_spec, raw_spec,
)
from repro.data.workload import fixed_context_trace
from repro.serving.metrics import summarize

CFG = get_config("yi-34b")
RATIOS = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}


def _ttft(spec, gbps: float, ctx: int) -> float:
    sim = ServingSimulator(CFG, spec, chip="h20", n_chips=2,
                           bandwidth=BandwidthTrace.constant(gbps),
                           table=H20_TABLE)
    res = sim.run(fixed_context_trace(ctx, n_requests=3, gap=90.0),
                  max_new_tokens=8)
    reqs = res.fetching() or res.requests
    return summarize(reqs)["ttft_mean"]


def _live_rows() -> List[Row]:
    """kvfetcher-async vs kvfetcher-sync vs fetch_agnostic on the live
    engine, bandwidth-limited (paper §3.3: pipelining is the TTFT win)."""
    import jax
    import numpy as np

    from repro.configs import reduce_config
    from repro.cluster.storage import KVStore
    from repro.core.chunks import prefix_key
    from repro.models import transformer as tf
    from repro.serving import paged_model
    from repro.serving.engine import LiveEngine

    cfg = reduce_config(get_config("lwm-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, 96)
    full = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, 8)])
    plain = rng.integers(0, cfg.vocab_size, 16)
    kv_k, kv_v = paged_model.donor_prefix_kv(params, cfg, prefix)
    store = KVStore()
    key = prefix_key(prefix)
    store.register_prefix(prefix, kv_k, kv_v, tokens_per_chunk=24,
                          resolutions=("240p", "480p", "1080p"))
    # decode table scaled to this toy model's ~25 kB chunks
    table = DecodeTable(
        name="live-bench", n_decoders=2,
        latency={r: (0.04, 0.05) for r in RATIOS},
        penalty={"240p": 0.01, "480p": 0.008, "640p": 0.004, "1080p": 0.0},
        chunk_size_mb={r: 0.004 for r in RATIOS})
    bw = BandwidthTrace.constant(0.0006)  # ~75 kB/s: bandwidth-limited
    rows: List[Row] = []
    ttfts = {}
    outs = {}
    for name, mode, policy in (("kvfetcher_async", "async", "kvfetcher"),
                               ("kvfetcher_sync", "sync", "kvfetcher"),
                               ("fetch_agnostic", "async",
                                "fetch_agnostic")):
        eng = LiveEngine(params, cfg, store, policy=policy,
                         fetch_mode=mode, bandwidth=bw, decode_table=table)
        r_fetch = eng.submit(full, reuse_prefix=key, reuse_tokens=96,
                             max_new_tokens=4)
        r_plain = eng.submit(plain, max_new_tokens=4)
        eng.run()
        ttfts[name] = r_fetch.ttft
        outs[name] = tuple(eng.outputs[r_fetch.rid])
        rows.append((f"ttft.live.{name}.fetch", r_fetch.ttft * 1e6,
                     r_fetch.ttft))
        rows.append((f"ttft.live.{name}.plain", r_plain.ttft * 1e6,
                     r_plain.ttft))
    assert outs["kvfetcher_async"] == outs["kvfetcher_sync"], \
        "async and sync engines must emit identical tokens"
    rows.append(("ttft.live.speedup_async_vs_sync", 0.0,
                 ttfts["kvfetcher_sync"] / ttfts["kvfetcher_async"]))
    return rows


def run() -> List[Row]:
    rows: List[Row] = []
    methods = {
        "full_prefill": full_prefill_spec(),
        "lmcache_raw": lmcache_raw_spec(),
        "raw": raw_spec(),
        "cachegen": cachegen_spec(3.5),
        "llm265": llm265_spec(5.0),
        "kvfetcher": kvfetcher_spec(RATIOS),
    }
    for gbps in (2.0, 16.0, 40.0):
        for ctx in (50_000, 150_000):
            base = None
            for name, spec in methods.items():
                t = _ttft(spec, gbps, ctx)
                if name == "cachegen":
                    base = t
                rows.append((f"ttft.{name}.bw{gbps:g}.ctx{ctx // 1000}k",
                             t * 1e6, t))
            ours = rows[-1][2]
            rows.append((f"ttft.speedup_vs_cachegen.bw{gbps:g}"
                         f".ctx{ctx // 1000}k", 0.0, base / ours))
    rows.extend(_live_rows())
    return rows
