"""Paper Fig. 18/21: TTFT across bandwidth x context for all methods.

Compression ratios fed to the simulator are measured by
bench_compression on real KV (conservative defaults used here so the
bench stays fast; see EXPERIMENTS.md for the measured values)."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.adaptive import H20_TABLE
from repro.cluster.network import BandwidthTrace
from repro.cluster.simulator import (
    ServingSimulator, cachegen_spec, full_prefill_spec, kvfetcher_spec,
    llm265_spec, lmcache_raw_spec, raw_spec,
)
from repro.data.workload import fixed_context_trace
from repro.serving.metrics import summarize

CFG = get_config("yi-34b")
RATIOS = {"240p": 9.0, "480p": 8.5, "640p": 8.0, "1080p": 7.0}


def _ttft(spec, gbps: float, ctx: int) -> float:
    sim = ServingSimulator(CFG, spec, chip="h20", n_chips=2,
                           bandwidth=BandwidthTrace.constant(gbps),
                           table=H20_TABLE)
    res = sim.run(fixed_context_trace(ctx, n_requests=3, gap=90.0),
                  max_new_tokens=8)
    reqs = res.fetching() or res.requests
    return summarize(reqs)["ttft_mean"]


def run() -> List[Row]:
    rows: List[Row] = []
    methods = {
        "full_prefill": full_prefill_spec(),
        "lmcache_raw": lmcache_raw_spec(),
        "raw": raw_spec(),
        "cachegen": cachegen_spec(3.5),
        "llm265": llm265_spec(5.0),
        "kvfetcher": kvfetcher_spec(RATIOS),
    }
    for gbps in (2.0, 16.0, 40.0):
        for ctx in (50_000, 150_000):
            base = None
            for name, spec in methods.items():
                t = _ttft(spec, gbps, ctx)
                if name == "cachegen":
                    base = t
                rows.append((f"ttft.{name}.bw{gbps:g}.ctx{ctx // 1000}k",
                             t * 1e6, t))
            ours = rows[-1][2]
            rows.append((f"ttft.speedup_vs_cachegen.bw{gbps:g}"
                         f".ctx{ctx // 1000}k", 0.0, base / ours))
    return rows
